"""Tiered expert residency (docs/offload.md): the `ExpertPlacement` tier
contract, `ResidencyState`'s cache / analytic-miss-curve / capacity
semantics, fetch pricing float-exactness between `batch_iteration_time`
and `BatchCostOracle`, bit-exact degradation of the all-hbm tier through
the whole `BatchedEngine` (token streams AND per-step telemetry), the
planner's residency constraints, and the motivating-regime facts: the
production MoE configs whose expert weights alone exceed a single
device's HBM."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (BatchCostOracle, BatchSpecPlanner, CascadeController,
                        ExpertPlacement, FetchDeadlineConstraint, Hardware,
                        MemoryCapConstraint, ResidencyState, TPU_V5E,
                        batch_iteration_time, expert_hbm_bytes,
                        expected_unique_experts_sharded, greedy_allocate)

CFG = get_config("mixtral-8x7b").reduced()          # 4 experts, top-2
EB = expert_hbm_bytes(CFG)
HOST_HW = Hardware("offload-test", hbm_bw=1e9, peak_flops=1e10,
                   ici_bw=5e8, host_bw=1e9)


def _tiered(n_shards=1, host=None):
    """A contiguous placement on CFG with `host` experts demoted."""
    pl = ExpertPlacement.contiguous(CFG.num_experts, n_shards)
    return pl.offload(host if host is not None
                      else [CFG.num_experts - 1])


# ===================================================================== #
# ExpertPlacement tier contract
# ===================================================================== #

def test_tier_contract_and_validation():
    pl = ExpertPlacement.contiguous(4, 2)
    assert pl.tier_of is None
    assert pl.tiers == ("hbm",) * 4 and not pl.has_host_tier

    off = pl.offload([3])
    assert off.tiers == ("hbm", "hbm", "hbm", "host")
    assert off.has_host_tier
    # homes and routed populations are tier-blind...
    assert off.shard_of == pl.shard_of and off.counts == pl.counts
    # ...but the pinned-HBM footprint view drops the host expert
    assert pl.resident_counts == (2, 2)
    assert off.resident_counts == (2, 1)
    assert off.hbm_tier_counts == (2, 1)
    assert off.host_tier_counts == (0, 1)

    with pytest.raises(ValueError):
        ExpertPlacement((0, 0, 1, 1), ("hbm", "hbm", "host"))  # wrong len
    with pytest.raises(ValueError):
        ExpertPlacement((0, 0, 1, 1), ("hbm", "hbm", "hbm", "disk"))
    with pytest.raises(ValueError):
        pl.offload([7])                                        # no such expert


def test_host_tier_cannot_be_replicated():
    pl = ExpertPlacement.contiguous(4, 2)
    rep = pl.replicate({0: 1})
    # replication preserves tiers; offloading the replicated expert raises
    off = rep.offload([3])
    assert off.tiers[3] == "host" and off.has_replication
    with pytest.raises(ValueError):
        rep.offload([0])
    # and the constructor enforces it directly
    with pytest.raises(ValueError):
        ExpertPlacement(((0, 1), 0, 1, 1), ("host", "hbm", "hbm", "hbm"))
    # replicate() carries tier_of through
    assert pl.offload([3]).replicate({0: 1}).tiers[3] == "host"


def test_production_moes_exceed_single_device_hbm():
    """The motivating regime (ISSUE / ROADMAP offload item): the big MoE
    configs' expert weights ALONE exceed one device's HBM — without a
    host tier those models are unservable on a single accelerator."""
    for name in ("deepseek_v2_236b", "kimi_k2_1t_a32b"):
        cfg = get_config(name)
        eb = expert_hbm_bytes(cfg)
        assert eb > 0
        total = cfg.num_experts * eb
        assert total > TPU_V5E.hbm_bytes
        assert total > 4 * TPU_V5E.hbm_bytes  # not marginal: >4 devices
    # the reduced test config comfortably fits (the tests' all-hbm tier)
    assert CFG.num_experts * EB < TPU_V5E.hbm_bytes


# ===================================================================== #
# ResidencyState: slots, caps, cache mechanics
# ===================================================================== #

def test_residency_slots_and_caps():
    off = _tiered(2, host=[2, 3])          # shard 1 homes 2 host experts
    rs = ResidencyState(off, CFG)          # uncapped: every host expert fits
    assert rs.slots == (0, 2)
    assert rs.capacity_experts == [2.0, 2.0]
    assert rs.expected_misses([2.0, 2.0]) == [0.0, 0.0]

    capped = ResidencyState(off, CFG, cap_bytes=[2 * EB, 1.5 * EB])
    assert capped.slots == (0, 1)          # shard 1: 1 slot after 0 pinned
    # shard 0 pins 2 hbm experts > cap -> loud error, not silent clamp
    with pytest.raises(ValueError):
        ResidencyState(off, CFG, cap_bytes=[EB, 2 * EB])
    # per-shard caps; None entries mean uncapped
    mixed = ResidencyState(off, CFG, cap_bytes=[None, EB])
    assert mixed.slots == (0, 1)
    with pytest.raises(ValueError):
        ResidencyState(off, CFG, cap_bytes=[EB])   # 1 cap vs 2 shards
    with pytest.raises(ValueError):
        ResidencyState(off, expert_bytes=0.0)
    with pytest.raises(ValueError):
        ResidencyState(off)                # neither cfg nor expert_bytes


def test_residency_miss_curve():
    off = _tiered(1, host=[2, 3])          # E=4, H=2 on one shard
    for slots, want_frac in ((2, 0.0), (1, 0.5), (0, 1.0)):
        rs = ResidencyState(off, CFG, cap_bytes=2 * EB + slots * EB)
        assert rs.slots == (slots,)
        # miss = acts * (H/E) * (1 - slots/H)
        assert rs.expected_misses([4.0]) == \
            pytest.approx([4.0 * 0.5 * want_frac])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB)
    assert rs.expected_misses([0.0]) == [0.0]
    with pytest.raises(ValueError):
        rs.expected_misses([1.0, 1.0])     # wrong shard count


def test_residency_cache_hits_misses_eviction():
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB + EB)   # 1 slot
    assert rs.resident_counts == (2,)      # only the pinned hbm pair
    hit, missing = rs.access([0, 2], step=0)
    assert hit == [] and missing == [2]    # hbm expert 0 is not tracked
    out = rs.fetch(missing, step=0)
    assert out["fetched"] == 1 and out["per_shard"] == [1]
    assert out["bytes"] == EB and rs.is_resident(2)
    assert rs.resident_counts == (3,)
    hit, missing = rs.access([2], step=1)
    assert hit == [2] and missing == []
    # fetching the other host expert evicts the coldest (slot pressure)
    rs.fetch([3], step=2)
    assert rs.is_resident(3) and not rs.is_resident(2)
    assert rs.evictions == 1
    # hbm-tier experts are always resident; re-fetching a resident is free
    assert rs.is_resident(0)
    assert rs.fetch([3], step=3)["fetched"] == 0
    snap = rs.snapshot()
    assert snap["hits"] == 1 and snap["misses"] == 1
    assert snap["evictions"] == 1 and snap["bytes_fetched"] == 2 * EB
    assert snap["hit_rate"] == pytest.approx(0.5)


def test_residency_eviction_prefers_cold_ema():
    off = _tiered(1, host=[1, 2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=EB + 2 * EB)   # 2 slots
    rs.fetch([1, 2], step=0)
    # expert 2 is hot (activated every step), expert 1 never again
    for step in range(1, 5):
        rs.access([2], step)
        rs.note_step([2], step)
    rs.fetch([3], step=5)
    assert rs.is_resident(2) and rs.is_resident(3)
    assert not rs.is_resident(1)           # the EMA-cold one got evicted


def test_residency_zero_slots_streams_without_retaining():
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB)        # 0 slots
    out = rs.fetch([2, 3], step=0)
    assert out["fetched"] == 2 and out["bytes"] == 2 * EB
    assert not rs.is_resident(2) and rs.evictions == 0
    assert rs.resident_counts == (2,)      # nothing retained
    _, missing = rs.access([2], step=1)    # still a miss next pass
    assert missing == [2]


def test_residency_staging_install_used_discard_unused():
    """The prefetch contract: staging bills bytes but touches nothing at
    prediction time; staged reads are hits; note_step installs only what
    the pass used (post-pass recency) and discards the rest."""
    off = _tiered(1, host=[1, 2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=EB + 2 * EB)   # 2 slots
    out = rs.fetch([1, 2], step=0, stage=True)
    assert out["fetched"] == 2 and out["bytes"] == 2 * EB
    assert not rs.is_resident(1)           # staged, not installed
    assert rs.resident_counts == (1,)      # cache untouched by staging
    hit, missing = rs.access([1, 3], step=0)
    assert hit == [1] and missing == [3]   # staged read counts as a hit
    rs.fetch(missing, step=0)              # demand miss installs directly
    assert rs.is_resident(3)
    rs.note_step([1, 3], step=0)
    assert rs.is_resident(1)               # used staged expert installed
    assert not rs.is_resident(2)           # unused staged expert discarded
    assert rs.resident_counts == (3,) and rs.evictions == 0
    # the discarded one re-bills; a now-resident one stages for free
    assert rs.fetch([1, 2], step=1, stage=True)["fetched"] == 1
    # draining into a full cache evicts the coldest, like a demand fetch
    rs.access([2], step=1)
    rs.note_step([2], step=1)
    assert rs.is_resident(2) and rs.evictions == 1
    assert not rs.is_resident(1)           # EMA-coldest (id tiebreak) out


# ===================================================================== #
# Fetch pricing: degradation, float-exactness, monotonicity
# ===================================================================== #

def test_all_hbm_residency_prices_bit_identically():
    """The degradation clause: an all-hbm ResidencyState (or none) leaves
    every batch_iteration_time output bit-identical — key for key."""
    pl = ExpertPlacement.contiguous(CFG.num_experts, 2)
    rs = ResidencyState(pl, CFG)
    for ns in ([3, 2], [0, 5], [1, 1]):
        ref = batch_iteration_time(CFG, HOST_HW, ns, [64, 64], placement=pl)
        got = batch_iteration_time(CFG, HOST_HW, ns, [64, 64], placement=pl,
                                   residency=rs)
        assert set(ref) == set(got)
        for k in ref:
            assert np.all(ref[k] == got[k]), k


@settings(max_examples=40, deadline=None)
@given(ns=st.lists(st.integers(0, 9), min_size=1, max_size=4),
       slots_b=st.integers(0, 2), hide=st.floats(0.0, 1e-3),
       shards=st.integers(1, 2))
def test_oracle_matches_batch_iteration_time_with_fetch(ns, slots_b, hide,
                                                        shards):
    """The PR-4/PR-6 float-exactness contract extends to fetch pricing:
    `BatchCostOracle.t_batch` == `batch_iteration_time`'s t_iter at every
    allocation, residency and fetch_hide included (shared `_fetch_time`)."""
    host = [2, 3] if shards == 1 else [3]  # host experts on the last shard
    off = _tiered(shards, host=host)
    pinned = sum(off.resident_counts)
    rs = ResidencyState(off, CFG,
                        cap_bytes=[c * EB + (slots_b * EB if s == shards - 1
                                             else 0.0)
                                   for s, c in enumerate(off.resident_counts)])
    ctx = [64] * len(ns)
    orc = BatchCostOracle(CFG, HOST_HW, ctx, placement=off, residency=rs,
                          fetch_hide=hide)
    ref = batch_iteration_time(CFG, HOST_HW, ns, ctx, placement=off,
                               residency=rs, fetch_hide=hide)
    assert orc.t_batch(ns) == ref["t_iter"]
    assert ref["t_fetch_unhidden"] == orc.fetch_unhidden(ns)
    assert np.isfinite(ref["t_iter"])
    del pinned


def test_fetch_pricing_monotone_in_cap_and_attributed():
    """More cache slots -> fewer analytic misses -> cheaper pass, down to
    exactly the uncapped (zero-fetch) price; the fetch term lands in the
    output keys (t_fetch / t_fetch_unhidden / fetch_bytes)."""
    off = _tiered(1, host=[2, 3])
    ns, ctx = [4, 3], [64, 64]
    base = batch_iteration_time(CFG, HOST_HW, ns, ctx, placement=off)
    prev = None
    for slots in (0, 1, 2):
        rs = ResidencyState(off, CFG, cap_bytes=2 * EB + slots * EB)
        out = batch_iteration_time(CFG, HOST_HW, ns, ctx, placement=off,
                                   residency=rs)
        assert np.isfinite(out["t_iter"])
        assert out["t_fetch"] >= out["t_fetch_unhidden"] >= 0.0
        assert out["fetch_bytes"] == pytest.approx(
            sum(out["fetch_miss"]) * EB)
        if prev is not None:
            assert out["t_iter"] <= prev + 1e-15
        prev = out["t_iter"]
    # uncapped host tier: zero analytic misses, the base price exactly
    rs = ResidencyState(off, CFG)
    out = batch_iteration_time(CFG, HOST_HW, ns, ctx, placement=off,
                               residency=rs)
    assert out["t_fetch"] == 0.0 and out["t_iter"] == base["t_iter"]
    # fetch_hide only ever shrinks the unhidden term
    capped = ResidencyState(off, CFG, cap_bytes=2 * EB)
    full = batch_iteration_time(CFG, HOST_HW, ns, ctx, placement=off,
                                residency=capped)
    hid = batch_iteration_time(CFG, HOST_HW, ns, ctx, placement=off,
                               residency=capped, fetch_hide=1.0)
    assert hid["t_fetch"] == full["t_fetch"]
    assert hid["t_fetch_unhidden"] == 0.0
    assert hid["t_iter"] == pytest.approx(full["t_iter"]
                                          - full["t_fetch_unhidden"])


def test_measured_misses_override_analytic_curve():
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG)          # uncapped: analytic misses = 0
    out = batch_iteration_time(CFG, HOST_HW, [4], [64], placement=off,
                               residency=rs, per_shard_miss=[2.0])
    assert out["fetch_miss"] == [2.0]
    assert out["t_fetch"] == pytest.approx(2.0 * EB / HOST_HW.host_bw)
    with pytest.raises(ValueError):
        batch_iteration_time(CFG, HOST_HW, [4], [64], placement=off,
                             residency=rs, per_shard_miss=[1.0, 1.0])


def test_host_tier_requires_host_bw():
    no_host = Hardware("no-host", hbm_bw=1e9, peak_flops=1e10, ici_bw=5e8)
    off = _tiered(1, host=[3])
    rs = ResidencyState(off, CFG)
    with pytest.raises(ValueError):
        batch_iteration_time(CFG, no_host, [3], [64], placement=off,
                             residency=rs)
    with pytest.raises(ValueError):
        BatchCostOracle(CFG, no_host, [64], placement=off, residency=rs)
    # all-hbm placements never touch the host link: no error
    pl = ExpertPlacement.contiguous(CFG.num_experts, 1)
    assert BatchCostOracle(CFG, no_host, [64], placement=pl,
                           residency=ResidencyState(pl, CFG)).t_batch([3]) > 0


def test_a2a_requires_ici():
    """The silent-fallback fix: an ici-less Hardware must refuse to price
    multi-shard all-to-all instead of impersonating HBM bandwidth."""
    no_ici = Hardware("no-ici", hbm_bw=1e9, peak_flops=1e10)
    pl2 = ExpertPlacement.contiguous(CFG.num_experts, 2)
    with pytest.raises(ValueError, match="ici_bw"):
        batch_iteration_time(CFG, no_ici, [3, 2], [64, 64], placement=pl2)
    with pytest.raises(ValueError, match="ici_bw"):
        BatchCostOracle(CFG, no_ici, [64, 64], placement=pl2).t_batch([3, 2])
    # one shard never crosses the interconnect: still fine
    pl1 = ExpertPlacement.contiguous(CFG.num_experts, 1)
    out = batch_iteration_time(CFG, no_ici, [3], [64], placement=pl1)
    assert out.get("t_a2a", 0.0) == 0.0 and np.isfinite(out["t_iter"])


def test_rebalance_respects_residency_capacity():
    """Replica relief must not rebalance onto a shard without residency
    headroom: capping the relief target's capacity at its current load
    pins the gating shard where the uncapped rebalance would have
    relieved it."""
    import dataclasses
    cfg8 = dataclasses.replace(CFG, num_experts=8)
    pl = ExpertPlacement.contiguous(8, 2).replicate({0: 1, 1: 1})
    ns = [6, 6]
    sw = [[1.0, 0.0], [1.0, 0.0]]          # all routing mass on shard 0
    free = expected_unique_experts_sharded(8, 2, ns, pl, 0.0,
                                           shard_weights=sw)
    cap1 = free["per_shard"][1] / 2        # headroom below the free relief
    tight = expected_unique_experts_sharded(
        8, 2, ns, pl, 0.0, shard_weights=sw, capacity=[8.0, cap1])
    assert free["max_shard"] < tight["max_shard"]      # relief was blocked
    assert tight["per_shard"][1] <= cap1 + 1e-9        # clamped to headroom


# ===================================================================== #
# Planner: residency constraints
# ===================================================================== #

def _oracle(residency, fetch_hide=0.0, b=2):
    return BatchCostOracle(CFG, HOST_HW, [64] * b,
                           placement=residency.placement,
                           residency=residency, fetch_hide=fetch_hide)


def test_memory_cap_constraint_denies_over_capacity_grants():
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB)        # capacity 2.0
    orc = _oracle(rs)
    decode, caps, accepts = [0, 1], {0: 6, 1: 6}, {0: 0.95, 1: 0.95}
    a_free, _ = greedy_allocate(orc, [1, 1], decode, caps, accepts)
    a_cap, info = greedy_allocate(
        orc, [1, 1], decode, caps, accepts,
        constraints=[MemoryCapConstraint(residency=rs)])
    assert sum(a_cap.values()) < sum(a_free.values())
    assert 0 in info["denied"].get("memory_cap", set()) \
        or 1 in info["denied"].get("memory_cap", set())
    # the base [1,1] already predicts a union of 3 > capacity 2, so the
    # don't-worsen clause governs: grants must not grow the union at all
    ns = [1 + a_cap[0], 1 + a_cap[1]]
    assert orc.shard_unique(ns)[0] <= orc.shard_unique([1, 1])[0] + 1e-9


def test_memory_cap_escape_clause_never_freezes_the_batch():
    """A base state already over capacity (tiny cap, big batch) must not
    deny everything forever — the don't-worsen clause still admits grants
    that leave the predicted union where it is (saturated)."""
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB)
    orc = _oracle(rs, b=4)
    decode = [0, 1, 2, 3]
    ns0 = [8, 8, 8, 8]                     # union saturated at E=4 > cap
    a, _ = greedy_allocate(orc, ns0, decode, {i: 4 for i in decode},
                           {i: 0.99 for i in decode},
                           constraints=[MemoryCapConstraint(residency=rs)])
    assert sum(a.values()) > 0             # saturated growth still admitted


def test_fetch_deadline_constraint_bounds_unhidden_fetch():
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB)        # every act misses
    decode, caps, accepts = [0, 1], {0: 6, 1: 6}, {0: 0.95, 1: 0.95}
    # zero hide window: any grant that grows the predicted union grows
    # unhidden fetch -> denied from the start
    tight = _oracle(rs, fetch_hide=0.0)
    a_tight, info = greedy_allocate(
        tight, [1, 1], decode, caps, accepts,
        constraints=[FetchDeadlineConstraint(residency=rs)])
    # a hide window big enough to swallow every fetch admits everything
    wide = _oracle(rs, fetch_hide=1.0)
    a_wide, _ = greedy_allocate(
        wide, [1, 1], decode, caps, accepts,
        constraints=[FetchDeadlineConstraint(residency=rs)])
    free, _ = greedy_allocate(wide, [1, 1], decode, caps, accepts)
    assert sum(a_tight.values()) < sum(a_wide.values())
    assert a_wide == free
    assert info["denied"].get("fetch_deadline")
    # the admitted allocation's unhidden fetch never exceeds the base's
    ns = [1 + a_tight[0], 1 + a_tight[1]]
    assert tight.fetch_unhidden(ns) <= tight.fetch_unhidden([1, 1]) + 1e-12


def test_planner_wires_residency_through():
    off = _tiered(1, host=[3])
    rs = ResidencyState(off, CFG, cap_bytes=3 * EB)
    planner = BatchSpecPlanner(CFG, HOST_HW, residency=rs)
    assert planner.placement is off        # adopted from the residency
    names = [c.name for c in planner.build_constraints([0, 1], {0: 3, 1: 3}, {})]
    assert "memory_cap" in names and "fetch_deadline" in names
    ctls = {i: CascadeController() for i in range(2)}
    plan = planner.plan(ctls, [64, 64])
    assert plan.t_base > 0 and np.isfinite(plan.t_predicted)
    # a residency tracking a different placement than the planner's is a
    # pricing-contract violation, loudly
    other = ExpertPlacement.contiguous(CFG.num_experts, 2)
    with pytest.raises(ValueError):
        BatchSpecPlanner(CFG, HOST_HW, placement=other, residency=rs)
    # without a host tier the pipeline stays exactly the PR-5 one
    pl = ExpertPlacement.contiguous(CFG.num_experts, 1)
    vanilla = BatchSpecPlanner(CFG, HOST_HW,
                               residency=ResidencyState(pl, CFG))
    names = [c.name for c in vanilla.build_constraints([0], {0: 3}, {})]
    assert "memory_cap" not in names and "fetch_deadline" not in names


# ===================================================================== #
# Engine: all-hbm drift gate and tiered telemetry
# ===================================================================== #

def _run_sched(cfg, params, residency, n_req=4, max_batch=3, prefetch=True):
    from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                               NGramDrafter, Request)
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                        max_batch=max_batch, max_len=256,
                        temperature=0.0, clock="model", seed=0,
                        residency=residency, prefetch=prefetch)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController())
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 4 + i, 5 + i] * 6,
                    max_new=10 + 2 * i) for i in range(n_req)]
    res = sched.run(reqs)
    return res, eng


@pytest.mark.parametrize("max_batch", [1, 4])
def test_engine_all_hbm_residency_identical_to_none(tiny_moe, max_batch):
    """The acceptance property at B in {1, 4}: an all-hbm ResidencyState
    must leave the BatchedEngine's token streams AND per-step telemetry
    bit-identical to the residency-free engine — every field, the new
    prefetch counters at their zero defaults."""
    cfg, params = tiny_moe
    pl = ExpertPlacement.contiguous(cfg.num_experts, 1)
    r_none, e_none = _run_sched(cfg, params, None, max_batch=max_batch)
    r_hbm, e_hbm = _run_sched(cfg, params, ResidencyState(pl, cfg),
                              max_batch=max_batch)
    assert [r.tokens for r in r_none] == [r.tokens for r in r_hbm]
    assert len(e_none.telemetry.steps) == len(e_hbm.telemetry.steps)
    for a, b in zip(e_none.telemetry.steps, e_hbm.telemetry.steps):
        assert a == b          # dataclass equality: every field
    for ra, rb in zip(r_none, r_hbm):
        assert ra.telemetry.iterations == rb.telemetry.iterations
        assert ra.telemetry.ttft == rb.telemetry.ttft
    assert e_hbm.telemetry.prefetch_hit_rate == 1.0
    assert e_hbm.telemetry.fetch_bytes == 0.0


def test_engine_tiered_residency_telemetry(tiny_moe):
    """A miss-forcing cap on a host-tiered placement: the engine fetches,
    the telemetry shows it, and greedy token streams stay lossless (the
    tier changes pricing, never routing)."""
    cfg, params = tiny_moe
    pl = ExpertPlacement.contiguous(cfg.num_experts, 1)
    eb = expert_hbm_bytes(cfg)
    off = pl.offload([cfg.num_experts - 2, cfg.num_experts - 1])
    cap = (cfg.num_experts - 2) * eb + eb  # one cache slot for two experts
    r_ref, _ = _run_sched(cfg, params, None)
    rs = ResidencyState(off, cfg, cap_bytes=cap)
    r_t, e_t = _run_sched(cfg, params, rs)
    assert [r.tokens for r in r_ref] == [r.tokens for r in r_t]
    tel = e_t.telemetry
    steps = tel.steps
    assert any(s.prefetch_misses > 0 for s in steps)
    assert any(s.t_fetch > 0 for s in steps)
    assert tel.fetch_bytes > 0 and tel.evictions > 0
    assert 0.0 <= tel.prefetch_hit_rate <= 1.0
    snap = rs.snapshot()
    assert snap["bytes_fetched"] == pytest.approx(tel.fetch_bytes)
    # prefetch off: same tokens, zero probe work, demand fetches only
    r_off, e_off = _run_sched(cfg, params,
                              ResidencyState(off, cfg, cap_bytes=cap),
                              prefetch=False)
    assert [r.tokens for r in r_ref] == [r.tokens for r in r_off]
    assert e_off.telemetry.fetch_bytes > 0


def test_engine_rejects_residency_placement_mismatch(tiny_moe):
    from repro.serving import BatchedEngine, NGramDrafter
    cfg, params = tiny_moe
    pl2 = ExpertPlacement.contiguous(cfg.num_experts, 2)
    rs = ResidencyState(ExpertPlacement.contiguous(cfg.num_experts, 1), cfg)
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                      max_len=128, placement=pl2, residency=rs)
    naked = BatchSpecPlanner(cfg)
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                      max_len=128, residency=rs, planner=naked)


# ===================================================================== #
# Satellite: benchmark entrypoints import clean (eagle_study docstring)
# ===================================================================== #

def test_benchmark_modules_import():
    import benchmarks.eagle_study as eagle
    import benchmarks.serving_micro as sm
    assert "simulator" in (eagle.__doc__ or "").lower()
    assert callable(eagle.main)
    assert callable(sm.main)
