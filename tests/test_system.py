"""End-to-end system behaviour: the paper's claims exercised on the REAL
stack (trained tiny MoE -> n-gram drafts -> verification -> Cascade), plus
simulator-level reproduction of the headline numbers."""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import CascadeConfig, CascadeController, StaticKController
from repro.data import make_sample
from repro.serving import NGramDrafter, Request, Scheduler, ServingEngine
from repro.sim.simulator import run_point


# ===================================================================== #
# Real-model end-to-end
# ===================================================================== #

def _engine(cfg, params, **kw):
    return ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                         temperature=0.0, clock="model", **kw)


def test_trained_model_real_speculation_gain(trained_tiny_moe):
    """After training on the periodic-copy task, greedy generations are
    n-gram draftable: the REAL engine must show ETR > 1.5 and identical
    outputs with speculation on/off."""
    from tests.conftest import COPY_PERIOD
    cfg, params, (ce0, ce1) = trained_tiny_moe
    assert ce1 < ce0 * 0.25, (ce0, ce1)  # model actually learned the task
    rng = np.random.default_rng(5)
    p = list(rng.integers(3, cfg.vocab_size, COPY_PERIOD))
    prompt = [1] + p + p + p[:8]  # mid-period: model continues the cycle
    eng = _engine(cfg, params)
    base = eng.generate(prompt, max_new=48,
                        controller=StaticKController(0))
    spec = eng.generate(prompt, max_new=48,
                        controller=StaticKController(3))
    assert spec.tokens == base.tokens           # losslessness
    assert spec.telemetry.etr > 1.5, spec.telemetry.etr

    cas = eng.generate(prompt, max_new=48, controller=CascadeController())
    assert cas.tokens == base.tokens
    # on a draftable stream Cascade must not be slower than no-spec
    assert cas.telemetry.tpot <= base.telemetry.tpot * 1.08


def test_scheduler_mixed_workload(trained_tiny_moe):
    cfg, params, _losses = trained_tiny_moe
    rng = np.random.default_rng(9)
    eng = _engine(cfg, params)
    sched = Scheduler(eng, controller_factory=lambda: CascadeController())
    reqs = []
    for i, task in enumerate(["extract", "math", "extract", "math"]):
        s = make_sample(task, rng, vocab=cfg.vocab_size, prompt_len=32,
                        cont_len=1)
        reqs.append(Request(request_id=f"r{i}", prompt=s.prompt,
                            max_new=24, task=task))
    results = sched.run(reqs)
    assert len(results) == 4
    assert sched.tokens_per_second() > 0
    for r in results:
        assert r.telemetry.output_tokens >= 23


def test_cascade_worst_case_bounded_real_engine(tiny_moe):
    """Cascade's worst-case slowdown is bounded on the real engine
    (paper: 5% at 10-minute horizons; short horizons pay more testing).

    Note the workload is NOT hostile as the original comment claimed: a
    random-weights target greedily collapses to a periodic stream, so
    n-gram drafts ARE accepted (Cascade correctly converges to K=3-4 with
    utility > 1 — verified by phase-by-phase inspection; the manager's
    back-off accounting is sound). Static K=3 therefore legitimately beats
    Cascade by the measurement overhead: 4 baseline iterations at K=0 plus
    test trials while the drafter still proposes short continuations. The
    old `k3 >= cas * 0.98` bound assumed zero acceptance and was wrong;
    the honest bound allows Cascade its documented testing cost (~5-7%
    here) while still catching pathological regressions."""
    cfg, params = tiny_moe
    eng = _engine(cfg, params)
    prompt = [5, 6, 7, 8, 9] * 8
    base = eng.generate(prompt, max_new=60,
                        controller=StaticKController(0))
    cas = eng.generate(prompt, max_new=60, controller=CascadeController())
    assert cas.tokens == base.tokens
    slowdown = cas.telemetry.tpot / base.telemetry.tpot
    assert slowdown < 1.12, slowdown
    # on this (draftable) stream static K=3 may be ahead by at most
    # Cascade's measurement overhead — not more
    k3 = eng.generate(prompt, max_new=60, controller=StaticKController(3))
    assert k3.telemetry.tpot >= cas.telemetry.tpot * 0.90
    # and Cascade must have actually enabled speculation (utility > 1)
    assert cas.telemetry.iterations[-1].utility > 1.0


# ===================================================================== #
# Simulator-level paper claims (fast profiles)
# ===================================================================== #

def test_paper_claim_static_k_harms_moe_math():
    cfg = get_config("mixtral-8x7b")
    r = run_point(cfg, ["math"], 3, n_requests=3, iters=150, seed=2)
    assert r["speedup"] < 0.9  # paper: down to 0.65


def test_paper_claim_cascade_bounds_slowdown():
    cfg = get_config("mixtral-8x7b")
    r = run_point(cfg, ["math"], None, n_requests=3, iters=300, seed=2)
    assert r["speedup"] > 0.88  # paper: >= ~0.95 at 10-min horizons


def test_paper_claim_cascade_on_favorable_task():
    cfg = get_config("mixtral-8x7b")
    r3 = run_point(cfg, ["code"], 3, n_requests=3, iters=200, seed=2)
    rc = run_point(cfg, ["code"], None, n_requests=3, iters=200, seed=2)
    assert rc["speedup"] > 1.15
    assert rc["speedup"] > r3["speedup"] * 0.9


def test_paper_claim_utility_predicts_speedup():
    import os
    os.environ.setdefault("REPRO_BENCH_OUT", "/tmp/bench_test")
    from benchmarks.utility_fit import main as fit
    r2 = fit(fast=True)
    assert r2 > 0.97  # paper: 0.994
