"""Training + data + checkpoint substrates."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.data import batch_iterator, make_sample, request_stream
from repro.data.workloads import MIXES
from repro.training import (adafactor, adamw, apply_updates,
                            clip_by_global_norm, make_train_step,
                            warmup_cosine)


def test_loss_decreases_tiny_moe(trained_tiny_moe):
    _, _, (first_ce, final_ce) = trained_tiny_moe
    assert final_ce < first_ce * 0.25, (first_ce, final_ce)


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 100.0, "b": (jnp.ones((2, 2)) * 100.0,)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.training import global_norm
    assert float(norm) > 100
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_and_adafactor_step_shapes():
    params = {"w": jnp.ones((8, 16), jnp.bfloat16),
              "blocks_list": ({"x": jnp.ones((4,), jnp.float32)},),
              "b": jnp.zeros((16,), jnp.float32)}
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 0.1,
                         params)
    for opt in (adamw(1e-2), adafactor(1e-2)):
        st = opt.init(params)
        up, st2 = opt.update(grads, st, params)
        new = apply_updates(params, up)
        assert jax.tree.structure(new) == jax.tree.structure(params)
        assert all(n.shape == p.shape for n, p in
                   zip(jax.tree.leaves(new), jax.tree.leaves(params)))
        assert int(st2.step) == 1
        # updates must be non-zero and finite
        for u in jax.tree.leaves(up):
            assert np.isfinite(np.asarray(u, np.float32)).all()
            assert float(jnp.abs(u.astype(jnp.float32)).max()) > 0


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)


def test_workload_draftability_ordering():
    """extraction must have far higher n-gram copy-rate than math — the
    property the paper's task suite rests on."""
    from repro.serving import NGramDrafter
    rng = np.random.default_rng(0)
    rates = {}
    for task in ("extract", "math"):
        hits = tot = 0
        for i in range(10):
            s = make_sample(task, rng, vocab=128, prompt_len=64,
                            cont_len=128)
            d = NGramDrafter()
            hist = list(s.prompt)
            for t in s.continuation:
                drafts, _ = d.propose(hist, 1)
                if drafts:
                    tot += 1
                    hits += int(drafts[0] == t)
                hist.append(t)
        rates[task] = hits / max(tot, 1)
    assert rates["extract"] > rates["math"] + 0.2, rates


def test_request_stream_mixing():
    reqs = request_stream("code+math", 6, seed=0)
    assert [r.task for r in reqs] == ["code", "math"] * 3
    assert set(MIXES["all-3"]) == {"code", "math", "extract"}


def test_batch_iterator_shapes():
    it = batch_iterator("all-3", 4, 64, vocab=128)
    b = next(it)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:])[b["mask"][:, :-1] > 0].all()


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "nested": ({"b": jnp.arange(5, dtype=jnp.int32)},),
            "scalar": jnp.asarray(2.5, jnp.float32)}
    path = os.path.join(tmp_path, "ck.msgpack")
    save(path, tree)
    back = restore(path)
    assert back["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    np.testing.assert_array_equal(np.asarray(back["nested"][0]["b"]),
                                  np.arange(5))
