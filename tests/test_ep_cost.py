"""EP-shard-aware cost accounting and planning: the `ExpertPlacement`
contract, the sharded union model's invariants (per-shard counts partition
the union, the gating shard never exceeds the global curve, skew
concentrates it monotonically), float-exact degradation to the unsharded
stack at n_shards=1 (statistics, oracle pricing, and the whole
`BatchedEngine` — token streams and telemetry), and the planner's
hot-shard steering. Property-based tests use hypothesis (or the in-repo
fallback, tests/_hypothesis_compat.py)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (BatchCostOracle, BatchSpecPlanner, CascadeController,
                        ExpertPlacement, Hardware, PlannerConfig, TPU_V5E,
                        batch_iteration_time, expected_unique_experts,
                        expected_unique_experts_batch,
                        expected_unique_experts_sharded, greedy_allocate)

CFG = get_config("mixtral-8x7b").reduced()          # 4 experts, top-2
# every regime carries an ici figure: these tests pair the hardware with
# multi-shard placements, and an ici-less Hardware now refuses to price
# multi-shard all-to-all instead of silently impersonating HBM bandwidth
HWS = [TPU_V5E,
       Hardware("slowmem", hbm_bw=1e9, peak_flops=197e12, ici_bw=1e9),
       Hardware("slowflops", hbm_bw=819e9, peak_flops=2e9, ici_bw=50e9),
       Hardware("crossover", hbm_bw=1e9, peak_flops=6e9, ici_bw=5e8)]


def _placement(e, s, kind):
    return (ExpertPlacement.contiguous(e, s) if kind == "contiguous"
            else ExpertPlacement.zipf(e, s, alpha=2.0))


# ===================================================================== #
# ExpertPlacement contract
# ===================================================================== #

def test_placement_constructors_and_validation():
    pl = ExpertPlacement.contiguous(8, 4)
    # matches distributed/expert_parallel.py's layout: e // (E / S)
    assert pl.shard_of == tuple(e // 2 for e in range(8))
    assert pl.counts == (2, 2, 2, 2) and pl.n_shards == 4

    pz = ExpertPlacement.zipf(8, 4, alpha=2.0)
    assert sum(pz.counts) == 8 and min(pz.counts) >= 1
    assert pz.counts == tuple(sorted(pz.counts, reverse=True))
    assert pz.counts[0] > pz.counts[-1]            # actually skewed

    assert ExpertPlacement.from_sizes([3, 1]).shard_of == (0, 0, 0, 1)
    with pytest.raises(ValueError):
        ExpertPlacement.contiguous(8, 3)           # not divisible
    with pytest.raises(ValueError):
        ExpertPlacement((0, 2))                    # shard 1 empty
    with pytest.raises(ValueError):
        ExpertPlacement.from_sizes([2, 0])
    with pytest.raises(ValueError):
        ExpertPlacement.zipf(4, 8)


def test_zipf_every_shard_nonempty_across_grid():
    for e in (4, 8, 16, 64):
        for s in (1, 2, 4):
            for a in (0.5, 1.0, 2.0, 4.0):
                pl = ExpertPlacement.zipf(e, s, alpha=a)
                assert sum(pl.counts) == e and min(pl.counts) >= 1


# ===================================================================== #
# Sharded union model invariants
# ===================================================================== #

@settings(max_examples=80, deadline=None)
@given(ns=st.lists(st.integers(0, 9), min_size=1, max_size=6),
       aff=st.floats(0.0, 1.0), seed=st.integers(0, 10 ** 6))
def test_sharded_n1_equals_batch_union_float_exactly(ns, aff, seed):
    """The pricing contract's degradation clause: at one shard (or no
    placement) the sharded statistics ARE `expected_unique_experts_batch`,
    bit for bit — no parallel re-derivation allowed to drift."""
    rng = np.random.default_rng(seed)
    e = int(rng.integers(2, 64))
    k = int(rng.integers(1, min(e, 8) + 1))
    ref = expected_unique_experts_batch(e, k, ns, aff)["union"]
    for pl in (None, ExpertPlacement.contiguous(e, 1)):
        sh = expected_unique_experts_sharded(e, k, ns, pl, aff)
        assert sh["union"] == ref
        assert sh["per_shard"] == [ref]
        assert sh["max_shard"] == ref and sh["hot_shard"] == 0


@settings(max_examples=80, deadline=None)
@given(ns=st.lists(st.integers(0, 9), min_size=1, max_size=6),
       aff=st.floats(0.0, 1.0), seed=st.integers(0, 10 ** 6))
def test_sharded_partition_and_gating_bounds(ns, aff, seed):
    """Every expert lives on exactly one shard, so the per-shard expected
    counts partition the model's union (sum >= union up to float error; at
    uniform routing the sum IS the global curve), and the gating shard can
    never exceed the global union (fewer bins hold fewer distinct
    experts)."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(2, 5))
    e = s * int(rng.integers(1, 9))
    k = int(rng.integers(1, min(e, 8) + 1))
    pl = _placement(e, s, str(rng.choice(["contiguous", "zipf"])))
    glob = expected_unique_experts(e, k, max(sum(ns), 1), aff)

    # uniform routing: shards partition the global curve exactly
    sh = expected_unique_experts_sharded(e, k, ns, pl, aff)
    assert sum(sh["per_shard"]) >= sh["union"] - 1e-9
    if sum(ns) > 0:
        assert sh["union"] == pytest.approx(glob, rel=1e-9)
    assert sh["max_shard"] <= glob + 1e-9
    assert sh["max_shard"] == max(sh["per_shard"])

    # skewed per-request profiles: the union concentrates — the sum stays
    # the (skew-consistent) union and the gating shard still never beats
    # the uniform global curve
    b = len(ns)
    w = rng.dirichlet(np.ones(s) * 0.5, size=b)
    shw = expected_unique_experts_sharded(e, k, ns, pl, aff,
                                          shard_weights=w.tolist())
    assert sum(shw["per_shard"]) >= shw["union"] - 1e-9
    assert shw["max_shard"] <= glob + 1e-9
    for u, cap in zip(shw["per_shard"], pl.counts):
        assert -1e-12 <= u <= cap + 1e-12


@settings(max_examples=40, deadline=None)
@given(t=st.integers(1, 40), aff=st.floats(0.0, 0.9),
       seed=st.integers(0, 10 ** 6))
def test_max_shard_monotone_in_skew(t, aff, seed):
    """Concentrating one routing profile onto the hot shard can only raise
    the gating shard's expected count: max_shard is nondecreasing in the
    skew exponent."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(2, 5))
    e = s * int(rng.integers(1, 5))
    k = int(rng.integers(1, min(e, 4) + 1))
    pl = ExpertPlacement.contiguous(e, s)
    prev = -1.0
    for alpha in (0.0, 0.5, 1.0, 2.0, 4.0, 8.0):
        w = np.array([1.0 / (i + 1) ** alpha for i in range(s)])
        w = (w / w.sum()).tolist()
        sh = expected_unique_experts_sharded(e, k, [t], pl, aff,
                                             shard_weights=[w])
        assert sh["per_shard"][0] >= prev - 1e-9
        prev = sh["per_shard"][0]
        assert sh["hot_shard"] == 0


# ===================================================================== #
# Sharded pricing: oracle == batch_iteration_time, degradation, structure
# ===================================================================== #

@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 6), seed=st.integers(0, 10 ** 6),
       aff=st.floats(0.0, 1.0))
def test_sharded_oracle_matches_batch_iteration_time_exactly(b, seed, aff):
    """The planner prices candidate allocations through the oracle; the
    engine prices the realized pass through `batch_iteration_time`. Under
    a placement (shard-aware AND the balanced comparator) the two must
    still agree to the float."""
    rng = np.random.default_rng(seed)
    ns = [int(rng.integers(0, 9)) for _ in range(b)]
    cls = [int(rng.integers(1, 400)) for _ in range(b)]
    ps = [int(rng.integers(0, 16)) for _ in range(b)]
    hw = HWS[seed % len(HWS)]
    import dataclasses
    s = int(rng.integers(1, 5))
    pl = _placement(4 * s, s, str(rng.choice(["contiguous", "zipf"])))
    cfg = dataclasses.replace(CFG, num_experts=pl.num_experts)
    sw = [rng.dirichlet(np.ones(s)).tolist() if rng.integers(2) else None
          for _ in range(b)]
    bal = bool(rng.integers(2))
    oracle = BatchCostOracle(cfg, hw, cls, affinity=aff, prefill_tokens=ps,
                             placement=pl, shard_weights=sw,
                             assume_balanced=bal)
    ref = batch_iteration_time(cfg, hw, ns, cls, affinity=aff,
                               prefill_tokens=ps, placement=pl,
                               shard_weights=sw, assume_balanced=bal)
    assert oracle.t_batch(ns) == ref["t_iter"]


def test_sharded_pricing_degrades_exactly_at_one_shard():
    """placement=None, a 1-shard placement, and PR 3's unsharded call must
    all price identically — keys included (no shard keys leak into the
    unsharded result)."""
    pl1 = ExpertPlacement.contiguous(CFG.num_experts, 1)
    a = batch_iteration_time(CFG, TPU_V5E, [3, 2], [100, 50], affinity=0.3)
    b = batch_iteration_time(CFG, TPU_V5E, [3, 2], [100, 50], affinity=0.3,
                             placement=pl1)
    assert a == b
    assert "shard_unique" not in a and "t_a2a" not in a


def test_sharded_result_structure_and_attribution():
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=8)
    pl = ExpertPlacement.zipf(8, 4, alpha=2.0)
    hw = HWS[3]
    r = batch_iteration_time(cfg, hw, [3, 2, 4], [100, 50, 200],
                             affinity=0.2, placement=pl)
    assert r["n_shards"] == 4 and len(r["shard_unique"]) == 4
    assert r["max_shard_experts"] == max(r["shard_unique"])
    assert r["hot_shard"] == int(np.argmax(r["shard_unique"]))
    assert r["imbalance"] >= 1.0 - 1e-12
    assert r["t_a2a"] > 0.0
    # attribution still sums to the pass (a2a + overhead split evenly)
    assert sum(p["t_attr"] for p in r["per_request"]) == pytest.approx(
        r["t_iter"], rel=1e-12)
    # the hottest shard gates: pricing with the max equals pricing the
    # same pass with every shard's count raised to the max
    gate = r["max_shard_experts"]
    r2 = batch_iteration_time(cfg, hw, [3, 2, 4], [100, 50, 200],
                              affinity=0.2, placement=pl,
                              per_shard_unique=[gate] * 4)
    assert r2["t_iter"] == r["t_iter"]


def test_measured_per_shard_counts_override_analytic():
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=8)
    pl = ExpertPlacement.contiguous(8, 2)
    hw = HWS[1]
    lo = batch_iteration_time(cfg, hw, [4], [100], placement=pl,
                              per_shard_unique=[1.0, 1.0])
    hi = batch_iteration_time(cfg, hw, [4], [100], placement=pl,
                              per_shard_unique=[4.0, 1.0])
    assert hi["t_iter"] > lo["t_iter"]
    assert hi["hot_shard"] == 0 and hi["imbalance"] == pytest.approx(1.6)
    with pytest.raises(ValueError):
        batch_iteration_time(cfg, hw, [4], [100], placement=pl,
                             per_shard_unique=[1.0, 1.0, 1.0])


def test_balanced_comparator_underprices_skewed_pass():
    """The --ep-sweep's motivating inequality: with a skewed placement the
    global-union (balanced) model prices the pass below the max-over-shards
    truth — the under-pricing that grants speculation a sharded deployment
    cannot afford."""
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=8)
    pl = ExpertPlacement.zipf(8, 4, alpha=2.0)
    hw = Hardware("mem", hbm_bw=1e9, peak_flops=1e14, ici_bw=5e8)
    aware = batch_iteration_time(cfg, hw, [4, 4], [100, 100], placement=pl)
    bal = batch_iteration_time(cfg, hw, [4, 4], [100, 100], placement=pl,
                               assume_balanced=True)
    assert bal["t_iter"] < aware["t_iter"]


# ===================================================================== #
# Planner steering
# ===================================================================== #

def test_water_filling_steers_away_from_hot_shard():
    """Two identical requests, one routing onto the gating shard, one
    spreading over cold shards: the hot-profiled request's grants can
    never exceed the cold one's, and in a regime where the hot shard's
    delta breaks the water level the cold request keeps speculating."""
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=16)
    pl = ExpertPlacement.contiguous(16, 4)
    hw = Hardware("mem", hbm_bw=1e9, peak_flops=1e14, ici_bw=5e8)
    hot = [1.0, 0.0, 0.0, 0.0]
    cold = [0.0, 1 / 3, 1 / 3, 1 / 3]
    oracle = BatchCostOracle(cfg, hw, [1024, 1024], placement=pl,
                             shard_weights=[hot, cold])
    accepts = {0: 0.4, 1: 0.4}
    caps = {0: 6, 1: 6}
    alloc, _ = greedy_allocate(oracle, [1, 1], [0, 1], caps, accepts)
    assert alloc[1] > alloc[0], alloc
    # sanity: with identical profiles the tie breaks symmetrically enough
    # that neither row dominates by more than one grant
    o2 = BatchCostOracle(cfg, hw, [1024, 1024], placement=pl,
                         shard_weights=[cold, cold])
    a2, _ = greedy_allocate(o2, [1, 1], [0, 1], caps, accepts)
    assert abs(a2[0] - a2[1]) <= 1


def test_planner_plan_accepts_shard_profiles():
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=8)
    pl = ExpertPlacement.contiguous(8, 4)
    planner = BatchSpecPlanner(cfg, HWS[3], placement=pl)
    ctls = {i: CascadeController() for i in range(2)}
    plan = planner.plan(ctls, [64, 64],
                        shard_weights={0: [1.0, 0.0, 0.0, 0.0]})
    assert plan.t_base > 0
    with pytest.raises(ValueError):
        BatchSpecPlanner(CFG, placement=ExpertPlacement.contiguous(8, 4))


def test_placement_model_mismatch_rejected_everywhere():
    """The pricing contract's one consistency check applies at every entry
    point — including the 1-shard placement (the degradation clause must
    not skip validation)."""
    wrong1 = ExpertPlacement.contiguous(8, 1)      # CFG has 4 experts
    with pytest.raises(ValueError):
        expected_unique_experts_sharded(CFG.num_experts, 2, [3], wrong1)
    with pytest.raises(ValueError):
        BatchSpecPlanner(CFG, placement=wrong1)
    with pytest.raises(ValueError):
        BatchCostOracle(CFG, TPU_V5E, [64], placement=wrong1)
    # a placement on a dense config is a loud error, not a silent no-op
    dense = get_config("stablelm-1.6b").reduced()
    with pytest.raises(ValueError):
        BatchSpecPlanner(dense, placement=ExpertPlacement.contiguous(8, 4))


def test_engine_rejects_planner_placement_mismatch(tiny_moe):
    """Like the PR 3 policy check: a supplied planner pricing a different
    deployment than the engine measures must raise, not silently
    re-introduce the global-union mispricing."""
    from repro.serving import BatchedEngine, NGramDrafter
    cfg, params = tiny_moe
    pl = ExpertPlacement.contiguous(cfg.num_experts, 2)
    naked = BatchSpecPlanner(cfg)                  # placement-free planner
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                      max_len=128, placement=pl, planner=naked)
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                      max_len=128, planner=BatchSpecPlanner(
                          cfg, placement=pl))
    # agreeing placements pass — including the balanced comparator
    eng = BatchedEngine(
        cfg, params, lambda: NGramDrafter(), max_batch=1, max_len=128,
        placement=pl,
        planner=BatchSpecPlanner(
            cfg, config=PlannerConfig(shard_aware=False), placement=pl))
    assert eng.placement is pl


# ===================================================================== #
# Engine: n_shards=1 placement is PR 3, bit for bit; sharded telemetry
# ===================================================================== #

def _run_sched(cfg, params, placement, temperature, n_req=4, max_batch=3):
    from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                               NGramDrafter, Request)
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                        max_batch=max_batch, max_len=256,
                        temperature=temperature, clock="model", seed=0,
                        placement=placement)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController())
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 4 + i, 5 + i] * 6,
                    max_new=10 + 2 * i) for i in range(n_req)]
    res = sched.run(reqs)
    return res, eng


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_engine_one_shard_placement_identical_to_none(tiny_moe,
                                                      temperature):
    """The acceptance property: a 1-shard ExpertPlacement must leave the
    BatchedEngine's token streams AND telemetry identical to PR 3's
    placement-free engine — every PR 3 step/iteration field, and the new
    shard fields at their unsharded defaults."""
    cfg, params = tiny_moe
    pl1 = ExpertPlacement.contiguous(cfg.num_experts, 1)
    r_none, e_none = _run_sched(cfg, params, None, temperature)
    r_one, e_one = _run_sched(cfg, params, pl1, temperature)
    assert [r.tokens for r in r_none] == [r.tokens for r in r_one]
    assert len(e_none.telemetry.steps) == len(e_one.telemetry.steps)
    for a, b in zip(e_none.telemetry.steps, e_one.telemetry.steps):
        assert a == b          # dataclass equality: every field, new ones too
    for ra, rb in zip(r_none, r_one):
        assert ra.telemetry.iterations == rb.telemetry.iterations
        assert ra.telemetry.ttft == rb.telemetry.ttft


def test_engine_sharded_telemetry_consistent(tiny_moe):
    """Sharded steps surface union AND gating shard separately (the
    engine.py np.mean fold fix): per-shard counts partition the union,
    max_shard is their max, imbalance = max/mean, and the planner stats
    aggregate them."""
    cfg, params = tiny_moe
    pl = ExpertPlacement.contiguous(cfg.num_experts, 2)
    res, eng = _run_sched(cfg, params, pl, 0.0)
    steps = eng.telemetry.steps
    assert steps and all(s.hot_shard >= 0 for s in steps)
    for s in steps:
        assert len(s.shard_experts) == 2
        assert s.max_shard_experts == pytest.approx(max(s.shard_experts))
        assert sum(s.shard_experts) == pytest.approx(s.union_experts)
        mean = sum(s.shard_experts) / 2
        if mean > 0:
            assert s.shard_imbalance == pytest.approx(
                s.max_shard_experts / mean)
        assert s.t_a2a > 0.0
    stats = eng.telemetry
    assert stats.mean_shard_imbalance >= 1.0
    assert 0.0 < stats.hot_shard_frac <= 1.0
    # greedy decoding stays lossless under sharded pricing
    r_none, _ = _run_sched(cfg, params, None, 0.0)
    assert [r.tokens for r in res] == [r.tokens for r in r_none]


# ===================================================================== #
# Hot-expert replication: min-over-replicas pricing
# ===================================================================== #

def test_replicated_placement_contract():
    pl = ExpertPlacement.contiguous(8, 4)
    pr = pl.replicate({0: 1, 1: (2, 3)})
    assert pr.has_replication and not pl.has_replication
    assert pr.primary_shard_of == pl.shard_of        # homes unchanged
    assert pr.counts == pl.counts                    # activation population
    assert pr.resident_counts == (2, 3, 3, 3)        # replicas add bytes
    assert pr.n_shards == 4 and pr.num_experts == 8
    assert pr.replication_groups == ((0, (1,), 1), (0, (2, 3), 1))
    # direct construction: tuple entries are replica sets, primary first
    mixed = ExpertPlacement(((0, 1), 1))
    assert mixed.primary_shard_of == (0, 1)
    with pytest.raises(ValueError):
        ExpertPlacement(((0, 0), 1))                 # duplicate replica
    with pytest.raises(ValueError):
        pl.replicate({0: 7})                         # beyond the shards
    with pytest.raises(ValueError):
        pl.replicate({99: 1})                        # no such expert
    with pytest.raises(ValueError):
        ExpertPlacement(((0, 2), 0))                 # shard 1 unresident


def test_replication_relieves_the_gating_shard_concretely():
    """All of hot shard 0's experts replicated onto cold shard 3: the
    activated load spreads and the gating count drops toward balance,
    while the union is conserved."""
    pl = ExpertPlacement.zipf(8, 4, alpha=2.0)       # shard 0 hot
    hot_experts = [e for e, s in enumerate(pl.shard_of) if s == 0]
    pr = pl.replicate({e: 3 for e in hot_experts})
    base = expected_unique_experts_sharded(8, 2, [6, 6], pl)
    rep = expected_unique_experts_sharded(8, 2, [6, 6], pr)
    assert rep["max_shard"] < base["max_shard"]
    assert rep["union"] == pytest.approx(base["union"], rel=1e-9)
    # and the priced pass is cheaper: the hottest shard gates it
    hw = Hardware("mem", hbm_bw=1e9, peak_flops=1e14, ici_bw=5e8)
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=8)
    t_base = batch_iteration_time(cfg, hw, [6, 6], [100, 100],
                                  placement=pl)["t_iter"]
    t_rep = batch_iteration_time(cfg, hw, [6, 6], [100, 100],
                                 placement=pr)["t_iter"]
    assert t_rep < t_base


@settings(max_examples=60, deadline=None)
@given(ns=st.lists(st.integers(0, 9), min_size=1, max_size=5),
       aff=st.floats(0.0, 1.0), seed=st.integers(0, 10 ** 6))
def test_replication_never_increases_gating_shard(ns, aff, seed):
    """The satellite property: ANY replication added to ANY placement can
    only lower (or keep) the gating shard's expected activated count and
    the priced pass time — min-over-replicas is a relief, never a tax.
    Union and per-request profiles are preserved."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(2, 5))
    e = s * int(rng.integers(1, 6))
    k = int(rng.integers(1, min(e, 8) + 1))
    pl = _placement(e, s, str(rng.choice(["contiguous", "zipf"])))
    reps = {}
    for ex in range(e):
        if rng.integers(3) == 0:
            extra = [x for x in range(s) if x != pl.shard_of[ex]]
            take = int(rng.integers(1, len(extra) + 1))
            reps[ex] = tuple(rng.choice(extra, take, replace=False)
                             .tolist())
    pr = pl.replicate(reps) if reps else pl
    sw = (rng.dirichlet(np.ones(s), size=len(ns)).tolist()
          if rng.integers(2) else None)
    base = expected_unique_experts_sharded(e, k, ns, pl, aff,
                                           shard_weights=sw)
    rep = expected_unique_experts_sharded(e, k, ns, pr, aff,
                                          shard_weights=sw)
    assert rep["max_shard"] <= base["max_shard"] + 1e-9
    assert rep["union"] == pytest.approx(base["union"], rel=1e-9, abs=1e-12)
    # oracle pricing agrees with batch_iteration_time under replication
    import dataclasses
    cfg = dataclasses.replace(CFG, num_experts=e)
    hw = HWS[seed % len(HWS)]
    cls = [int(rng.integers(8, 200)) for _ in ns]
    oracle = BatchCostOracle(cfg, hw, cls, affinity=aff, placement=pr,
                             shard_weights=sw)
    ref = batch_iteration_time(cfg, hw, ns, cls, affinity=aff,
                               placement=pr, shard_weights=sw)
    assert oracle.t_batch(ns) == ref["t_iter"]
    assert ref["t_iter"] <= batch_iteration_time(
        cfg, hw, ns, cls, affinity=aff, placement=pl,
        shard_weights=sw)["t_iter"] + 1e-12
