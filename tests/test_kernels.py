"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp ref.py
oracles, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)
from repro.kernels.linear_scan import linear_scan, linear_scan_ref
from repro.kernels.moe_gmm import (moe_gmm, moe_gmm_fused,
                                   moe_gmm_fused_ref, moe_gmm_ref)
from repro.kernels.rwkv_scan import rwkv_scan, rwkv_scan_ref

RNG = np.random.default_rng(0)


def _r(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


# --------------------------------------------------------------------- #

@pytest.mark.parametrize("b,t,d,bt,bd", [
    (2, 8, 16, 4, 8), (1, 16, 8, 8, 8), (3, 12, 24, 4, 8), (1, 32, 16, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_linear_scan(b, t, d, bt, bd, dtype):
    a = jnp.asarray(RNG.uniform(0.5, 1.0, (b, t, d)), dtype)
    x = _r((b, t, d), dtype)
    h0 = _r((b, d), dtype)
    y1, h1 = linear_scan_ref(a, x, h0)
    y2, h2 = linear_scan(a, x, h0, force_pallas=True, bt=bt, bd=bd)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


@pytest.mark.parametrize("b,t,h,n,bt", [(2, 8, 2, 8, 4), (1, 16, 3, 16, 8),
                                        (1, 12, 1, 8, 12)])
def test_rwkv_scan(b, t, h, n, bt):
    r, k, v = _r((b, t, h, n)), _r((b, t, h, n)), _r((b, t, h, n))
    w = jnp.asarray(RNG.uniform(0.5, 1.0, (b, t, h, n)), jnp.float32)
    u = _r((h, n))
    s0 = _r((b, h, n, n))
    y1, s1 = rwkv_scan_ref(r, k, v, w, u, s0)
    y2, s2 = rwkv_scan(r, k, v, w, u, s0, force_pallas=True, bt=bt)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)


@pytest.mark.parametrize("b,h,hkv,d,s,bs,win,filled", [
    (2, 4, 2, 16, 32, 8, 0, 20),
    (1, 8, 1, 32, 64, 16, 24, 64),
    (2, 2, 2, 8, 16, 16, 0, 5),
    (1, 4, 4, 64, 32, 8, 8, 30),
])
def test_decode_attention(b, h, hkv, d, s, bs, win, filled):
    q = _r((b, h, d))
    kc, vc = _r((b, s, hkv, d)), _r((b, s, hkv, d))
    pos = np.full((b, s), -1, np.int32)
    pos[:, :filled] = np.arange(filled)
    pos = jnp.asarray(pos)
    qpos = jnp.full((b,), filled - 1, jnp.int32)
    o1 = decode_attention_ref(q, kc, vc, pos, qpos, window=win)
    o2 = decode_attention(q, kc, vc, pos, qpos, window=win,
                          force_pallas=True, bs=bs)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("b,s,h,hkv,d,bq,bk,win", [
    (2, 32, 4, 2, 16, 8, 8, 0),
    (1, 64, 2, 1, 32, 16, 16, 24),
    (1, 16, 4, 4, 8, 16, 8, 0),
    (2, 32, 8, 2, 16, 8, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, hkv, d, bq, bk, win, dtype):
    q, k, v = (_r((b, s, h, d), dtype), _r((b, s, hkv, d), dtype),
               _r((b, s, hkv, d), dtype))
    o1 = flash_attention_ref(q, k, v, window=win)
    o2 = flash_attention(q, k, v, window=win, force_pallas=True,
                         bq=bq, bk=bk)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


@pytest.mark.parametrize("e,c,d,f,bc,bd,bf", [
    (4, 16, 32, 24, 8, 16, 8),
    (8, 8, 16, 16, 8, 8, 16),
    (3, 32, 8, 8, 16, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(e, c, d, f, bc, bd, bf, dtype):
    counts = RNG.integers(0, c + 1, e).astype(np.int32)
    x = RNG.normal(0, 1, (e, c, d)).astype(np.float32)
    for i, n in enumerate(counts):
        x[i, n:] = 0.0  # dead capacity slots hold zeros by construction
    w = RNG.normal(0, 1, (e, d, f)).astype(np.float32)
    x, w = jnp.asarray(x, dtype), jnp.asarray(w, dtype)
    cj = jnp.asarray(counts)
    y1 = moe_gmm_ref(x, w, cj)
    y2 = moe_gmm(x, w, cj, force_pallas=True, bc=bc, bd=bd, bf=bf)
    atol = 1e-4 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=atol)


def test_moe_gmm_dead_experts_exact_zero():
    """Tiles of experts with zero tokens must be exactly zero (the kernel
    skips their MXU work)."""
    e, c, d, f = 4, 8, 16, 8
    counts = jnp.asarray([0, 8, 0, 3], jnp.int32)
    x = _r((e, c, d))
    x = x.at[0].set(0).at[2].set(0).at[3, 3:].set(0)
    w = _r((e, d, f))
    y = moe_gmm(x, w, counts, force_pallas=True, bc=8, bd=16, bf=8)
    assert float(jnp.abs(y[0]).max()) == 0.0
    assert float(jnp.abs(y[2]).max()) == 0.0


@pytest.mark.parametrize("e,c,d,f,bc,bd,bf", [
    (3, 10, 12, 20, 8, 8, 16),   # nothing divides: every axis padded
    (4, 7, 16, 8, 8, 16, 8),     # C < bc
    (2, 33, 8, 24, 16, 8, 16),   # C just over a tile boundary
])
def test_moe_gmm_non_divisible(e, c, d, f, bc, bd, bf):
    """Regression for the former hard divisibility assert: the kernel now
    pads C/d/F internally and slices the result back."""
    counts = jnp.asarray(RNG.integers(0, c + 1, e), jnp.int32)
    x = RNG.normal(0, 1, (e, c, d)).astype(np.float32)
    for i, n in enumerate(np.asarray(counts)):
        x[i, n:] = 0.0
    x = jnp.asarray(x)
    w = _r((e, d, f))
    y1 = moe_gmm_ref(x, w, counts)
    y2 = moe_gmm(x, w, counts, force_pallas=True, bc=bc, bd=bd, bf=bf)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# --------------------------------------------------------------------- #
# fused packed-union kernel (docs/kernels.md): interpret-mode Pallas vs
# the jnp oracle vs the dense einsum chain the packed dispatch replaces
# --------------------------------------------------------------------- #

def _fused_inputs(u, c, d, f, activation, full=False):
    counts = (np.full(u, c, np.int32) if full
              else RNG.integers(0, c + 1, u).astype(np.int32))
    x = RNG.normal(0, 1, (u, c, d)).astype(np.float32)
    for i, n in enumerate(counts):
        x[i, n:] = 0.0
    wg = _r((u, d, f)) if activation == "swiglu" else None
    wu, wd = _r((u, d, f)), _r((u, f, d))
    return jnp.asarray(x), wg, wu, wd, jnp.asarray(counts)


def _dense_chain(x, wg, wu, wd, counts, activation):
    """The stacked-einsum FFN the packed dispatch path inlines — the
    bit-level oracle `apply_moe(packed=True)` must match."""
    up = jnp.einsum("ucd,udf->ucf", x, wu,
                    preferred_element_type=jnp.float32)
    if activation == "swiglu":
        g = jnp.einsum("ucd,udf->ucf", x, wg,
                       preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("ucf,ufd->ucd", h, wd,
                   preferred_element_type=jnp.float32)
    mask = (jnp.arange(x.shape[1])[None, :] < counts[:, None])
    return (y * mask[:, :, None]).astype(x.dtype)


@pytest.mark.parametrize("u,c,d,f,bc,bf", [
    (1, 8, 16, 16, 8, 8),        # U=1 corner (single activated expert)
    (4, 16, 32, 24, 8, 8),
    (8, 8, 16, 16, 8, 16),       # U=E-shaped full union
    (3, 10, 12, 20, 8, 16),      # non-divisible C and F
    (5, 7, 8, 8, 8, 8),
])
@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
def test_moe_gmm_fused_parity(u, c, d, f, bc, bf, activation):
    x, wg, wu, wd, counts = _fused_inputs(u, c, d, f, activation)
    y_ref = moe_gmm_fused_ref(x, wg, wu, wd, counts, activation=activation)
    y_dense = _dense_chain(x, wg, wu, wd, counts, activation)
    y_k = moe_gmm_fused(x, wg, wu, wd, counts, activation=activation,
                        backend="interpret", bc=bc, bf=bf)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_dense),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               atol=1e-4)


def test_moe_gmm_fused_full_union_parity():
    """U = E corner with every slot saturated: no masking in play, pure
    fused-matmul parity."""
    x, wg, wu, wd, counts = _fused_inputs(6, 8, 16, 16, "swiglu", full=True)
    y_ref = moe_gmm_fused_ref(x, wg, wu, wd, counts)
    y_k = moe_gmm_fused(x, wg, wu, wd, counts, backend="interpret",
                        bc=8, bf=8)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               atol=1e-4)


def test_moe_gmm_fused_dead_slots_exact_zero():
    """Padded union slots (counts == 0) must come out exactly zero — the
    kernel's scalar-prefetch steering never initializes them with real
    expert traffic."""
    x, wg, wu, wd, _ = _fused_inputs(4, 8, 16, 8, "swiglu", full=True)
    counts = jnp.asarray([0, 8, 0, 3], jnp.int32)
    x = x.at[0].set(0).at[2].set(0).at[3, 3:].set(0)
    y = moe_gmm_fused(x, wg, wu, wd, counts, backend="interpret",
                      bc=8, bf=8)
    assert float(jnp.abs(y[0]).max()) == 0.0
    assert float(jnp.abs(y[2]).max()) == 0.0
    assert float(jnp.abs(y[1]).max()) > 0.0


def test_moe_gmm_backend_dispatch():
    """Explicit backend selection: 'ref' and 'interpret' agree; unknown
    backends and unknown tile kwargs are rejected loudly."""
    x, wg, wu, wd, counts = _fused_inputs(2, 8, 8, 8, "swiglu")
    y_ref = moe_gmm_fused(x, wg, wu, wd, counts, backend="ref")
    y_int = moe_gmm_fused(x, wg, wu, wd, counts, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_int),
                               atol=1e-4)
    # force_pallas=True off-TPU lowers to interpret mode (the legacy knob)
    y_fp = moe_gmm_fused(x, wg, wu, wd, counts, force_pallas=True)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_fp),
                               atol=1e-6)
    with pytest.raises(ValueError):
        moe_gmm_fused(x, wg, wu, wd, counts, backend="cuda")
    with pytest.raises(TypeError):
        moe_gmm_fused(x, wg, wu, wd, counts, backend="ref", bd=64)
    with pytest.raises(ValueError):
        moe_gmm(x[:, :, :8], wu[:, :8, :], counts, backend="rocm")


@pytest.mark.parametrize("seed", range(4))
def test_moe_gmm_fused_randomized(seed):
    """Randomized U/C/d/F shapes (odd sizes on every axis) against the
    oracle — the fuzz net for the internal-padding logic."""
    rng = np.random.default_rng(seed)
    u = int(rng.integers(1, 7))
    c = int(rng.integers(1, 20))
    d = int(rng.integers(4, 24))
    f = int(rng.integers(4, 24))
    activation = ["swiglu", "gelu"][seed % 2]
    x, wg, wu, wd, counts = _fused_inputs(u, c, d, f, activation)
    y_ref = moe_gmm_fused_ref(x, wg, wu, wd, counts, activation=activation)
    y_k = moe_gmm_fused(x, wg, wu, wd, counts, activation=activation,
                        backend="interpret", bc=8, bf=8)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               atol=1e-4)


# --------------------------------------------------------------------- #
# chunked WKV (§Perf 'chunked-wkv') vs serial oracle
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("b,t,h,n,c", [(2, 64, 2, 8, 32), (1, 96, 3, 16, 32),
                                       (2, 32, 1, 8, 8)])
def test_wkv_chunked_matches_serial(b, t, h, n, c):
    from repro.models.rwkv import wkv_chunked, wkv_scan
    r, k, v = _r((b, t, h, n)), _r((b, t, h, n)), _r((b, t, h, n))
    w = jnp.asarray(RNG.uniform(0.3, 0.999, (b, t, h, n)), jnp.float32)
    u = _r((h, n))
    s0 = _r((b, h, n, n))
    y1, states = wkv_scan(r, k, v, w, u, s0)
    y2, s2 = wkv_chunked(r, k, v, w, u, s0, chunk=c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(states[-1]), np.asarray(s2),
                               atol=2e-4)
