"""Continuous-batching correctness: per-row rollback vs. single-request
rollbacks, BatchedEngine(B=1) bit-identity with the legacy ServingEngine,
batch cost-model reduction to the single-request model, and the scheduler's
admission/retire behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CascadeController, StaticKController, TPU_V5E,
                        batch_iteration_time, expected_unique_experts,
                        expected_unique_experts_batch, iteration_time)
from repro.models import transformer as T
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           NGramDrafter, Request, Scheduler, ServingEngine)


# ===================================================================== #
# Cost model: batch reduces to single-request
# ===================================================================== #

@pytest.mark.parametrize("arch", ["mixtral-8x7b", "stablelm-1.6b"])
def test_batch_iteration_time_b1_equals_iteration_time(arch):
    cfg = get_config(arch)
    for n, ctx, uniq in [(1, 128, None), (4, 1024, None), (9, 4096, 6.0)]:
        a = iteration_time(cfg, TPU_V5E, n, ctx, unique_experts=uniq,
                           affinity=0.3)
        b = batch_iteration_time(cfg, TPU_V5E, [n], [ctx],
                                 unique_experts=uniq, affinity=0.3)
        assert b["t_iter"] == a["t_iter"]
        assert b["per_request"][0]["t_attr"] == a["t_iter"]


def test_batch_attribution_sums_to_total():
    cfg = get_config("mixtral-8x7b")
    r = batch_iteration_time(cfg, TPU_V5E, [4, 2, 9, 1],
                             [100, 2000, 50, 800], affinity=0.2)
    s = sum(p["t_attr"] for p in r["per_request"])
    assert s == pytest.approx(r["t_iter"], rel=1e-12)
    # a request with a longer context owns more bytes (its own KV read)
    long_ctx = r["per_request"][1]["bytes_attr"]
    short_ctx = r["per_request"][2]["bytes_attr"]
    assert long_ctx > 0 and short_ctx > 0


def test_expected_union_grows_sublinearly():
    """The batch-level Fig. 2 effect: the expert union grows with total
    drafted tokens but saturates, so each extra request's marginal expert
    cost shrinks — speculation utility degrades as the batch fills."""
    e, k = 8, 2
    one = expected_unique_experts(e, k, 4)
    batch = expected_unique_experts_batch(e, k, [4, 4, 4, 4])
    assert batch["union"] > one            # more tokens, more experts...
    assert batch["union"] < 4 * one        # ...but far from additive
    m = batch["marginal"]
    assert all(mi < one for mi in m)       # marginal < standalone cost
    assert batch["union"] <= e


def test_empty_rows_cost_nothing():
    cfg = get_config("mixtral-8x7b")
    a = batch_iteration_time(cfg, TPU_V5E, [3, 0], [128, 0])
    b = iteration_time(cfg, TPU_V5E, 3, 128)
    assert a["t_iter"] == b["t_iter"]
    assert a["per_request"][1]["t_attr"] == 0.0


# ===================================================================== #
# Per-row rollback == loop of single-request rollbacks
# ===================================================================== #

def test_per_row_rollback_matches_single_request_loop(tiny_moe):
    cfg, params = tiny_moe
    prompts = [list(range(3, 19)), list(range(7, 31)),
               [5, 6, 7] * 6]
    spans = [[5, 6, 7], [9], [4, 2]]
    accepts = [2, 1, 0]

    # single-request path, one cache per request
    singles = []
    for p, sp, acc in zip(prompts, spans, accepts):
        c = T.init_cache(cfg, 1, 128)
        _, c, _ = T.prefill(cfg, params, jnp.asarray([p], jnp.int32), c)
        lo, c, _, st = T.decode_step(cfg, params, c,
                                     jnp.asarray([sp], jnp.int32))
        singles.append(T.rollback_cache(cfg, c, st, acc, len(p)))

    # batched per-row path
    bc = T.init_cache(cfg, 3, 128, per_row=True)
    for i, p in enumerate(prompts):
        c = T.init_cache(cfg, 1, 128)
        _, c, _ = T.prefill(cfg, params, jnp.asarray([p], jnp.int32), c)
        bc = T.write_cache_row(bc, i, c)
    t_max = max(len(s) for s in spans)
    toks = np.zeros((3, t_max), np.int32)
    mask = np.zeros((3, t_max), bool)
    for i, sp in enumerate(spans):
        toks[i, :len(sp)] = sp
        mask[i, :len(sp)] = True
    lens_before = np.asarray(bc["lengths"])
    _, bc, _, st = T.decode_step(cfg, params, bc, jnp.asarray(toks),
                                 token_mask=jnp.asarray(mask))
    bc = T.rollback_cache(cfg, bc, st, jnp.asarray(accepts),
                          jnp.asarray(lens_before))

    for i, (single, p, acc) in enumerate(zip(singles, prompts, accepts)):
        assert int(bc["lengths"][i]) == len(p) + acc
        assert int(single["length"]) == len(p) + acc
        pos_b = np.asarray(bc["pos"][i])
        pos_s = np.asarray(single["pos"][0])
        np.testing.assert_array_equal(pos_b, pos_s)
        valid = pos_s >= 0
        k_b = np.asarray(bc["k"][:, i])[:, valid]
        k_s = np.asarray(single["k"][:, 0])[:, valid]
        np.testing.assert_allclose(k_b, k_s, atol=3e-5)


# ===================================================================== #
# BatchedEngine(B=1) == legacy ServingEngine, bit for bit
# ===================================================================== #

@pytest.mark.parametrize("temperature", [0.0, 1.0])
@pytest.mark.parametrize("controller_factory", [
    lambda: StaticKController(3),
    lambda: CascadeController(),
])
def test_batched_b1_bit_identical_to_legacy(tiny_moe, temperature,
                                            controller_factory):
    cfg, params = tiny_moe
    prompt = [5, 6, 7, 8, 9] * 8
    leg = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                        temperature=temperature, clock="model", seed=7)
    bat = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                        max_len=512, temperature=temperature,
                        clock="model", seed=7)
    r1 = leg.generate(prompt, max_new=32, controller=controller_factory())
    r2 = bat.generate(prompt, max_new=32, controller=controller_factory())
    assert r1.tokens == r2.tokens
    assert len(r1.telemetry.iterations) == len(r2.telemetry.iterations)
    # same virtual clock, so Cascade saw identical attributed times
    assert r1.telemetry.decode_time == r2.telemetry.decode_time


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_joint_policy_b1_bit_identical_to_independent(tiny_moe, temperature):
    """The planner bypass at B=1: BatchedEngine(policy="joint") must emit a
    bit-identical token stream AND identical telemetry to the per-request
    controller path (policy="independent") on fixed seeds — the planner is
    invisible in the paper's single-batch regime."""
    cfg, params = tiny_moe
    prompt = [5, 6, 7, 8, 9] * 8

    def run(policy):
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=1, max_len=512,
                            temperature=temperature, clock="model",
                            seed=7, policy=policy)
        return eng.generate(prompt, max_new=32,
                            controller=CascadeController()), eng

    r_joint, e_joint = run("joint")
    r_ind, e_ind = run("independent")
    assert r_joint.tokens == r_ind.tokens
    assert r_joint.telemetry.decode_time == r_ind.telemetry.decode_time
    its_j, its_i = r_joint.telemetry.iterations, r_ind.telemetry.iterations
    assert len(its_j) == len(its_i)
    for a, b in zip(its_j, its_i):
        assert (a.k_requested, a.k_granted, a.k_drafted) == \
            (b.k_requested, b.k_granted, b.k_drafted)
        assert a.k_granted == a.k_requested      # bypass: grant == ask
        assert not a.plan_held
        assert (a.t_iter, a.t_draft, a.t_verify, a.t_sample) == \
            (b.t_iter, b.t_draft, b.t_verify, b.t_sample)
    # step telemetry identical too, planner fields included
    for sa, sb in zip(e_joint.telemetry.steps, e_ind.telemetry.steps):
        assert (sa.k_requested, sa.k_granted, sa.preempted,
                sa.held_tests) == (sb.k_requested, sb.k_granted,
                                   sb.preempted, sb.held_tests)
        assert sa.t_step == sb.t_step
        assert sa.t_step_predicted == sb.t_step_predicted
    # and both match the legacy single-request engine's stream
    leg = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                        temperature=temperature, clock="model", seed=7)
    assert r_joint.tokens == leg.generate(
        prompt, max_new=32, controller=CascadeController()).tokens


def test_engine_policy_planner_consistency(tiny_moe):
    """A supplied planner's config is the policy source of truth: an
    explicit contradicting `policy` argument raises instead of being
    silently ignored, and the engine's `policy` attribute reflects the
    planner actually in use."""
    from repro.core import BatchSpecPlanner, PlannerConfig
    cfg, params = tiny_moe
    pl = BatchSpecPlanner(cfg, config=PlannerConfig(policy="independent"))
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                      max_len=128, policy="joint", planner=pl)
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                        max_len=128, planner=pl)
    assert eng.policy == "independent"
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                      max_len=128, policy="bogus")


def test_legacy_scheduler_works_over_batched_engine(tiny_moe):
    """The legacy FIFO Scheduler is a thin wrapper over batch=1."""
    cfg, params = tiny_moe
    bat = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                        max_len=256, temperature=0.0, clock="model")
    sched = Scheduler(bat, controller_factory=lambda: StaticKController(2))
    res = sched.run([Request(request_id="a", prompt=[1, 2, 3] * 6,
                             max_new=12),
                     Request(request_id="b", prompt=[4, 5] * 8,
                             max_new=12)])
    assert len(res) == 2
    assert all(len(r.tokens) == 12 for r in res)
    assert sched.tokens_per_second() > 0


# ===================================================================== #
# Continuous batching end-to-end
# ===================================================================== #

def test_continuous_batching_drains_queue_in_order(tiny_moe):
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=2,
                        max_len=256, temperature=0.0, clock="model")
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: StaticKController(2))
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 4 + i] * 8,
                    max_new=8 + 4 * i) for i in range(5)]
    res = sched.run(reqs)
    assert [r.telemetry.request_id for r in res] == [q.request_id
                                                    for q in reqs]
    for r, q in zip(res, reqs):
        assert len(r.tokens) == q.max_new
    tel = eng.telemetry
    assert tel.steps, "engine recorded no steps"
    assert 1.0 <= tel.mean_occupancy <= 2.0
    assert all(s.occupancy <= 2 for s in tel.steps)
    # per-request iteration records carry the batch fields
    its = [it for r in res for it in r.telemetry.iterations]
    assert any(it.batch_occupancy == 2 for it in its)
    assert all(it.batch_occupancy in (1, 2) for it in its)
    if cfg.is_moe:
        assert any(it.union_experts > 0 for it in its)


def test_batched_outputs_match_sequential_greedy(tiny_moe):
    """Greedy decoding is lossless under batching: each request's token
    stream must equal its single-request stream regardless of who shares
    the verification pass."""
    cfg, params = tiny_moe
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 5 + i, 7 + i] * 6,
                    max_new=16) for i in range(3)]
    leg = ServingEngine(cfg, params, NGramDrafter(), max_len=256,
                        temperature=0.0, clock="model")
    ref = {q.request_id: leg.generate(
        q.prompt, q.max_new, controller=StaticKController(2)).tokens
        for q in reqs}
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=3,
                        max_len=256, temperature=0.0, clock="model")
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: StaticKController(2))
    for r in sched.run(reqs):
        assert r.tokens == ref[r.telemetry.request_id], r.telemetry.request_id


# ===================================================================== #
# Union-packed verification path (docs/kernels.md): bit-identity with
# the dense dispatch at the engine level
# ===================================================================== #

@pytest.mark.parametrize("b", [1, 4])
def test_packed_engine_streams_bit_identical_to_dense(tiny_moe, b):
    """BatchedEngine(packed=True) compacts each pass's expert union into
    `packed_expert_cap` slots but performs the same contractions in the
    same dtype — so every emitted token stream must equal the dense
    engine's bit for bit, at B=1 and under a shared B=4 pass."""
    cfg, params = tiny_moe
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 5 + i, 7 + i] * 6,
                    max_new=16) for i in range(max(b, 3))]

    def streams(packed):
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=b, max_len=256, temperature=0.0,
                            clock="model", seed=0, packed=packed)
        sched = ContinuousBatchingScheduler(
            eng, controller_factory=lambda: CascadeController())
        res = sched.run([Request(request_id=q.request_id,
                                 prompt=list(q.prompt),
                                 max_new=q.max_new) for q in reqs])
        return {r.telemetry.request_id: r.tokens for r in res}, eng

    dense, _ = streams(False)
    packed, eng = streams(True)
    assert dense == packed
    # the packed path actually engaged and reported its slot count
    from repro.models.moe import packed_expert_cap
    caps = [s.packed_experts for s in eng.telemetry.steps]
    assert all(c > 0 for c in caps)
    assert all(c <= cfg.num_experts for c in caps)
    dense_caps = [s.packed_experts for s in streams(False)[1].telemetry.steps]
    assert all(c == 0 for c in dense_caps)
