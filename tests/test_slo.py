"""SLO-constrained joint grants (docs/slo.md): the `RequestSLO` contract
and shared predicate, the constraint-pipeline water-filling's bit-identity
with the pre-pipeline implementation when no SLOs are set, the victim-
protection invariant (a granted allocation never pushes any co-scheduled
bounded request's predicted TPOT past max(bound, no-spec TPOT)), the
latency-weighted water level, tier-aware admission, the manager downclimb
regression, and the flag-gated per-position acceptance curve. Property-
based tests use hypothesis (or the in-repo fallback)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (BatchCostOracle, BatchSpecPlanner,
                        BreakEvenConstraint, CascadeConfig,
                        CascadeController, DraftYieldModel, Hardware,
                        IterationRecord, PlannerConfig, RequestSLO,
                        SLOTpotConstraint, SpeculationManager, TPU_V5E,
                        UtilityAnalyzer, expected_emitted,
                        expected_emitted_curve, greedy_allocate,
                        tpot_within)
from repro.core.manager import SET, TEST
from repro.core.slo import LATENCY, THROUGHPUT

CFG = get_config("mixtral-8x7b").reduced()

# the same four regimes the planner tests price across (test_planner.py)
HWS = [TPU_V5E,
       Hardware("slowmem", hbm_bw=1e9, peak_flops=197e12),
       Hardware("slowflops", hbm_bw=819e9, peak_flops=2e9),
       Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)]


# ===================================================================== #
# RequestSLO contract + the one shared predicate
# ===================================================================== #

def test_request_slo_contract():
    assert RequestSLO().tier == THROUGHPUT
    assert RequestSLO.latency(tpot=0.1).is_latency_tier
    with pytest.raises(ValueError):
        RequestSLO(tier="gold")
    with pytest.raises(ValueError):
        RequestSLO(tpot=0.0)
    with pytest.raises(ValueError):
        RequestSLO(ttft=-1.0)


def test_tpot_within_is_the_shared_predicate():
    # None bound / None estimate always pass; otherwise <= decides
    assert tpot_within(None, 5.0) and tpot_within(0.1, None)
    assert tpot_within(0.1, 0.1) and not tpot_within(0.1, 0.100001)


def test_manager_slo_allows_delegates_to_predicate():
    """The manager's measured trial gate and the planner's predicted grant
    constraint must share one comparison rule — same boundary behaviour
    (tpot == bound passes)."""
    mgr = SpeculationManager(cfg=CascadeConfig(slo_tpot=0.5))
    for _ in range(4):
        mgr.analyzer.observe(IterationRecord(k=0, tokens=1, t_iter=1.0))
    for _ in range(4):   # measured TPOT at K=2: exactly 0.5 s/token
        mgr.analyzer.observe(IterationRecord(k=2, tokens=2, t_iter=1.0))
    assert mgr._slo_allows(2)                  # boundary: == bound passes
    for _ in range(8):   # now 1.5/2 = 0.75 > bound
        mgr.analyzer.observe(IterationRecord(k=2, tokens=2, t_iter=1.5))
    assert not mgr._slo_allows(2)


# ===================================================================== #
# Satellite regression: the SLO downclimb must disable, not settle on a
# k_min that itself violates the bound
# ===================================================================== #

def _mgr_with_all_k_violating(k_min=1):
    """A manager whose measured TPOT violates the bound at EVERY K>0: each
    K emits 1 token in 1.0s (bound 0.5), so no downclimb target is legal."""
    mgr = SpeculationManager(cfg=CascadeConfig(slo_tpot=0.5, k_min=k_min))
    for _ in range(4):
        mgr.analyzer.observe(IterationRecord(k=0, tokens=1, t_iter=0.4))
    for k in range(1, mgr.cfg.k_max + 1):
        for _ in range(4):
            mgr.analyzer.observe(IterationRecord(k=k, tokens=1, t_iter=1.0))
    return mgr


def test_downclimb_returns_none_when_k_min_violates_slo():
    """Regression: `_next_trial_k`'s SLO downclimb used to bottom out AT
    k_min and return it even when k_min itself fails the bound — trialing
    a K the manager already measured as SLO-breaking. It must disable
    (None) instead."""
    mgr = _mgr_with_all_k_violating()
    mgr.phase = TEST
    mgr._trials = [(3, 1.2)]   # utility fine — only the SLO blocks
    mgr._trials_done = 1
    assert mgr._next_trial_k() is None
    # and the full FSM settles on K=0 (disabled), never trialing k_min
    mgr2 = _mgr_with_all_k_violating()
    mgr2.phase = TEST
    mgr2._k_now = 3
    mgr2._phase_left = 1
    mgr2._trials, mgr2._trials_done, mgr2._trial_records = [], 0, []
    mgr2.observe(IterationRecord(k=3, tokens=2, t_iter=1.0))
    assert mgr2.phase == SET and mgr2._k_now == 0


def test_downclimb_still_finds_a_legal_lower_k():
    """Non-regression: when some lower K satisfies the bound, the
    downclimb must still land on it (not over-disable)."""
    mgr = SpeculationManager(cfg=CascadeConfig(slo_tpot=0.5, k_min=1))
    for _ in range(4):
        mgr.analyzer.observe(IterationRecord(k=0, tokens=1, t_iter=0.4))
    for _ in range(4):       # K=1 fine: 0.45 s/token
        mgr.analyzer.observe(IterationRecord(k=1, tokens=2, t_iter=0.9))
    for k in (2, 3):         # K=2 and K=3 violate: 1.0 s/token
        for _ in range(4):
            mgr.analyzer.observe(IterationRecord(k=k, tokens=1, t_iter=1.0))
    mgr.phase = TEST
    # single improving trial at K=2 -> hill-climb proposes 3; the SLO
    # downclimb walks 3 -> 2 -> 1, and 1 is legal and untested
    mgr._trials = [(2, 1.2)]
    mgr._trials_done = 1
    nxt = mgr._next_trial_k()
    assert nxt == 1 and mgr._slo_allows(nxt)


# ===================================================================== #
# Tentpole: the constraint pipeline is the pre-pipeline water-filling,
# bit for bit, when no SLOs are set
# ===================================================================== #

def _reference_water_filling(oracle, base_ns, decode, caps, accepts, *,
                             fixed=frozenset(), util_floor=1.0):
    """VERBATIM pre-pipeline implementation (PR 4's greedy_allocate) — the
    reference the refactored pipeline must reproduce exactly."""
    ns = list(base_ns)
    alloc = {i: 0 for i in decode}
    t_base = oracle.t_batch(ns)
    r_floor = (util_floor * len(decode) / t_base) if decode else 0.0
    for i in fixed:
        alloc[i] = caps[i]
        ns[i] += caps[i]
    t_cur = oracle.t_batch(ns)
    while True:
        best, best_rate = None, 0.0
        for i in decode:
            if i in fixed or alloc[i] >= caps[i]:
                continue
            d_tok = accepts[i] ** (alloc[i] + 1)
            ns[i] += 1
            d_t = oracle.t_batch(ns) - t_cur
            ns[i] -= 1
            rate = (d_tok / d_t) if d_t > 0 else float("inf")
            if best is None or rate > best_rate:
                best, best_rate = i, rate
        if best is None or best_rate < r_floor:
            break
        alloc[best] += 1
        ns[best] += 1
        t_cur = oracle.t_batch(ns)
    return alloc, {"t_base": t_base, "t_alloc": t_cur, "r_floor": r_floor}


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 5), seed=st.integers(0, 10 ** 6),
       floor=st.floats(0.0, 2.0))
def test_pipeline_bit_identical_to_reference_without_slos(b, seed, floor):
    """The tentpole's degradation clause: with no SLO constraints the
    pipeline's allocation AND info floats equal the pre-pipeline loop
    exactly — grants, water level, priced times. Fixed (pinned-trial)
    rows included."""
    rng = np.random.default_rng(seed)
    hw = HWS[seed % len(HWS)]
    cls = [int(rng.integers(8, 400)) for _ in range(b)]
    caps = {i: int(rng.integers(0, 6)) for i in range(b)}
    accepts = {i: float(rng.uniform(0.0, 0.99)) for i in range(b)}
    decode = list(range(b))
    fixed = frozenset(i for i in decode
                      if caps[i] > 0 and rng.integers(4) == 0)
    oracle = BatchCostOracle(CFG, hw, cls,
                             affinity=float(rng.choice([0.0, 0.3, 0.9])))
    ref_alloc, ref_info = _reference_water_filling(
        oracle, [1] * b, decode, caps, accepts, fixed=fixed,
        util_floor=floor)
    alloc, info = greedy_allocate(oracle, [1] * b, decode, caps, accepts,
                                  fixed=fixed, util_floor=floor)
    assert alloc == ref_alloc
    for key in ("t_base", "t_alloc", "r_floor"):
        assert info[key] == ref_info[key], key
    assert info["denied"].get("slo_tpot", set()) == set()


@settings(max_examples=40, deadline=None)
@given(b=st.integers(2, 5), seed=st.integers(0, 10 ** 6))
def test_unbounded_slo_constraint_changes_nothing(b, seed):
    """An SLOTpotConstraint with no bounds (every request unbounded) must
    be provably inert: identical allocation to the default pipeline."""
    rng = np.random.default_rng(seed)
    hw = HWS[seed % len(HWS)]
    cls = [int(rng.integers(8, 400)) for _ in range(b)]
    caps = {i: int(rng.integers(0, 6)) for i in range(b)}
    accepts = {i: float(rng.uniform(0.0, 0.99)) for i in range(b)}
    oracle = BatchCostOracle(CFG, hw, cls, affinity=0.3)
    a1, _ = greedy_allocate(oracle, [1] * b, list(range(b)), caps, accepts)
    a2, _ = greedy_allocate(
        oracle, [1] * b, list(range(b)), caps, accepts,
        constraints=[BreakEvenConstraint(), SLOTpotConstraint(bounds={})])
    assert a1 == a2


# ===================================================================== #
# Victim protection: the property the SLO constraint guarantees
# ===================================================================== #

def _predicted_tpots(oracle, base_ns, decode, alloc, accepts):
    ns = list(base_ns)
    for i in decode:
        ns[i] += alloc[i]
    emitted = [expected_emitted(accepts[i], alloc[i]) if i in alloc else 0.0
               for i in range(len(base_ns))]
    return oracle.predicted_tpot(ns, emitted)


@settings(max_examples=60, deadline=None)
@given(b=st.integers(2, 5), seed=st.integers(0, 10 ** 6),
       slack=st.floats(1.0, 2.0))
def test_granted_allocation_never_breaks_a_feasible_bound(b, seed, slack):
    """Property (the ISSUE's test b): after water-filling under
    SLOTpotConstraint, NO bounded request's predicted TPOT exceeds
    max(bound, its no-speculation TPOT) — co-scheduled victims included,
    whoever the grants went to. Bounds are sampled around the no-spec
    pass so some bind and some don't."""
    rng = np.random.default_rng(seed)
    hw = HWS[seed % len(HWS)]
    cls = [int(rng.integers(8, 400)) for _ in range(b)]
    caps = {i: int(rng.integers(0, 6)) for i in range(b)}
    accepts = {i: float(rng.uniform(0.0, 0.99)) for i in range(b)}
    decode = list(range(b))
    oracle = BatchCostOracle(CFG, hw, cls, affinity=0.3)
    base_ns = [1] * b
    t_zero = oracle.t_batch(base_ns)
    base_tpot = _predicted_tpots(oracle, base_ns, decode,
                                 {i: 0 for i in decode}, accepts)
    bounds = {i: float(t_zero * rng.uniform(0.8, slack)) for i in decode
              if rng.integers(2)}
    alloc, _ = greedy_allocate(
        oracle, base_ns, decode, caps, accepts,
        constraints=[BreakEvenConstraint(),
                     SLOTpotConstraint(bounds=bounds)])
    tpots = _predicted_tpots(oracle, base_ns, decode, alloc, accepts)
    for j, bound in bounds.items():
        assert tpots[j] <= max(bound, base_tpot[j]) + 1e-12, (
            j, tpots[j], bound, base_tpot[j], alloc)


def test_slo_denies_victim_harming_grants_not_just_grantee():
    """The motivating scenario: a bounded latency request co-scheduled
    with eager throughput requests. Unconstrained water-filling grants
    push the pass past the victim's bound; the SLO pipeline denies those
    grants even though the victim itself asked for nothing."""
    hw = Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)
    oracle = BatchCostOracle(CFG, hw, [128] * 4, affinity=0.0)
    decode = [0, 1, 2, 3]
    caps = {0: 0, 1: 6, 2: 6, 3: 6}       # row 0: the quiet victim
    accepts = {0: 0.5, 1: 0.9, 2: 0.9, 3: 0.9}
    base_ns = [1] * 4
    free, _ = greedy_allocate(oracle, base_ns, decode, caps, accepts)
    assert sum(free.values()) > 0          # speculation is worth it here
    t_zero = oracle.t_batch(base_ns)
    t_free = oracle.t_batch([1 + free.get(i, 0) for i in range(4)])
    assert t_free > t_zero                 # ...and it lengthens the pass
    # bound the victim between the no-spec pass and the free-for-all pass
    bound = 0.5 * (t_zero + t_free)
    con = SLOTpotConstraint(bounds={0: bound})
    capped, info = greedy_allocate(
        oracle, base_ns, decode, caps, accepts,
        constraints=[BreakEvenConstraint(), con])
    t_capped = oracle.t_batch([1 + capped.get(i, 0) for i in range(4)])
    assert t_capped <= bound + 1e-12       # victim's TPOT = pass / 1
    assert sum(capped.values()) < sum(free.values())
    denied = info["denied"].get("slo_tpot", set())
    assert denied and 0 not in denied      # others were denied, not row 0


def test_infeasible_bound_denies_harm_without_deadlock():
    """A bound below even the no-speculation pass cannot be met. The
    escape clause then still permits the bounded row's OWN TPOT-improving
    speculation (Theorem 4.2: its tokens-per-pass rise faster than the
    pass lengthens) while denying the co-scheduled row's grants, which
    only worsen the victim — and the loop terminates rather than
    deadlocking on the unsatisfiable bound."""
    hw = HWS[1]
    oracle = BatchCostOracle(CFG, hw, [128, 128], affinity=0.0)
    alloc, info = greedy_allocate(
        oracle, [1, 1], [0, 1], {0: 4, 1: 4}, {0: 0.9, 1: 0.9},
        constraints=[BreakEvenConstraint(),
                     SLOTpotConstraint(bounds={0: 1e-12})])
    assert alloc[1] == 0                      # the co-scheduled harm
    assert 1 in info["denied"]["slo_tpot"]
    # the victim's own grants never worsened it past its no-spec TPOT
    tpots = _predicted_tpots(oracle, [1, 1], [0, 1], alloc,
                             {0: 0.9, 1: 0.9})
    base = _predicted_tpots(oracle, [1, 1], [0, 1], {0: 0, 1: 0},
                            {0: 0.9, 1: 0.9})
    assert tpots[0] <= base[0] + 1e-12


def test_pinned_trial_demoted_when_probe_breaks_a_bound():
    """SLO beats trial fidelity: a staggered TEST probe whose pinned K
    would push a co-scheduled bounded request past its bound is demoted
    to an ordinary water-filled candidate."""
    hw = Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)
    oracle = BatchCostOracle(CFG, hw, [128, 128], affinity=0.0)
    t_zero = oracle.t_batch([1, 1])
    t_pinned = oracle.t_batch([1 + 6, 1])
    bound = 0.5 * (t_zero + t_pinned)      # pinned probe breaks it
    alloc, info = greedy_allocate(
        oracle, [1, 1], [0, 1], {0: 6, 1: 0}, {0: 0.1, 1: 0.5},
        fixed=frozenset([0]),
        constraints=[BreakEvenConstraint(),
                     SLOTpotConstraint(bounds={1: bound})])
    assert alloc[0] < 6                    # probe no longer runs in full
    assert 0 in info["denied"]["pinned"]
    assert oracle.t_batch([1 + alloc[0], 1]) <= bound + 1e-12
    # without the bound the same pin runs unmodified
    free, _ = greedy_allocate(oracle, [1, 1], [0, 1], {0: 6, 1: 0},
                              {0: 0.1, 1: 0.5}, fixed=frozenset([0]))
    assert free[0] == 6


def test_latency_weighted_water_level_grants_no_more():
    """Mixed-tier traffic raises the water level: with a latency-tier row
    weighted above 1, total grants never exceed the unweighted pipeline's
    (same caps, same acceptance), and the weighted floor is higher."""
    hw = Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)
    oracle = BatchCostOracle(CFG, hw, [128] * 4, affinity=0.0)
    caps = {i: 4 for i in range(4)}
    accepts = {i: 0.7 for i in range(4)}
    plain, pi = greedy_allocate(oracle, [1] * 4, list(range(4)), caps,
                                accepts)
    weighted, wi = greedy_allocate(
        oracle, [1] * 4, list(range(4)), caps, accepts,
        constraints=[BreakEvenConstraint(weights={0: 4.0})])
    assert wi["r_floor"] > pi["r_floor"]
    assert sum(weighted.values()) <= sum(plain.values())


def test_oracle_predicted_tpot_semantics():
    """predicted_tpot = whole pass / per-request expected emissions; rows
    with nothing to emit report inf; granting ANY row lengthens every
    row's predicted TPOT (the victim effect the attribution split cannot
    show)."""
    oracle = BatchCostOracle(CFG, HWS[1], [128, 128], affinity=0.0)
    before = oracle.predicted_tpot([1, 1], [1.0, 1.0])
    assert before[0] == before[1] == oracle.t_batch([1, 1])
    after = oracle.predicted_tpot([4, 1], [expected_emitted(0.8, 3), 1.0])
    assert after[1] > before[1]            # victim pays for row 0's grant
    assert oracle.predicted_tpot([1, 1], [1.0, 0.0])[1] == float("inf")


# ===================================================================== #
# Planner + engine plumbing
# ===================================================================== #

def _drive_to_test(mgr):
    while mgr.phase != TEST:
        k = mgr.next_k()
        mgr.observe(IterationRecord(k=k, tokens=max(1, k), t_iter=1.0))


def test_planner_plan_applies_slo_bounds():
    """plan(slos=...) wires bounds into the pipeline: an infeasibly
    bounded QUIET row (asking nothing itself) forces every co-scheduled
    grant — pinned TEST probes included — to be denied and reported as
    slo_denied; the same batch unbounded grants freely."""
    hw = Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)
    planner = BatchSpecPlanner(CFG, hw,
                               config=PlannerConfig(stagger_tests=False))

    def controllers():
        out = {0: CascadeController()}     # BASELINE: asks 0 (the victim)
        for i in (1, 2):
            c = CascadeController()
            _drive_to_test(c.manager)
            for _ in range(8):   # high-acceptance history
                c.manager.analyzer.observe(
                    IterationRecord(k=3, tokens=4, t_iter=1e-3))
            out[i] = c
        return out

    free = planner.plan(controllers(), [64, 64, 64])
    assert free.granted_total > 0 and free.slo_denied == 0
    tight = planner.plan(controllers(), [64, 64, 64],
                         slos={0: RequestSLO(tpot=1e-12)})
    assert tight.granted_total == 0
    assert tight.slo_denied > 0
    assert any(d.slo_capped for d in tight.decisions.values())


@pytest.mark.parametrize("batch", [1, 4])
def test_engine_no_slo_bit_identical_to_unbounded_slo(tiny_moe, batch):
    """Acceptance property (ISSUE test a): with no binding SLOs the whole
    serving stack — token streams, per-request iteration telemetry, and
    step telemetry, dataclass equality — is bit-identical whether the SLO
    machinery is absent (slo=None) or engaged but unbounded
    (RequestSLO() on every request), at B=1 and B=4."""
    from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                               NGramDrafter, Request)
    cfg, params = tiny_moe

    def run(slo):
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=batch, max_len=256, temperature=0.0,
                            clock="model", seed=0)
        sched = ContinuousBatchingScheduler(
            eng, controller_factory=lambda: CascadeController())
        reqs = [Request(request_id=f"r{i}",
                        prompt=[3 + i, 4 + i, 5 + i] * 6,
                        max_new=10 + 2 * i, slo=slo) for i in range(5)]
        return sched.run(reqs), eng

    r_none, e_none = run(None)
    r_un, e_un = run(RequestSLO())
    assert [r.tokens for r in r_none] == [r.tokens for r in r_un]
    assert len(e_none.telemetry.steps) == len(e_un.telemetry.steps)
    for a, b in zip(e_none.telemetry.steps, e_un.telemetry.steps):
        assert a == b            # every field, slo_denied == 0 included
    for ra, rb in zip(r_none, r_un):
        assert ra.telemetry.iterations == rb.telemetry.iterations
        assert ra.telemetry.ttft == rb.telemetry.ttft


def test_latency_tier_jumps_admission_queue(tiny_moe):
    """Tier-aware admission: with the slot table full, a latency-tier
    request submitted BEHIND throughput requests is admitted first when a
    slot frees (FIFO within tiers; plain FIFO without latency traffic)."""
    from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                               NGramDrafter, Request)
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                        max_len=256, temperature=0.0, clock="model", seed=0)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController())
    reqs = [Request(request_id="t0", prompt=[3, 4, 5] * 4, max_new=6),
            Request(request_id="t1", prompt=[4, 5, 6] * 4, max_new=6),
            Request(request_id="lat", prompt=[5, 6, 7] * 4, max_new=6,
                    slo=RequestSLO.latency(tpot=10.0)),
            Request(request_id="t2", prompt=[6, 7, 8] * 4, max_new=6)]
    res = sched.run(reqs)
    tel = {r.telemetry.request_id: r.telemetry for r in res}
    # the latency request waited less than the earlier-submitted t1
    assert tel["lat"].t_queue < tel["t1"].t_queue
    assert tel["lat"].tier == LATENCY
    stats = sched.tier_stats()
    assert stats[LATENCY]["n"] == 1 and stats[THROUGHPUT]["n"] == 3
    assert stats[LATENCY]["tpot_violations"] == 0
    assert sched.slo_violations() == 0


def test_engine_propagates_slo_tpot_to_cascade_config(tiny_moe):
    from repro.serving import BatchedEngine, NGramDrafter
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=2,
                        max_len=256, temperature=0.0, clock="model")
    idx = eng.join([3, 4, 5] * 4, 4, slo=RequestSLO.latency(tpot=0.25))
    s = eng.slots[idx]
    assert s.controller.config.slo_tpot == 0.25
    assert s.tel.tier == LATENCY and s.tel.slo_tpot == 0.25
    # an explicit CascadeConfig bound wins over the request's
    own = CascadeController(CascadeConfig(slo_tpot=0.5))
    idx2 = eng.join([4, 5, 6] * 4, 4, controller=own,
                    slo=RequestSLO.latency(tpot=0.25))
    assert eng.slots[idx2].controller.config.slo_tpot == 0.5
    # the caller's config object is never mutated: a factory handing ONE
    # shared tuned config to every controller must not have request A's
    # bound leak into request B's FSM (regression)
    shared = CascadeConfig()
    eng.retire(idx)
    idx3 = eng.join([5, 6, 7] * 4, 4,
                    controller=CascadeController(shared),
                    slo=RequestSLO.latency(tpot=0.125))
    s3 = eng.slots[idx3]
    assert shared.slo_tpot is None                  # untouched
    assert s3.controller.config.slo_tpot == 0.125
    assert s3.controller.manager.cfg.slo_tpot == 0.125  # FSM sees it too


def test_mixed_tier_serving_meets_bound_end_to_end(tiny_moe):
    """End-to-end on the crossover regime: unconstrained joint planning
    pushes a quiet latency request past a feasible TPOT bound; with the
    bound attached, every latency request meets it and the planner
    reports the denials."""
    from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                               NGramDrafter, Request)
    cfg, params = tiny_moe
    # deeper past the roofline than the sweep regime: the reduced model's
    # trial-phase spans must add real compute time for the bound to bind
    hw = Hardware("crossover-deep", hbm_bw=1e9, peak_flops=1.5e9)

    def run(bound):
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=4, max_len=256, temperature=0.0,
                            clock="model", seed=0, hw=hw)
        sched = ContinuousBatchingScheduler(
            eng, controller_factory=lambda: CascadeController())
        reqs = []
        for i in range(4):
            # latency tier on even rows in BOTH runs (so the comparison
            # differs only in the bound, not in tiering/weights)
            slo = (RequestSLO.latency(tpot=bound) if i % 2 == 0 else None)
            reqs.append(Request(request_id=f"r{i}",
                                prompt=[3 + i, 4 + i, 5 + i] * 6,
                                max_new=16, slo=slo))
        res = sched.run(reqs)
        lat = [r.telemetry.experienced_tpot for r in res
               if r.telemetry.tier == LATENCY]
        return lat, sched

    free_tpots, _ = run(None)
    # feasible-but-binding bound: between the zero-spec pass and what the
    # unconstrained run actually inflicted on the latency rows
    t_zero = BatchCostOracle(cfg, hw, [20] * 4).t_batch([1] * 4)
    worst = max(free_tpots)
    if worst <= t_zero * 1.05:
        pytest.skip("regime did not inflate the pass enough to bind")
    bound = 0.5 * (t_zero + worst)
    tpots, sched = run(bound)
    assert all(t <= bound * 1.05 for t in tpots), (tpots, bound)
    assert sched.planner_stats()["slo_denied"] > 0


# ===================================================================== #
# Acceptance-model upgrade: per-position curve (flag-gated)
# ===================================================================== #

def test_accept_curve_estimates_per_position():
    an = UtilityAnalyzer(window=16)
    assert an.accept_curve(4) is None      # no speculative history
    # records (k=3): tokens=4 -> all 3 accepted; tokens=2 -> pos0 ok,
    # pos1 rejected, pos2 unreached; tokens=1 -> pos0 rejected
    for tokens in (4, 2, 1):
        an.observe(IterationRecord(k=3, tokens=tokens, t_iter=1.0))
    curve = an.accept_curve(4)
    assert curve[0] == pytest.approx(2 / 3)   # reached 3x, accepted 2x
    assert curve[1] == pytest.approx(1 / 2)   # reached 2x, accepted 1x
    assert curve[2] == pytest.approx(0.999)   # reached once, accepted (cap)
    # position 3 never drafted -> falls back to the flat rate
    assert curve[3] == an.accept_rate()
    assert all(c <= 0.999 for c in curve)


def test_accept_curve_catches_depth_decay():
    """A depth-decaying history yields a decaying curve: the flat mean
    under-prices shallow drafts and over-prices deep ones, which is
    exactly the bias the curve-gated yield model removes."""
    an = UtilityAnalyzer(window=64)
    rng = np.random.default_rng(0)
    for _ in range(48):
        # position p accepted w.p. 0.9 - 0.25p: deep drafts mostly die
        tokens = 1
        for p in range(4):
            if rng.random() < 0.9 - 0.25 * p:
                tokens += 1
            else:
                break
        an.observe(IterationRecord(k=4, tokens=tokens, t_iter=1.0))
    curve = an.accept_curve(4, 64)
    flat = an.accept_rate(64)
    assert curve[0] > flat > curve[3]      # decay straddles the mean
    ym_flat = DraftYieldModel({0: flat})
    ym_curve = DraftYieldModel({0: flat}, {0: curve})
    # the first draft is worth more than the flat mean says...
    assert ym_curve.marginal(0, 0) > ym_flat.marginal(0, 0)
    # ...and emitted matches the generalized series
    assert ym_curve.emitted(0, 4) == pytest.approx(
        expected_emitted_curve(curve, 4))


def test_expected_emitted_curve_degrades_to_flat():
    for a in (0.0, 0.3, 0.8):
        for k in range(5):
            assert expected_emitted_curve([a] * k, k) == pytest.approx(
                expected_emitted(a, k), rel=1e-9)
    assert expected_emitted_curve([], 3) == 1.0  # empty curve: no yield


def test_use_accept_curve_flag_gated_b1_tokens_identical(tiny_moe):
    """Flag on, B=1: the bypass keeps the token stream identical to the
    flat path (grants == asks either way); default off is the bit-identity
    baseline the pipeline tests pin."""
    from repro.serving import BatchedEngine, NGramDrafter
    cfg, params = tiny_moe
    assert PlannerConfig().use_accept_curve is False

    def run(flag):
        planner = BatchSpecPlanner(
            cfg, config=PlannerConfig(use_accept_curve=flag))
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=1, max_len=256, temperature=0.0,
                            clock="model", seed=0, planner=planner)
        return eng.generate([5, 6, 7, 8] * 6, max_new=24,
                            controller=CascadeController())

    assert run(True).tokens == run(False).tokens
