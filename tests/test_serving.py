"""Serving substrate: rejection-sampler exactness, n-gram drafter, and the
key end-to-end invariant — greedy speculative output == greedy plain
output, token for token, regardless of K policy."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

import jax

from repro.core import CascadeController, StaticKController
from repro.models import transformer as T
from repro.serving import NGramDrafter, ServingEngine
from repro.serving.drafter import DraftModelDrafter
from repro.serving.sampler import greedy_verify, rejection_sample


# ===================================================================== #
# Rejection sampler
# ===================================================================== #

def test_rejection_preserves_target_distribution_point_drafts():
    """With a deterministic (n-gram) drafter, the emitted first token must
    be distributed exactly as the target distribution."""
    rng = np.random.default_rng(0)
    v = 5
    p = np.array([0.5, 0.2, 0.15, 0.1, 0.05])
    draft_tok = 0
    counts = np.zeros(v)
    n = 40_000
    for _ in range(n):
        res = rejection_sample(rng, np.stack([p, p]), [draft_tok], None)
        tok = res.accepted[0] if res.n_accepted else res.next_token
        counts[tok] += 1
    emp = counts / n
    np.testing.assert_allclose(emp, p, atol=0.01)


def test_rejection_preserves_target_distribution_stochastic_drafts():
    """Leviathan guarantee with a stochastic drafter q != p."""
    rng = np.random.default_rng(1)
    p = np.array([0.6, 0.3, 0.1])
    q = np.array([0.2, 0.3, 0.5])
    counts = np.zeros(3)
    n = 40_000
    for _ in range(n):
        d = int(rng.choice(3, p=q))
        res = rejection_sample(rng, np.stack([p, p]), [d], np.stack([q]))
        tok = res.accepted[0] if res.n_accepted else res.next_token
        counts[tok] += 1
    np.testing.assert_allclose(counts / n, p, atol=0.01)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=5),
       st.integers(0, 2**31 - 1))
def test_rejection_accepted_is_prefix(drafts, seed):
    rng = np.random.default_rng(seed)
    k = len(drafts)
    p = rng.dirichlet(np.ones(8), size=k + 1)
    res = rejection_sample(rng, p, drafts, None)
    assert res.accepted == drafts[:res.n_accepted]
    assert 0 <= res.n_accepted <= k
    assert 0 <= res.next_token < 8


def test_greedy_verify_matches_argmax():
    logits = np.array([[0, 3, 1], [5, 0, 0], [0, 0, 9], [1, 2, 0]],
                      np.float32)
    res = greedy_verify(logits, [1, 0, 0])
    assert res.accepted == [1, 0]
    assert res.next_token == 2  # argmax of row 2 (first mismatch position)
    res2 = greedy_verify(logits, [1, 0, 2])
    assert res2.n_accepted == 3 and res2.next_token == 1  # bonus row


# ===================================================================== #
# N-gram drafter
# ===================================================================== #

def test_ngram_drafter_finds_repetition():
    d = NGramDrafter(max_ngram=3)
    hist = [1, 2, 3, 4, 5, 1, 2, 3]
    drafts, probs = d.propose(hist, 3)
    assert drafts == [4, 5, 1]
    assert probs is None


def test_ngram_drafter_prefers_longest_match():
    d = NGramDrafter(max_ngram=3)
    hist = [9, 2, 3, 7, 7, 7, 1, 2, 3, 5, 5, 1, 2, 3]
    drafts, _ = d.propose(hist, 2)
    assert drafts == [5, 5]  # trigram [1,2,3] match beats bigram/unigram


def test_ngram_drafter_no_match():
    d = NGramDrafter()
    drafts, _ = d.propose([1, 2, 3, 4, 5], 4)
    assert drafts == [] or len(drafts) <= 4  # unigram fallback allowed
    drafts, _ = d.propose([1], 4)
    assert drafts == []


# ===================================================================== #
# End-to-end greedy equivalence (speculation must be lossless)
# ===================================================================== #

@pytest.mark.parametrize("controller_factory", [
    lambda: StaticKController(3),
    lambda: CascadeController(),
])
def test_speculative_greedy_equals_plain_greedy(tiny_moe, controller_factory):
    cfg, params = tiny_moe
    prompt = [5, 6, 7, 8, 9] * 6
    eng = ServingEngine(cfg, params, NGramDrafter(), max_len=256,
                        temperature=0.0, clock="model", seed=0)
    ref = eng.generate(prompt, max_new=24, controller=StaticKController(0))
    out = eng.generate(prompt, max_new=24, controller=controller_factory())
    assert out.tokens == ref.tokens


def test_draft_model_drafter_end_to_end(tiny_moe):
    cfg, params = tiny_moe
    # the target itself as (perfect) drafter: every draft must be accepted
    drafter = DraftModelDrafter(cfg, params, max_len=256, temperature=0.0)
    eng = ServingEngine(cfg, params, drafter, max_len=256,
                        temperature=0.0, clock="model", seed=0)
    prompt = list(range(3, 23))
    ref = eng.generate(prompt, max_new=16, controller=StaticKController(0))
    out = eng.generate(prompt, max_new=16, controller=StaticKController(4))
    assert out.tokens == ref.tokens
    etr = out.telemetry.etr
    assert etr > 3.0, f"perfect drafter should accept ~all drafts, etr={etr}"


def test_engine_telemetry_breakdown(tiny_moe):
    cfg, params = tiny_moe
    eng = ServingEngine(cfg, params, NGramDrafter(), max_len=256,
                        temperature=0.0, clock="model")
    res = eng.generate([1, 2, 3] * 8, max_new=12,
                       controller=StaticKController(2))
    tel = res.telemetry
    assert tel.output_tokens >= 12 - 1
    bd = tel.breakdown()
    assert bd["verify"] > 0 and bd["total"] >= bd["verify"]
    assert all(i.unique_experts >= cfg.experts_per_token
               for i in tel.iterations)
