"""Distribution layer: sharding rules (divisibility fallbacks), the HLO
trip-aware analyzer, and a real (subprocess) dry-run on the production
mesh for one arch x shape."""

import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh44():
    # 4 "devices" arranged logically; on 1 real device jax.make_mesh fails,
    # and an abstract mesh needs no devices at all. make_abstract_mesh
    # absorbs the AbstractMesh constructor change across jax versions.
    from repro.launch.mesh import make_abstract_mesh
    return make_abstract_mesh((4, 4), ("data", "model"))


def test_param_rules_divisibility_fallback(mesh44):
    cfg = get_config("whisper-large-v3").reduced()
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = sh.param_shardings(cfg, shapes, mesh44)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, ns in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        leaf = shapes
        for p in path:
            leaf = leaf[getattr(p, "key", getattr(p, "idx", None))]
        # every sharded dim must divide evenly
        for dim, ax in zip(leaf.shape, ns.spec):
            if ax is None:
                continue
            size = 4 if isinstance(ax, str) else 16
            assert dim % size == 0, (keys, leaf.shape, ns.spec)


def test_expert_weights_2d_sharded(mesh44):
    cfg = get_config("mixtral-8x7b")
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = sh.param_shardings(cfg, shapes, mesh44)
    wg = specs["blocks"]["moe"]["w_gate"].spec
    assert wg == P(None, "data", None, "model")  # [L, E, d, F]
    wd = specs["blocks"]["moe"]["w_down"].spec
    assert wd == P(None, "data", "model", None)  # [L, E, F, d]
    emb = specs["embed"]["embedding"].spec
    assert emb == P("model", None)


def test_cache_sharding_context_parallel_batch1(mesh44):
    cfg = get_config("stablelm-1.6b")
    cache_shapes = jax.eval_shape(lambda: T.init_cache(cfg, 1, 8192))
    specs = sh.cache_shardings(cfg, cache_shapes, mesh44, batch=1)
    # batch=1: sequence dim must carry 'data' (context parallelism)
    assert specs["k"].spec == P(None, None, "data", "model", None)
    specs_b = sh.cache_shardings(cfg, jax.eval_shape(
        lambda: T.init_cache(cfg, 8, 8192)), mesh44, batch=8)
    assert specs_b["k"].spec[1] in ("data", ("pod", "data"))


def test_hlo_trip_aware_analyzer():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jnp.zeros((128, 128), jnp.bfloat16)
    ws = jnp.zeros((6, 128, 128), jnp.bfloat16)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(6 * 2 * 128 ** 3, rel=0.01)


@pytest.mark.slow
def test_dryrun_subprocess_production_mesh(tmp_path):
    """Real 16x16-mesh lower+compile for one (arch, shape) in a fresh
    process (the XLA device-count flag must precede jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "olmoe-1b-7b" if False else "stablelm-1.6b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "1/1 combinations compiled" in out.stdout, out.stdout + out.stderr
    rec = json.load(open(os.path.join(
        tmp_path, "stablelm-1.6b_decode_32k_16x16.json")))
    assert rec["ok"] and rec["devices"] == 256
    assert rec["trip_aware"]["flops_per_device"] > 0
