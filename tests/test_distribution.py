"""Distribution layer: sharding rules (divisibility fallbacks), the HLO
trip-aware analyzer, and a real (subprocess) dry-run on the production
mesh for one arch x shape."""

import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh44():
    # 4 "devices" arranged logically; on 1 real device jax.make_mesh fails,
    # and an abstract mesh needs no devices at all. make_abstract_mesh
    # absorbs the AbstractMesh constructor change across jax versions.
    from repro.launch.mesh import make_abstract_mesh
    return make_abstract_mesh((4, 4), ("data", "model"))


def test_param_rules_divisibility_fallback(mesh44):
    cfg = get_config("whisper-large-v3").reduced()
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = sh.param_shardings(cfg, shapes, mesh44)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, ns in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        leaf = shapes
        for p in path:
            leaf = leaf[getattr(p, "key", getattr(p, "idx", None))]
        # every sharded dim must divide evenly
        for dim, ax in zip(leaf.shape, ns.spec):
            if ax is None:
                continue
            size = 4 if isinstance(ax, str) else 16
            assert dim % size == 0, (keys, leaf.shape, ns.spec)


def test_expert_weights_2d_sharded(mesh44):
    cfg = get_config("mixtral-8x7b")
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = sh.param_shardings(cfg, shapes, mesh44)
    wg = specs["blocks"]["moe"]["w_gate"].spec
    assert wg == P(None, "data", None, "model")  # [L, E, d, F]
    wd = specs["blocks"]["moe"]["w_down"].spec
    assert wd == P(None, "data", "model", None)  # [L, E, F, d]
    emb = specs["embed"]["embedding"].spec
    assert emb == P("model", None)


def test_cache_sharding_context_parallel_batch1(mesh44):
    cfg = get_config("stablelm-1.6b")
    cache_shapes = jax.eval_shape(lambda: T.init_cache(cfg, 1, 8192))
    specs = sh.cache_shardings(cfg, cache_shapes, mesh44, batch=1)
    # batch=1: sequence dim must carry 'data' (context parallelism)
    assert specs["k"].spec == P(None, None, "data", "model", None)
    specs_b = sh.cache_shardings(cfg, jax.eval_shape(
        lambda: T.init_cache(cfg, 8, 8192)), mesh44, batch=8)
    assert specs_b["k"].spec[1] in ("data", ("pod", "data"))


def test_hlo_trip_aware_analyzer():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jnp.zeros((128, 128), jnp.bfloat16)
    ws = jnp.zeros((6, 128, 128), jnp.bfloat16)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    r = analyze_hlo(txt)
    assert r["flops"] == pytest.approx(6 * 2 * 128 ** 3, rel=0.01)


_EP_PARITY_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config
from repro.distributed.expert_parallel import make_expert_parallel_moe
from repro.models import moe as moe_mod
from repro.models import transformer as T

assert jax.device_count() == 4, jax.devices()
cfg = get_config("mixtral-8x7b").reduced()          # 4 experts, top-2
mesh = Mesh(np.array(jax.devices()).reshape(4, 1), ("data", "model"))

params = T.init_params(cfg, jax.random.PRNGKey(0))
p = jax.tree.map(lambda x: x[0], params["blocks"]["moe"])   # layer 0
t, d = 32, cfg.d_model
x2d = jax.random.normal(jax.random.PRNGKey(7), (t, d), jnp.float32)

# reference: the dense scatter/gather path at exact capacity (no drops)
y_ref, aux_ref = moe_mod.apply_moe(cfg, p, x2d, capacity_policy="exact")
assert int(aux_ref["dropped"]) == 0

# EP path at the default capacity factor: c_src = T_loc*k*cf // E + 1 = 9
# >= T_loc = 8, so no (source, expert) bucket can overflow -> exact parity
apply_ep = make_expert_parallel_moe(cfg, mesh, capacity_factor=2.0)
y_ep, aux_ep = apply_ep(p, x2d)
np.testing.assert_array_equal(np.asarray(aux_ep["expert_idx"]),
                              np.asarray(aux_ref["expert_idx"]))
assert int(np.sum(np.asarray(aux_ep["dropped"]))) == 0
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           atol=3e-5, rtol=1e-5)
# lb_loss is pmean-of-local-losses under EP (each device balances its own
# token shard) — an intentional approximation of the full-batch loss
np.testing.assert_allclose(float(aux_ep["lb_loss"]),
                           float(aux_ref["lb_loss"]), rtol=0.05)
# per-source-shard activated counts match the routing decision
idx = np.asarray(aux_ref["expert_idx"])             # [T, k]
src_counts = [len(np.unique(idx[s * 8:(s + 1) * 8])) for s in range(4)]
np.testing.assert_array_equal(np.asarray(aux_ep["unique_experts"]),
                              src_counts)

# the apply_moe wrapper (opt "ep-a2a" + context mesh): the union must be
# the dense path's distinct count, NOT the sum of per-source counts, and
# the raw per-source view stays visible under its own key
from repro.distributed import sharding as sh
sh.set_options(["ep-a2a"], mesh)
try:
    y_wrap, aux_wrap = moe_mod.apply_moe(cfg, p, x2d,
                                         capacity_policy="serve")
finally:
    sh.set_options([], None)
np.testing.assert_allclose(np.asarray(y_wrap), np.asarray(y_ep),
                           atol=3e-5, rtol=1e-5)
assert int(aux_wrap["unique_experts"]) == int(aux_ref["unique_experts"])
np.testing.assert_array_equal(np.asarray(aux_wrap["unique_experts_src"]),
                              src_counts)
assert int(aux_wrap["dropped"]) == 0

# forced-drop case: c_src = 1 -> every (source shard, expert) bucket keeps
# one (token, choice); the dropped counter must account for the overflow
# exactly, computed independently from the routing decision
apply_tiny = make_expert_parallel_moe(cfg, mesh, capacity_factor=1e-6)
y_tiny, aux_tiny = apply_tiny(p, x2d)
expected_drops = 0
for s in range(4):
    vals, counts = np.unique(idx[s * 8:(s + 1) * 8], return_counts=True)
    expected_drops += int(np.sum(np.maximum(counts - 1, 0)))
assert expected_drops > 0
assert int(np.sum(np.asarray(aux_tiny["dropped"]))) == expected_drops
assert np.all(np.isfinite(np.asarray(y_tiny)))
print("EP-PARITY-OK")
"""


def test_expert_parallel_apply_matches_dense_moe(tmp_path):
    """EP numerics parity end-to-end: `make_expert_parallel_moe` on a
    forced 4-device CPU mesh against the dense `moe.apply_moe` scatter
    path — exact routing agreement, allclose outputs when no bucket can
    overflow, and exact dropped-token accounting when one can. Runs in a
    subprocess because the XLA host-device-count flag must precede jax
    initialisation."""
    script = tmp_path / "ep_parity.py"
    script.write_text(_EP_PARITY_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    out = subprocess.run(
        [sys.executable, str(script)], env=env, capture_output=True,
        text=True, timeout=560,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "EP-PARITY-OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_dryrun_subprocess_production_mesh(tmp_path):
    """Real 16x16-mesh lower+compile for one (arch, shape) in a fresh
    process (the XLA device-count flag must precede jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "olmoe-1b-7b" if False else "stablelm-1.6b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "1/1 combinations compiled" in out.stdout, out.stdout + out.stderr
    rec = json.load(open(os.path.join(
        tmp_path, "stablelm-1.6b_decode_32k_16x16.json")))
    assert rec["ok"] and rec["devices"] == 256
    assert rec["trip_aware"]["flops_per_device"] > 0
