"""Per-architecture smoke tests (deliverable f) + decode/rollback
equivalence — the correctness bedrock for speculative verification."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.training import make_train_step


def _enc_out(cfg, b=1):
    if cfg.is_encoder_decoder:
        return jnp.ones((b, cfg.encoder_len, cfg.encoder_d_model),
                        jnp.float32) * 0.1
    return None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    """Reduced variant: one forward + one train step; shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 3 and cfg.d_model <= 256
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, aux = T.train_forward(cfg, params, toks, enc_out=_enc_out(cfg, 2))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    init_state, step = make_train_step(cfg)
    state = init_state(key)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((2, 16), jnp.float32)}
    if cfg.is_encoder_decoder:
        batch["enc_out"] = _enc_out(cfg, 2)
    if cfg.vision_stub:
        batch["embeds"] = jax.random.normal(key, (2, 16, cfg.d_model))
        batch["rope_pos"] = jnp.broadcast_to(
            jnp.arange(16, dtype=jnp.int32), (3, 2, 16))
        batch.pop("tokens")
        if cfg.vision_stub:
            batch_tokens = None
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", [
    "mixtral-8x7b",          # MoE
    "kimi-k2-1t-a32b",       # MoE, sigmoid router, shared expert
    "deepseek-v2-236b",      # MLA + MoE
    "rwkv6-3b",              # SSM state rollback
    "recurrentgemma-9b",     # hybrid pattern
    "whisper-large-v3",      # enc-dec
    "chatglm3-6b",           # dense GQA + 2d rope
    "qwen2-vl-7b",           # VLM / M-RoPE
])
def test_decode_matches_full_forward_and_rollback(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, key)
    enc = _enc_out(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 15), 0,
                              cfg.vocab_size)
    full, _ = T.train_forward(cfg, params, toks, moe_exact=True, enc_out=enc)
    cache = T.init_cache(cfg, 1, 64)
    _, cache, _ = T.prefill(cfg, params, toks[:, :12], cache, enc_out=enc)
    lo, cache2, _, staged = T.decode_step(cfg, params, cache, toks[:, 12:15])
    np.testing.assert_allclose(np.asarray(full[:, 12:15]), np.asarray(lo),
                               atol=2e-4, rtol=2e-3)
    # reject 2 of 3 -> rollback -> re-verify must still match
    cache3 = T.rollback_cache(cfg, cache2, staged, 1, 12)
    assert int(cache3["length"]) == 13
    lo2, _, _, _ = T.decode_step(cfg, params, cache3, toks[:, 13:15])
    np.testing.assert_allclose(np.asarray(full[:, 13:15]), np.asarray(lo2),
                               atol=2e-4, rtol=2e-3)


def test_sliding_window_ring_cache_matches_windowed_forward(key):
    """long_500k variant: ring cache (window + pad) must reproduce the
    windowed full-sequence forward."""
    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              num_layers=2)
    params = T.init_params(cfg, key)
    win = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 30), 0,
                              cfg.vocab_size)
    full, _ = T.train_forward(cfg, params, toks, window=win)
    cache = T.init_cache(cfg, 1, 64, window=win)
    assert cache["k"].shape[2] == win + 2 * T.SPEC_PAD  # ring, not full len
    _, cache, _ = T.prefill(cfg, params, toks[:, :27], cache, window=win)
    lo, _, _, _ = T.decode_step(cfg, params, cache, toks[:, 27:30],
                                window=win)
    np.testing.assert_allclose(np.asarray(full[:, 27:30]), np.asarray(lo),
                               atol=2e-4, rtol=2e-3)


def test_moe_unique_expert_telemetry(tiny_moe, key):
    cfg, params = tiny_moe
    cache = T.init_cache(cfg, 1, 64)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    _, cache, aux = T.prefill(cfg, params, toks, cache)
    _, _, aux, _ = T.decode_step(cfg, params, cache, toks[:, :4])
    u = np.asarray(aux["unique_experts"])
    assert u.shape == (cfg.num_layers,)
    assert (u >= cfg.experts_per_token).all()
    assert (u <= cfg.num_experts).all()


def test_param_counts_sane():
    cfg = get_config("kimi-k2-1t-a32b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 0.8e12 < total < 1.4e12          # ~1T
    assert 20e9 < active < 45e9             # ~32B active
    d2 = get_config("deepseek-v2-236b")
    assert 180e9 < d2.param_count() < 300e9


def test_vlm_mrope_positions(key):
    cfg = get_config("qwen2-vl-7b").reduced()
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    pos3 = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32), (3, 1, 12))
    lo_a, _ = T.train_forward(cfg, params, toks, rope_pos=pos3)
    lo_b, _ = T.train_forward(cfg, params, toks)
    # text-only: 3-D ids equal per axis == 1-D path
    np.testing.assert_allclose(np.asarray(lo_a), np.asarray(lo_b),
                               atol=1e-5)
    # genuinely different 2-D layout must change the logits
    pos_img = pos3.at[1].set(pos3[1] // 2).at[2].set(pos3[2] % 3)
    lo_c, _ = T.train_forward(cfg, params, toks, rope_pos=pos_img)
    assert float(jnp.abs(lo_c - lo_a).max()) > 1e-4


# ===================================================================== #
# Union-packed MoE dispatch (docs/kernels.md)
# ===================================================================== #

def test_packed_apply_moe_bit_identical(tiny_moe):
    """The packed path's inlined einsums use the dense path's exact
    contraction structure and dtypes, so its output is bitwise equal —
    across token counts spanning U=1-shaped unions to full saturation."""
    from repro.models import moe
    cfg, _ = tiny_moe
    p = moe.init_moe(cfg, jax.random.PRNGKey(1), jnp.float32)
    for t in (1, 2, 3, 8, 33):
        x = jax.random.normal(jax.random.PRNGKey(t), (t, cfg.d_model),
                              jnp.float32)
        yd, auxd = moe.apply_moe(cfg, p, x, capacity_policy="exact")
        yp, auxp = moe.apply_moe(cfg, p, x, capacity_policy="exact",
                                 packed=True)
        assert bool(jnp.all(yd == yp)), f"packed diverged at T={t}"
        np.testing.assert_array_equal(np.asarray(auxd["unique_experts"]),
                                      np.asarray(auxp["unique_experts"]))


def test_packed_apply_moe_fused_kernel_close(tiny_moe):
    """kernel_backend='interpret' runs the fused Pallas kernel in
    interpret mode over the packed layout — numerically close to the
    inline einsum path (not bit-equal: the kernel accumulates per-tile)."""
    from repro.models import moe
    cfg, _ = tiny_moe
    p = moe.init_moe(cfg, jax.random.PRNGKey(1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, cfg.d_model),
                          jnp.float32)
    y0, _ = moe.apply_moe(cfg, p, x, capacity_policy="exact", packed=True)
    y1, _ = moe.apply_moe(cfg, p, x, capacity_policy="exact", packed=True,
                          kernel_backend="interpret")
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-3)


def test_packed_expert_cap_and_counters(tiny_moe):
    """The packed path's dry-run counters scale with the bucketed union
    cap U_pad, not E: strictly below the dense counters while the union
    is unsaturated, exactly equal once U_pad == E."""
    from repro.models import moe
    cfg, _ = tiny_moe
    e, k = cfg.num_experts, cfg.experts_per_token
    caps = [moe.packed_expert_cap(cfg, t) for t in (1, 2, 4, 64)]
    assert caps[0] == min(2 ** (k - 1).bit_length(), e) or caps[0] <= e
    assert all(c <= e for c in caps)
    assert caps == sorted(caps)            # monotone in T
    assert moe.packed_expert_cap(cfg, 64) == e
    for t in (1, 2, 4, 64):
        cd = moe.moe_pass_counters(cfg, t, capacity_policy="exact")
        cp = moe.moe_pass_counters(cfg, t, capacity_policy="exact",
                                   packed=True)
        assert cp["capacity"] == cd["capacity"]
        if moe.packed_expert_cap(cfg, t) < e:
            assert cp["expert_weight_bytes"] < cd["expert_weight_bytes"]
            assert cp["ffn_flops"] < cd["ffn_flops"]
        else:
            assert cp["expert_weight_bytes"] == cd["expert_weight_bytes"]
            assert cp["ffn_flops"] == cd["ffn_flops"]
