"""Deliverable-artifact consistency: if the dry-run matrices have been run
(experiments/dryrun/), every (arch x shape x mesh) record must be ok with
sane telemetry. Skipped when artifacts are absent (fresh checkout)."""

import glob
import json
import os

import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.models.config import INPUT_SHAPES

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def _records(mesh):
    paths = glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))
    return [json.load(open(p)) for p in paths]


@pytest.mark.parametrize("mesh,devices", [("16x16", 256), ("2x16x16", 512)])
def test_dryrun_matrix_complete_and_ok(mesh, devices):
    if not os.path.isdir(DRYRUN_DIR):
        pytest.skip("dry-run artifacts not generated")
    recs = _records(mesh)
    if not recs:
        pytest.skip(f"no {mesh} artifacts")
    by_key = {(r.get("arch"), r.get("shape")): r for r in recs}
    missing = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES
               if (a.replace(".", "-"), s) not in
               {(k[0].replace(".", "-"), k[1]) for k in by_key}]
    assert not missing, f"missing combos: {missing}"
    for r in recs:
        assert r.get("ok"), (r.get("arch"), r.get("shape"), r.get("error"))
        assert r["devices"] == devices
        ta = r.get("trip_aware")
        if ta:  # multi-pod artifacts may predate the analyzer; single must have it
            assert ta["flops_per_device"] > 0
            assert ta["bytes_per_device"] > 0


def test_multi_pod_shards_per_device_work():
    """The pod axis must genuinely shard: per-device FLOPs at 2x16x16 are
    ~half of 16x16 for the batch-sharded shapes."""
    if not os.path.isdir(DRYRUN_DIR):
        pytest.skip("dry-run artifacts not generated")
    single = {(r["arch"], r["shape"]): r for r in _records("16x16")
              if r.get("ok")}
    multi = {(r["arch"], r["shape"]): r for r in _records("2x16x16")
             if r.get("ok")}
    if not single or not multi:
        pytest.skip("need both meshes")
    checked = 0
    for key, s in single.items():
        m = multi.get(key)
        if m is None or key[1] == "long_500k":  # batch=1: pod can't shard it
            continue
        fs = s.get("trip_aware", {}).get("flops_per_device") or \
            s["flops_per_device"]
        fm = m.get("trip_aware", {}).get("flops_per_device") or \
            m["flops_per_device"]
        assert fm < fs * 0.8, (key, fs, fm)
        checked += 1
    assert checked >= 10
