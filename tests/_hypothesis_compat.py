"""Minimal in-repo fallback for `hypothesis` so the tier-1 suite collects
and runs on machines without it (the real library is in requirements-dev.txt
and is used whenever importable).

Provides just the surface the tests use — `given`, `settings`, and the
`integers` / `floats` / `lists` strategies — running each property test on a
deterministic pseudo-random sample of examples (seeded per test name, so
failures reproduce). No shrinking, no database; a red test here is a plain
assertion error with the generated arguments in the traceback.
"""

from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, sample_fn):
        self._sample = sample_fn

    def example(self, rng):
        return self._sample(rng)


class strategies:  # noqa: N801 — mirrors `hypothesis.strategies` module use
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(sample)


st = strategies


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator: records max_examples on the (given-wrapped) function."""
    def deco(fn):
        fn._max_examples = min(max_examples, 100)
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        params = list(inspect.signature(fn).parameters.values())
        # hypothesis semantics: positional strategies fill the RIGHTMOST
        # parameters; keyword strategies fill their named parameters. What
        # remains are pytest fixtures and must stay visible to pytest.
        pos_names = ([p.name for p in params[-len(arg_strategies):]]
                     if arg_strategies else [])
        fixture_params = [p for p in params
                          if p.name not in kw_strategies
                          and p.name not in pos_names]

        @functools.wraps(fn)
        def wrapper(**fixture_kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode()) & 0xFFFFFFFF)
            for _ in range(n):
                # everything by name, so generated values land on their own
                # parameters even when fixtures precede them in the signature
                kwargs = dict(zip(pos_names,
                                  (s.example(rng) for s in arg_strategies)))
                kwargs.update((k, s.example(rng))
                              for k, s in kw_strategies.items())
                fn(**fixture_kwargs, **kwargs)

        # pytest must only see the fixture parameters, not generated ones
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(fixture_params)
        return wrapper
    return deco
