"""Chunked, cost-priced prefill + speculative-decode-loop bugfix
regressions: prefill_chunk vs one-shot prefill, mixed prefill+decode steps
vs sequential references, chunk=0 legacy bit-exactness, TTFT/queue
telemetry, the admission budget, stop-token-mid-draft truncation, the
controller-derived KV-ring guard, and the bounded n-gram scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import StaticKController, TPU_V5E
from repro.core import cost_model as cm
from repro.models import transformer as T
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           NGramDrafter, Request, ServingEngine)
from repro.serving.drafter import Drafter

VARIED_PROMPT = list(range(3, 35))  # greedy stream has distinct tokens


class ScriptedDrafter(Drafter):
    """Oracle drafter: proposes the known future of the token stream, so
    greedy verification accepts every draft — the deterministic way to land
    a stop token mid-draft."""

    def __init__(self, script):
        self.script = list(script)

    def propose(self, history, k, rng=None):
        n = len(history)
        return self.script[n:n + k], None


# ===================================================================== #
# Cost model: prefill crosses the roofline
# ===================================================================== #

def test_prefill_time_crosses_roofline():
    cfg = get_config("mixtral-8x7b")
    one = cm.prefill_time(cfg, TPU_V5E, 1)
    assert not one["compute_bound"]          # single token: decode regime
    big = cm.prefill_time(cfg, TPU_V5E, 8192)
    assert big["compute_bound"]              # long chunk: compute-bound
    cross = cm.prefill_crossover_tokens(cfg, TPU_V5E)
    assert 1 < cross < 8192
    assert cm.prefill_time(cfg, TPU_V5E, cross)["compute_bound"]
    assert not cm.prefill_time(cfg, TPU_V5E, cross // 2)["compute_bound"]
    # monotone in chunk size; chunk writes make it dearer than a decode
    # iteration of the same token count
    ts = [cm.prefill_time(cfg, TPU_V5E, n)["t_iter"] for n in (1, 64, 4096)]
    assert ts[0] <= ts[1] <= ts[2]
    assert (cm.prefill_time(cfg, TPU_V5E, 64)["bytes"]
            > cm.iteration_time(cfg, TPU_V5E, 64, 0)["bytes"])


def test_bucket_length_powers_of_two():
    assert [T.bucket_length(n) for n in (1, 2, 3, 5, 8, 9, 33)] == \
        [1, 2, 4, 8, 8, 16, 64]


# ===================================================================== #
# prefill_chunk == one-shot prefill (model level)
# ===================================================================== #

def test_prefill_chunk_matches_full_prefill(tiny_moe):
    cfg, params = tiny_moe
    prompt = VARIED_PROMPT
    ref_cache = T.init_cache(cfg, 1, 128)
    ref_lo, ref_cache, _ = T.prefill(
        cfg, params, jnp.asarray([prompt], jnp.int32), ref_cache)

    cache = T.init_cache(cfg, 1, 128)
    chunk = 8
    lo = None
    for start in range(0, len(prompt), chunk):
        span = prompt[start:start + chunk]
        t_pad = T.bucket_length(len(span))
        toks = np.zeros((1, t_pad), np.int32)
        msk = np.zeros((1, t_pad), bool)
        toks[0, :len(span)] = span
        msk[0, :len(span)] = True
        lo, cache, _, st = T.prefill_chunk(cfg, params, cache,
                                           jnp.asarray(toks),
                                           token_mask=jnp.asarray(msk))
        cache = T.rollback_cache(cfg, cache, st, len(span),
                                 int(cache["length"]) - t_pad)
    assert int(cache["length"]) == len(prompt) == int(ref_cache["length"])
    last = len(prompt) % chunk or chunk
    np.testing.assert_allclose(np.asarray(lo[0, last - 1], np.float32),
                               np.asarray(ref_lo[0, -1], np.float32),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_array_equal(np.asarray(cache["pos"]),
                                  np.asarray(ref_cache["pos"]))


def test_mixed_prefill_decode_step_matches_references(tiny_moe):
    """One pass packing a decode span (row 0) and a prefill chunk (row 1)
    must reproduce each row's standalone logits."""
    cfg, params = tiny_moe
    p0 = list(range(3, 23))
    p1 = [9, 40, 17, 88, 5, 61] * 4
    bc = T.init_cache(cfg, 2, 128, per_row=True)
    c0 = T.init_cache(cfg, 1, 128)
    _, c0, _ = T.prefill(cfg, params, jnp.asarray([p0], jnp.int32), c0)
    bc = T.write_cache_row(bc, 0, c0)

    span0 = [7, 9, 11]
    chunk1 = p1[:8]
    t_max = 8
    toks = np.zeros((2, t_max), np.int32)
    msk = np.zeros((2, t_max), bool)
    toks[0, :len(span0)] = span0
    msk[0, :len(span0)] = True
    toks[1, :len(chunk1)] = chunk1
    msk[1, :len(chunk1)] = True
    lo, _, _, _ = T.prefill_chunk(cfg, params, bc, jnp.asarray(toks),
                                  token_mask=jnp.asarray(msk))

    lo0, _, _, _ = T.decode_step(cfg, params, c0,
                                 jnp.asarray([span0], jnp.int32))
    np.testing.assert_allclose(np.asarray(lo[0, :len(span0)], np.float32),
                               np.asarray(lo0[0], np.float32),
                               atol=2e-4, rtol=2e-4)

    c1 = T.init_cache(cfg, 1, 128)
    lo1, _, _ = T.prefill(cfg, params, jnp.asarray([chunk1], jnp.int32), c1)
    np.testing.assert_allclose(np.asarray(lo[1, :len(chunk1)], np.float32),
                               np.asarray(lo1[0], np.float32),
                               atol=2e-4, rtol=2e-4)


# ===================================================================== #
# Engine: chunked admission
# ===================================================================== #

def test_chunked_stream_matches_blocking_greedy(tiny_moe):
    cfg, params = tiny_moe
    blocking = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                             max_batch=2, max_len=256, temperature=0.0,
                             clock="model", seed=0)
    chunked = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=2, max_len=256, temperature=0.0,
                            clock="model", seed=0, chunk=8)
    ctl = lambda: StaticKController(3)
    r_b = blocking.generate(VARIED_PROMPT, max_new=16, controller=ctl())
    r_c = chunked.generate(VARIED_PROMPT, max_new=16, controller=ctl())
    assert r_b.tokens == r_c.tokens
    assert r_c.telemetry.prefill_chunks == 4       # 32 tokens / chunk=8
    assert r_b.telemetry.prefill_chunks == 0       # blocking one-shot
    assert r_c.telemetry.t_prefill > 0
    assert r_c.telemetry.ttft > 0


def test_model_clock_prefill_is_cost_model_not_wall(tiny_moe):
    """tel.t_prefill under clock='model' must come from cm.prefill_time —
    wall seconds of a jitted CPU trace would mix units with the virtual
    decode clock (the old bug made TTFT meaningless)."""
    cfg, params = tiny_moe
    expect = cm.prefill_time(cfg, TPU_V5E, len(VARIED_PROMPT))["t_iter"]
    leg = ServingEngine(cfg, params, NGramDrafter(), max_len=256,
                        temperature=0.0, clock="model")
    r = leg.generate(VARIED_PROMPT, max_new=4,
                     controller=StaticKController(2))
    assert r.telemetry.t_prefill == expect
    assert r.telemetry.ttft == expect
    bat = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                        max_len=256, temperature=0.0, clock="model")
    r2 = bat.generate(VARIED_PROMPT, max_new=4,
                      controller=StaticKController(2))
    assert r2.telemetry.t_prefill == expect
    # deterministic: a rerun sees the identical virtual prefill time
    bat2 = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                         max_len=256, temperature=0.0, clock="model")
    r3 = bat2.generate(VARIED_PROMPT, max_new=4,
                       controller=StaticKController(2))
    assert r3.telemetry.t_prefill == r2.telemetry.t_prefill


def _queue_run(cfg, params, depth, chunk, max_new=6):
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=2,
                        max_len=256, temperature=0.0, clock="model",
                        seed=0, chunk=chunk)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: StaticKController(2))
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 4 + i, 5 + i] * 8,
                    max_new=max_new) for i in range(depth)]
    sched.run(reqs)
    return eng, sched


def test_ttft_monotone_in_queue_depth(tiny_moe):
    cfg, params = tiny_moe
    means = [_queue_run(cfg, params, d, chunk=8)[1].mean_ttft()
             for d in (1, 3, 6)]
    assert means[0] <= means[1] <= means[2]
    assert means[2] > means[0]  # a deep queue really does wait


def test_prefill_budget_respected(tiny_moe):
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=2,
                        max_len=256, temperature=0.0, clock="model",
                        seed=0, chunk=8, max_prefill_tokens_per_step=8)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: StaticKController(2))
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 4 + i] * 10,
                    max_new=4) for i in range(3)]
    res = sched.run(reqs)
    assert len(res) == 3 and all(len(r.tokens) == 4 for r in res)
    steps = eng.telemetry.steps
    assert all(s.prefill_tokens <= 8 for s in steps)
    assert any(s.prefill_tokens for s in steps)
    assert any(s.decode_tokens for s in steps)
    # the split is telemetered coherently
    assert all(s.prefill_tokens + s.decode_tokens == s.tokens_in_flight
               for s in steps)


def test_queue_delay_recorded_under_load(tiny_moe):
    cfg, params = tiny_moe
    _, sched = _queue_run(cfg, params, depth=5, chunk=8)
    delays = [r.telemetry.t_queue for r in sched.results]
    assert delays[0] == 0.0               # head of queue starts immediately
    assert max(delays) > 0.0              # someone had to wait
    assert all(r.telemetry.ttft >= r.telemetry.t_queue
               for r in sched.results)


def test_degenerate_prompts_raise(tiny_moe):
    """Empty prompts (which would hang chunked admission forever) and
    prompts that cannot fit the cache fail loudly in both engines."""
    cfg, params = tiny_moe
    leg = ServingEngine(cfg, params, NGramDrafter(), max_len=64,
                        temperature=0.0, clock="model")
    for bad in ([], list(range(3, 70))):
        with pytest.raises(ValueError):
            leg.generate(bad, max_new=4, controller=StaticKController(2))
        for chunk in (0, 8):
            eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                                max_batch=1, max_len=64, temperature=0.0,
                                clock="model", chunk=chunk)
            with pytest.raises(ValueError):
                eng.join(bad, max_new=4, controller=StaticKController(2))


def test_chunked_padded_writes_never_wrap(tiny_moe):
    """Every row of the padded pass writes T_max slots from its own length,
    so a near-capacity decode row sharing a step with a large prefill chunk
    must cap the step's T — otherwise the padded writes wrap onto the row's
    own early cache slots and destroy its context."""
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=2,
                        max_len=40, temperature=0.0, clock="model",
                        seed=0, chunk=32)
    a = eng.join([5, 6, 7, 8] * 8, max_new=4,
                 controller=StaticKController(4))
    eng.step()                      # a's 32-token prompt lands in one chunk
    b = eng.join(list(range(3, 37)), max_new=4,
                 controller=StaticKController(4))
    for _ in range(64):
        if eng.slots[a].done and eng.slots[b].done:
            break
        eng.step()
        pos = np.asarray(eng.cache["pos"])
        assert pos.max() < 40        # never a wrapped (clobbering) write
    assert eng.slots[a].done and eng.slots[b].done
    # b's 34-token prompt was throttled into sub-chunk pieces by a's
    # proximity to the cache end, but still completed
    assert eng.slots[b].tel.prefill_chunks > 2
    assert len(eng.retire(b).tokens) >= 1


# ===================================================================== #
# Bugfix: stop token accepted mid-draft
# ===================================================================== #

@pytest.mark.parametrize("engine_kind", ["legacy", "batched"])
def test_stop_token_mid_draft_greedy(tiny_moe, engine_kind):
    cfg, params = tiny_moe
    ref = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                        temperature=0.0, clock="model", seed=0)
    stream = ref.generate(VARIED_PROMPT, max_new=20,
                          controller=StaticKController(4)).tokens
    assert len(set(stream[:5])) == 5     # varied: mid-draft stop possible
    script = VARIED_PROMPT + stream
    stop = stream[2]                     # accepted-draft (non-bonus) slot
    assert stream.index(stop) == 2

    if engine_kind == "legacy":
        eng = ServingEngine(cfg, params, ScriptedDrafter(script),
                            max_len=512, temperature=0.0, clock="model",
                            seed=0)
        res = eng.generate(VARIED_PROMPT, max_new=20, stop_token=stop,
                           controller=StaticKController(4))
    else:
        eng = BatchedEngine(cfg, params, lambda: ScriptedDrafter(script),
                            max_batch=1, max_len=512, temperature=0.0,
                            clock="model", seed=0)
        res = eng.generate(VARIED_PROMPT, max_new=20, stop_token=stop,
                           controller=StaticKController(4))
    # the oracle drafter makes iteration 0 emit 5 tokens; the stop sits at
    # accepted-draft position 1, so the old == next_token check missed it
    assert res.tokens == stream[:3]
    assert res.tokens[-1] == stop


@pytest.mark.parametrize("engine_kind", ["legacy", "batched"])
def test_stop_token_truncates_sampled(tiny_moe, engine_kind):
    cfg, params = tiny_moe

    def make(stop=None):
        if engine_kind == "legacy":
            eng = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                                temperature=1.0, clock="model", seed=3)
            return eng.generate(VARIED_PROMPT, max_new=24, stop_token=stop,
                                controller=StaticKController(4))
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=1, max_len=512, temperature=1.0,
                            clock="model", seed=3)
        return eng.generate(VARIED_PROMPT, max_new=24, stop_token=stop,
                            controller=StaticKController(4))

    stream = make().tokens
    # pick a token whose first occurrence is past the first position
    idx = next(i for i in range(1, len(stream))
               if stream[i] not in stream[:i])
    stop = stream[idx]
    res = make(stop=stop).tokens
    assert res == stream[:idx + 1]       # identical prefix, nothing after
    assert res.count(stop) == 1 and res[-1] == stop


# ===================================================================== #
# Bugfix: KV-ring guard derived from the controller's k_max
# ===================================================================== #

def test_ring_guard_derived_from_controller(tiny_moe):
    """max_len=48, prompt=28: after the first token the history is 29 long.
    A k_max=20 controller's next span (up to 21 tokens) would write to
    position 49 — past the cache — which the old hardcoded `+16` guard
    allowed (29+16 < 48). The derived guard stops first; a k_max=7
    controller still gets to speculate."""
    cfg, params = tiny_moe
    prompt = VARIED_PROMPT[:28]
    for make_engine in (
        lambda: ServingEngine(cfg, params, NGramDrafter(), max_len=48,
                              temperature=0.0, clock="model", seed=0),
        lambda: BatchedEngine(cfg, params, lambda: NGramDrafter(),
                              max_batch=1, max_len=48, temperature=0.0,
                              clock="model", seed=0),
    ):
        wide = make_engine().generate(prompt, max_new=16,
                                      controller=StaticKController(20))
        assert len(wide.tokens) == 1     # no room for a 21-token span
        narrow = make_engine().generate(prompt, max_new=16,
                                        controller=StaticKController(7))
        assert len(narrow.tokens) > 1    # an 8-token span still fits


def test_ring_guard_never_overflows_cache(tiny_moe):
    """Regression: with a k_max>15 controller near max_len, every cache
    write must stay inside the ring — the old guard let spans wrap around
    and silently clobber live positions."""
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=1,
                        max_len=64, temperature=0.0, clock="model", seed=0)
    idx = eng.join([5, 6, 7, 8] * 7, max_new=48,
                   controller=StaticKController(20))
    while not eng.slots[idx].done:
        eng.step()
        pos = np.asarray(eng.cache["pos"][0])
        assert pos.max() < 64            # never a wrapped (clobbering) write
        valid = pos[pos >= 0]
        assert len(np.unique(valid)) == len(valid)
    res = eng.retire(idx)
    assert len(res.tokens) >= 1
    # terminated because the next worst-case span would not fit
    assert 28 + len(res.tokens) + 21 > 64


# ===================================================================== #
# Bugfix/perf: bounded n-gram scan
# ===================================================================== #

def test_ngram_bounded_scan_exact_on_short_histories():
    rng = np.random.default_rng(0)
    bounded = NGramDrafter(max_scan=512)
    unbounded = NGramDrafter(max_scan=0)
    for _ in range(20):
        n = int(rng.integers(4, 500))
        hist = list(rng.integers(0, 8, n))   # small vocab => matches exist
        for k in (1, 4, 8):
            assert bounded.propose(hist, k) == unbounded.propose(hist, k)


def test_ngram_bounded_scan_long_history():
    # most recent occurrence inside the window: bounded == unbounded
    pat = [7, 8, 9, 10, 11]
    noise = list(np.random.default_rng(1).integers(20, 400, 1500))
    hist = noise[:1400] + pat + noise[1400:] + pat  # match ~100 tokens back
    bounded = NGramDrafter(max_scan=512)
    unbounded = NGramDrafter(max_scan=0)
    assert bounded.propose(hist, 4) == unbounded.propose(hist, 4)
    assert bounded.propose(hist, 4)[0]       # and it actually found it
    # match only outside the window: bounded proposes nothing, by design
    hist2 = pat + list(np.random.default_rng(2).integers(20, 400, 1500)) \
        + pat[:3]
    b_prop, _ = NGramDrafter(max_scan=256).propose(hist2, 4)
    u_prop, _ = unbounded.propose(hist2, 4)
    assert u_prop and not b_prop
