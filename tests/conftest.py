import jax
import numpy as np
import pytest

# Tests run on the single host CPU device (the 512-device override is only
# ever set inside the dry-run subprocess).


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_moe():
    """A reduced Mixtral-family MoE shared across tests (init is slow on
    one core; do it once)."""
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


COPY_PERIOD = 32


def copy_batch(rng, bs=16, period=COPY_PERIOD, seq=96, vocab=128):
    """Periodic-copy task: [BOS, p, p, p...] — the minimal structure a
    2-layer model learns quickly (fixed-offset attention) and that n-gram
    drafting accelerates at serving time."""
    import jax.numpy as jnp
    import numpy as np
    p = rng.integers(3, vocab, (bs, period))
    reps = seq // period + 2
    full = np.concatenate([np.ones((bs, 1), int)]
                          + [p] * reps, axis=1)[:, :seq + 1]
    toks = full[:, :seq].astype(np.int32)
    labels = full[:, 1:seq + 1].astype(np.int32)
    mask = np.zeros((seq,), np.float32)
    mask[period:] = 1.0  # score only the predictable copy region
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
            "mask": jnp.broadcast_to(jnp.asarray(mask), (bs, seq))}


@pytest.fixture(scope="session")
def trained_tiny_moe():
    """A tiny MoE trained on the periodic-copy task so that its greedy
    generations are genuinely n-gram-draftable (real acceptance, real
    routing — the honest end-to-end path of DESIGN.md §4)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.training import make_train_step
    from repro.training.optimizer import adamw

    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              vocab_size=128, num_layers=2)
    init_state, step = make_train_step(cfg, optimizer=adamw(3e-3))
    state = init_state(jax.random.PRNGKey(1))
    step = jax.jit(step)
    rng = np.random.default_rng(3)
    first = None
    for _ in range(200):
        state, m = step(state, copy_batch(rng))
        if first is None:
            first = float(m["ce"])
    return cfg, state[0], (first, float(m["ce"]))
