"""Open-loop load harness (docs/serving_load.md): arrival-stamped queue
delay, the admission starvation guard, censored-vs-drained throughput
accounting, shed-request violation accounting, arrival-process sanity,
and the predictive TTFT admission constraint's deny/defer semantics and
escape clause."""

import math

import numpy as np
import pytest

from repro.core import (ADMIT, DEFER, SHED, CascadeController,
                        PredictiveTTFTAdmission, RequestSLO, ttft_violated)
from repro.core.slo import LATENCY, THROUGHPUT
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           NGramDrafter, Request, percentile)
from repro.serving.load import (LoadSpec, build_trace, diurnal_arrivals,
                                poisson_arrivals, run_load, summarize)
from repro.serving.telemetry import StepTelemetry, planner_aggregates


def _sched(tiny_moe, *, max_batch=2, chunk=0, **kw):
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                        max_batch=max_batch, max_len=256, temperature=0.0,
                        clock="model", seed=0, chunk=chunk)
    return ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController(), **kw)


def _req(rid, *, max_new=6, slo=None, seed=3):
    return Request(request_id=rid, prompt=[seed, seed + 1, seed + 2] * 4,
                   max_new=max_new, slo=slo)


# ===================================================================== #
# arrival-stamped queue delay
# ===================================================================== #

def test_trace_queue_delay_reflects_arrival_time(tiny_moe):
    """Two requests arrive at t=0 with one slot: the queued one's t_queue
    must cover its wait from ARRIVAL (≈ the first request's service
    time), not from the submit() call the replay loop happened to make
    later."""
    sched = _sched(tiny_moe, max_batch=1)
    sched.run_trace([(0.0, _req("a")), (0.0, _req("b", seed=5))])
    tel = {r.telemetry.request_id: r.telemetry for r in sched.results}
    assert tel["a"].t_queue == 0.0
    assert tel["b"].t_queue > 0.0
    # b waited out a's entire occupancy: its delay is on the order of the
    # clock when it was admitted, not epsilon-above-zero
    assert tel["b"].t_queue >= tel["a"].ttft

def test_trace_idle_engine_fast_forwards_clock(tiny_moe):
    """A request arriving long after the previous one drained must not be
    charged phantom queue delay: the idle engine jumps its clock to the
    arrival."""
    sched = _sched(tiny_moe, max_batch=1)
    sched.run_trace([(0.0, _req("a")), (50.0, _req("b", seed=5))])
    tel = {r.telemetry.request_id: r.telemetry for r in sched.results}
    assert tel["b"].t_queue == 0.0
    assert sched.engine.now > 50.0


def test_closed_loop_submit_unchanged(tiny_moe):
    """submit() without `at` stamps the engine clock — the closed-loop
    behavior run() depends on keeps byte-identity with the pre-trace
    scheduler."""
    sched = _sched(tiny_moe)
    sched.submit(_req("a"))
    assert sched._submit_time["a"] == sched.engine.now


# ===================================================================== #
# starvation guard
# ===================================================================== #

def _starvation_delays(tiny_moe, guard):
    """Saturating latency-tier stream, one throughput probe behind the
    first few arrivals; returns the probe's queue delay."""
    sched = _sched(tiny_moe, max_batch=1, max_queue_jumps=guard)
    trace = [(i * 1e-4, _req(f"lat-{i}", slo=RequestSLO.latency(),
                             seed=3 + i))
             for i in range(10)]
    trace.append((2.5e-4, _req("probe", seed=30)))
    sched.run_trace(trace)
    tel = {r.telemetry.request_id: r.telemetry for r in sched.results}
    return tel["probe"].t_queue, sched


def test_starvation_guard_bounds_probe_delay(tiny_moe):
    """Unguarded (max_queue_jumps=None), every later latency arrival
    jumps the waiting throughput probe — it is served dead last. The
    bounded-jump guard admits it after at most `max_queue_jumps` jumps,
    cutting its queue delay."""
    unguarded, su = _starvation_delays(tiny_moe, None)
    guarded, sg = _starvation_delays(tiny_moe, 2)
    assert guarded < unguarded
    # unguarded: the probe outlasted every latency request
    lat_delays = [r.telemetry.t_queue for r in su.results
                  if r.telemetry.request_id.startswith("lat-")]
    assert unguarded > max(lat_delays)
    # everything was still served in both runs
    assert len(su.results) == len(sg.results) == 11


def test_no_latency_traffic_is_plain_fifo(tiny_moe):
    """With no latency-tier request waiting, the guard is inert: results
    arrive in FIFO order whether the guard is on, off, or disabled."""
    orders = []
    for guard in (8, None, 0):
        sched = _sched(tiny_moe, max_batch=1, max_queue_jumps=guard)
        sched.run([_req(f"r{i}", seed=3 + i) for i in range(4)])
        orders.append([r.telemetry.request_id for r in sched.results])
    assert orders[0] == orders[1] == orders[2] == [f"r{i}"
                                                  for i in range(4)]


# ===================================================================== #
# censored vs drained throughput
# ===================================================================== #

@pytest.mark.parametrize("chunk", [0, 8])
def test_drained_run_throughput_identical(tiny_moe, chunk):
    """On a fully drained run the censored-corrected figure and the
    finished-only figure are the same quantity — equal to the float."""
    sched = _sched(tiny_moe, chunk=chunk)
    sched.run([_req(f"r{i}", seed=3 + i) for i in range(4)])
    stats = sched.throughput_stats()
    assert stats["censored"] is False
    assert stats["inflight_tokens"] == 0
    assert stats["tokens_per_s"] == stats["drained_tokens_per_s"]
    assert sched.tokens_per_second() == stats["tokens_per_s"]
    assert stats["tokens_per_s"] > 0


def test_horizon_cut_throughput_counts_inflight(tiny_moe):
    """Cut the replay at a step horizon with requests still in flight:
    the corrected figure must count their emissions (the drained figure
    censors them away)."""
    sched = _sched(tiny_moe, max_batch=2, chunk=8)
    trace = [(0.0, _req(f"r{i}", max_new=12, seed=3 + i))
             for i in range(4)]
    sched.run_trace(trace, max_steps=6)
    stats = sched.throughput_stats()
    assert stats["censored"] is True
    assert stats["inflight_tokens"] > 0
    assert stats["tokens_per_s"] > stats["drained_tokens_per_s"]
    assert sched.tokens_per_second() == stats["tokens_per_s"]


# ===================================================================== #
# shed-request violation accounting
# ===================================================================== #

def test_shed_bounded_request_counts_as_ttft_violation(tiny_moe):
    """A TTFT-bounded request the admission pipeline sheds must surface
    in tier_stats/slo_violations — never-served is a violation, not a
    silent zero. Unbounded requests ride through untouched (the escape
    clause)."""
    sched = _sched(tiny_moe, chunk=8,
                   admission=PredictiveTTFTAdmission())
    doomed = _req("doomed", slo=RequestSLO.latency(ttft=1e-12))
    free = _req("free", seed=9)
    sched.run([doomed, free])
    assert [r.telemetry.request_id for r in sched.results] == ["free"]
    assert [r.telemetry.request_id
            for r in sched.shed_results] == ["doomed"]
    shed_tel = sched.shed_results[0].telemetry
    assert shed_tel.shed and shed_tel.ttft == 0.0
    assert shed_tel.slo_ttft_violated
    stats = sched.tier_stats()
    assert stats[LATENCY]["shed"] == 1
    assert stats[LATENCY]["n"] == 0
    assert stats[LATENCY]["ttft_violations"] == 1
    assert sched.slo_violations() >= 1


def test_ttft_violated_predicate():
    assert not ttft_violated(None, None)
    assert not ttft_violated(None, 123.0)
    assert ttft_violated(0.5, None)       # bounded, never served
    assert ttft_violated(0.5, 0.0)        # bounded, no first token
    assert ttft_violated(0.5, 0.6)
    assert not ttft_violated(0.5, 0.5)


# ===================================================================== #
# arrival processes + long-tail traces
# ===================================================================== #

def test_poisson_arrival_statistics():
    rng = np.random.default_rng(0)
    ats = poisson_arrivals(rng, rate=50.0, n=4000)
    assert len(ats) == 4000
    assert all(b > a for a, b in zip(ats, ats[1:]))
    gaps = np.diff([0.0] + ats)
    assert abs(gaps.mean() - 1 / 50.0) / (1 / 50.0) < 0.1
    # exponential gaps: std == mean (CV = 1)
    assert abs(gaps.std() / gaps.mean() - 1.0) < 0.1


def test_diurnal_arrivals_modulate_but_keep_mean_rate():
    rng = np.random.default_rng(1)
    rate, period = 50.0, 4.0
    ats = diurnal_arrivals(rng, rate, 4000, amplitude=0.9, period=period)
    assert len(ats) == 4000
    assert all(b > a for a, b in zip(ats, ats[1:]))
    assert abs(len(ats) / ats[-1] - rate) / rate < 0.25
    # counts in peak-phase vs trough-phase period halves must differ
    phase = (np.asarray(ats) % period) / period
    peak = np.sum(phase < 0.5)      # sin > 0 half
    trough = len(ats) - peak
    assert peak > 1.3 * trough


def test_build_trace_deterministic_and_long_tailed():
    spec = LoadSpec(n_requests=200, rate=100.0, seed=5, latency_frac=0.4,
                    latency_ttft=1.0)
    t1, t2 = build_trace(spec), build_trace(spec)
    assert [(at, r.request_id, r.prompt) for at, r in t1] \
        == [(at, r.request_id, r.prompt) for at, r in t2]
    lens = [len(r.prompt) for _, r in t1]
    assert min(lens) >= spec.prompt_lo
    assert max(lens) <= spec.prompt_hi + 1      # +1: BOS
    assert np.mean(lens) > np.median(lens)      # right-skewed tail
    tiers = [r.slo.tier for _, r in t1 if r.slo is not None]
    assert tiers and all(t == LATENCY for t in tiers)
    assert 0.2 < len(tiers) / len(t1) < 0.6


# ===================================================================== #
# predictive admission semantics
# ===================================================================== #

def test_predictive_admission_decide_semantics():
    slo = RequestSLO.latency(ttft=1.0)
    shed = PredictiveTTFTAdmission()
    # escape clause: no bound, or bound met, always admits
    assert shed.decide(None, queue_delay=99, service_time=99).action \
        == ADMIT
    assert shed.decide(slo, queue_delay=0.4,
                       service_time=0.5).action == ADMIT
    # doomed: accrued delay + predicted service past the bound
    assert shed.decide(slo, queue_delay=0.8,
                       service_time=0.5).action == SHED
    d = PredictiveTTFTAdmission(on_doomed="defer", max_defers=2)
    assert d.decide(slo, queue_delay=2.0, service_time=0.5,
                    deferrals=0).action == DEFER
    assert d.decide(slo, queue_delay=2.0, service_time=0.5,
                    deferrals=1).action == DEFER
    # the defer budget is the liveness valve: exhausted -> admit anyway
    assert d.decide(slo, queue_delay=2.0, service_time=0.5,
                    deferrals=2).action == ADMIT
    # headroom scales the bound
    roomy = PredictiveTTFTAdmission(headroom=2.0)
    assert roomy.decide(slo, queue_delay=0.8,
                        service_time=0.5).action == ADMIT
    with pytest.raises(ValueError):
        PredictiveTTFTAdmission(on_doomed="explode")


def test_predictive_admission_invisible_when_not_engaged(tiny_moe):
    """Closed-loop run with generous bounds: the admission pipeline
    decides ADMIT everywhere and the token streams are identical to the
    unconstrained scheduler."""
    def run(admission):
        sched = _sched(tiny_moe, chunk=8, admission=admission)
        reqs = [_req(f"r{i}", seed=3 + i,
                     slo=RequestSLO.latency(ttft=1e6)) for i in range(4)]
        return sched.run(reqs), sched
    r_base, _ = run(None)
    r_pred, s_pred = run(PredictiveTTFTAdmission())
    assert [r.tokens for r in r_base] == [r.tokens for r in r_pred]
    assert s_pred.shed_results == [] and s_pred.deferred == 0


def test_defer_mode_backpressures_then_serves(tiny_moe):
    """on_doomed="defer": a doomed request is held at the queue head
    while the batch drains (deferred counter ticks) but is eventually
    served — deferral must never become livelock."""
    sched = _sched(tiny_moe, max_batch=2, chunk=8,
                   admission=PredictiveTTFTAdmission(on_doomed="defer",
                                                     max_defers=3))
    # `tight` must arrive while the engine is busy — DEFER against an
    # idle engine is treated as ADMIT (the clock only moves with the
    # batch, so holding a request there would never resolve)
    trace = [(0.0, _req("a", max_new=12)),
             (1e-9, _req("tight", seed=9,
                         slo=RequestSLO.latency(ttft=1e-12)))]
    sched.run_trace(trace)
    assert {r.telemetry.request_id for r in sched.results} \
        == {"a", "tight"}
    assert sched.shed_results == []
    assert sched.deferred >= 1


# ===================================================================== #
# shared percentile + calibration-sample filter
# ===================================================================== #

def test_percentile_nearest_rank():
    vals = list(range(1, 101))
    assert percentile(vals, 0.50) == 50
    assert percentile(vals, 0.95) == 95
    assert percentile(vals, 0.99) == 99
    assert percentile(vals, 1.0) == 100
    assert percentile([7.0], 0.99) == 7.0
    assert percentile([], 0.95) == 0.0
    assert percentile([3, 1, 2], 0.5) == 2    # sorts internally


def test_planner_aggregates_counts_zero_predictions():
    """The calibration-error filter keys on "a plan priced this pass"
    (`planned`), not on the prediction's truthiness — an exactly-0.0
    prediction is a sample with error 1.0, not a missing sample."""
    steps = [StepTelemetry(step=0, occupancy=1, tokens_in_flight=1,
                           padded_tokens=0, t_step=1.0,
                           t_step_predicted=0.0, planned=True),
             StepTelemetry(step=1, occupancy=1, tokens_in_flight=1,
                           padded_tokens=0, t_step=1.0,
                           t_step_predicted=0.5, planned=True),
             # unplanned step: excluded no matter what the field says
             StepTelemetry(step=2, occupancy=1, tokens_in_flight=1,
                           padded_tokens=0, t_step=1.0,
                           t_step_predicted=0.9, planned=False)]
    err = planner_aggregates(steps)["plan_time_error"]
    assert err == pytest.approx((1.0 + 0.5) / 2)


def test_engine_steps_are_planned(tiny_moe):
    sched = _sched(tiny_moe)
    sched.run([_req("a")])
    steps = sched.engine.telemetry.steps
    assert steps and all(s.planned for s in steps)


# ===================================================================== #
# the full harness, miniaturized
# ===================================================================== #

def test_run_load_report_shape(tiny_moe):
    cfg, params = tiny_moe
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(), max_batch=2,
                        max_len=256, temperature=0.0, clock="model",
                        seed=0, chunk=16)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController())
    spec = LoadSpec(n_requests=6, rate=200.0, seed=2, latency_frac=0.5,
                    prompt_median=12.0, prompt_hi=32, out_median=4.0,
                    out_hi=8)
    rep = run_load(sched, spec)
    assert rep["n_served"] == 6 and rep["n_shed"] == 0
    assert rep["p99_ttft"] >= rep["p95_ttft"] >= rep["p50_ttft"] > 0
    assert rep["makespan"] > 0 and rep["tokens"] > 0
    assert rep["goodput_frac"] == 1.0     # no binding bounds anywhere
    assert rep["queue_depth_max"] >= 0 and rep["occupancy_mean"] > 0
    assert len(rep["timeline"]) > 0
    assert rep["throughput"]["censored"] is False
    assert {LATENCY, THROUGHPUT} >= set(rep["tier_stats"])
