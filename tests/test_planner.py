"""Batch-level speculation planner: the cost oracle's exact agreement with
`batch_iteration_time`, the attribution-split invariants it relies on,
greedy water-filling against the brute-force-enumerated optimum (plus its
provable water-level guarantee), grant monotonicity in acceptance rate,
preemption, and Cascade TEST-phase staggering through the manager's hold
hook. Property-based tests use hypothesis (or the in-repo fallback)."""

import itertools
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (BatchCostOracle, BatchSpecPlanner, CascadeConfig,
                        CascadeController, Hardware, IterationRecord,
                        PlannerConfig, SpeculationManager, TPU_V5E,
                        UtilityAnalyzer, batch_iteration_time,
                        expected_emitted, expected_unique_experts,
                        expected_unique_experts_batch, greedy_allocate)
from repro.core.manager import BASELINE, SET, TEST

CFG = get_config("mixtral-8x7b").reduced()

# hardware regimes the water-filling must price correctly: the real v5e
# point (reduced model: overhead-dominated), a bandwidth-starved
# memory-bound point, a flop-starved compute-bound point, and the
# crossover regime the planner sweep runs in
HWS = [TPU_V5E,
       Hardware("slowmem", hbm_bw=1e9, peak_flops=197e12),
       Hardware("slowflops", hbm_bw=819e9, peak_flops=2e9),
       Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)]


def _throughput(oracle, decode, base_ns, alloc, accepts):
    """Predicted batch token rate of an allocation — the quantity the
    brute-force enumeration maximizes."""
    ns = list(base_ns)
    for i in decode:
        ns[i] += alloc[i]
    toks = sum(expected_emitted(accepts[i], alloc[i]) for i in decode)
    return toks / oracle.t_batch(ns)


# ===================================================================== #
# BatchCostOracle == batch_iteration_time, exactly
# ===================================================================== #

@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 6), seed=st.integers(0, 10 ** 6),
       aff=st.floats(0.0, 1.0))
def test_oracle_matches_batch_iteration_time_exactly(b, seed, aff):
    """The planner prices allocations through the oracle; the engine prices
    the realized pass through batch_iteration_time. Same inputs must give
    the same float, or predicted-vs-measured telemetry would drift even
    with a perfect acceptance model."""
    rng = np.random.default_rng(seed)
    ns = [int(rng.integers(0, 9)) for _ in range(b)]
    cls = [int(rng.integers(1, 400)) for _ in range(b)]
    ps = [int(rng.integers(0, 32)) for _ in range(b)]
    hw = HWS[seed % len(HWS)]
    oracle = BatchCostOracle(CFG, hw, cls, affinity=aff, prefill_tokens=ps)
    ref = batch_iteration_time(CFG, hw, ns, cls, affinity=aff,
                               prefill_tokens=ps)
    assert oracle.t_batch(ns) == ref["t_iter"]


def test_oracle_rejects_mismatched_rows():
    oracle = BatchCostOracle(CFG, TPU_V5E, [100, 200])
    with pytest.raises(ValueError):
        oracle.t_batch([1, 1, 1])
    with pytest.raises(ValueError):
        BatchCostOracle(CFG, TPU_V5E, [100, 200], prefill_tokens=[1])


# ===================================================================== #
# Attribution-split invariants (the statistics the planner prices with)
# ===================================================================== #

@settings(max_examples=100, deadline=None)
@given(e=st.integers(2, 64), k=st.integers(1, 8),
       ns=st.lists(st.integers(0, 9), min_size=1, max_size=6),
       aff=st.floats(0.0, 1.0))
def test_marginal_sum_bounded_by_union(e, k, ns, aff):
    """sum(marginal) <= union: each request's marginal expert contribution
    is the *top* increment of a concave union curve, so the B top-segments
    can never exceed the whole curve. B=1 (one live request) owns the
    union outright."""
    k = min(k, e)
    est = expected_unique_experts_batch(e, k, ns, aff)
    live = [n for n in ns if n > 0]
    assert sum(est["marginal"]) <= est["union"] + 1e-9
    if len(live) == 1:
        assert est["marginal"][ns.index(live[0])] == pytest.approx(
            est["union"], rel=1e-12)
    for n, m in zip(ns, est["marginal"]):
        assert m >= -1e-12
        if n == 0:
            assert m == 0.0


@settings(max_examples=40, deadline=None)
@given(b=st.integers(2, 5), seed=st.integers(0, 10 ** 6),
       aff=st.floats(0.0, 0.95))
def test_batch_attribution_marginals_consistent(b, seed, aff):
    """batch_iteration_time's per-request marginal_experts must obey the
    same invariant, and the attributed times must still sum to t_iter."""
    rng = np.random.default_rng(seed)
    ns = [int(rng.integers(1, 9)) for _ in range(b)]
    cls = [int(rng.integers(8, 400)) for _ in range(b)]
    r = batch_iteration_time(CFG, TPU_V5E, ns, cls, affinity=aff)
    marg = [p["marginal_experts"] for p in r["per_request"]]
    assert sum(marg) <= r["unique_experts"] + 1e-9
    assert sum(p["t_attr"] for p in r["per_request"]) == pytest.approx(
        r["t_iter"], rel=1e-12)


# ===================================================================== #
# Greedy water-filling vs the brute-force optimum
# ===================================================================== #

@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 4), seed=st.integers(0, 10 ** 6))
def test_greedy_within_bound_of_bruteforce(b, seed):
    """On small instances (B<=4, K<=4, the 4-expert reduced Mixtral) the
    greedy allocation's predicted batch throughput is within 0.85x of the
    enumerated optimum over the whole {0..cap_i}^B box, across all four
    hardware regimes. Greedy is *deliberately* not the argmax: any grant
    whose marginal rate still beats the no-speculation water level is
    admitted (the paper's break-even rule per grant), which can overshoot
    the throughput peak — the water-level guarantee below is the exact
    property; 0.85 is the measured-floor bound (worst observed 0.93)."""
    rng = np.random.default_rng(seed)
    hw = HWS[seed % len(HWS)]
    cls = [int(rng.integers(8, 300)) for _ in range(b)]
    caps = {i: int(rng.integers(0, 5)) for i in range(b)}
    accepts = {i: float(rng.uniform(0.0, 0.99)) for i in range(b)}
    aff = float(rng.choice([0.0, 0.3, 0.9]))
    decode = list(range(b))
    base_ns = [1] * b
    oracle = BatchCostOracle(CFG, hw, cls, affinity=aff)
    alloc, info = greedy_allocate(oracle, base_ns, decode, caps, accepts)

    got = _throughput(oracle, decode, base_ns, alloc, accepts)
    best = max(_throughput(oracle, decode, base_ns, dict(enumerate(combo)),
                           accepts)
               for combo in itertools.product(
                   *[range(caps[i] + 1) for i in decode]))
    assert got >= 0.85 * best
    # provable water-level guarantee: every admitted grant's marginal rate
    # beat len(decode)/t_base, so the mediant never drops below it —
    # speculation can only help the predicted batch rate
    assert got >= info["r_floor"] * (1 - 1e-9)
    for i in decode:
        assert 0 <= alloc[i] <= caps[i]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10 ** 6), b=st.integers(2, 5))
def test_grants_monotone_in_acceptance(seed, b):
    """With equal contexts and caps, a request with strictly higher
    windowed acceptance never receives fewer draft tokens."""
    rng = np.random.default_rng(seed)
    hw = HWS[seed % len(HWS)]
    cls = [128] * b
    caps = {i: 4 for i in range(b)}
    accepts = {i: float(a) for i, a in enumerate(
        sorted(rng.uniform(0.0, 0.99, b), reverse=True))}
    oracle = BatchCostOracle(CFG, hw, cls, affinity=0.3)
    alloc, _ = greedy_allocate(oracle, [1] * b, list(range(b)), caps,
                               accepts)
    grants = [alloc[i] for i in range(b)]
    assert grants == sorted(grants, reverse=True), (accepts, grants)


def test_preempts_low_acceptance_in_compute_bound_pass():
    """Once the shared pass crosses the roofline every draft token costs
    real time: a request with near-zero acceptance must be preempted
    outright while a high-acceptance request sharing the pass keeps
    speculating (in a *fully* flop-starved pass the threshold approaches
    B/n_tokens ~ 1 and even strong requests are rightly denied — this test
    sits at the crossover, the planner sweep's regime)."""
    hw = Hardware("crossover", hbm_bw=1e9, peak_flops=6e9)
    oracle = BatchCostOracle(CFG, hw, [128, 128, 128, 128], affinity=0.0)
    caps = {i: 4 for i in range(4)}
    accepts = {0: 0.95, 1: 0.9, 2: 0.02, 3: 0.01}
    alloc, _ = greedy_allocate(oracle, [1] * 4, list(range(4)), caps,
                               accepts)
    assert alloc[0] > 0
    assert alloc[2] == 0 and alloc[3] == 0


def test_greedy_fixed_rows_run_unmodified():
    """A staggered TEST trial's probe K is pinned before water-filling, so
    the FSM measures exactly the K it asked for."""
    oracle = BatchCostOracle(CFG, TPU_V5E, [64, 64, 64])
    alloc, _ = greedy_allocate(oracle, [1, 1, 1], [0, 1, 2],
                               {0: 3, 1: 4, 2: 2},
                               {0: 0.0, 1: 0.9, 2: 0.9},
                               fixed=frozenset([0]))
    assert alloc[0] == 3  # zero acceptance, granted anyway: it's the trial


# ===================================================================== #
# Acceptance estimation
# ===================================================================== #

def test_accept_rate_windowed_estimate():
    an = UtilityAnalyzer(window=16)
    assert an.accept_rate() is None
    an.observe(IterationRecord(k=0, tokens=1, t_iter=1.0))
    assert an.accept_rate() is None          # baseline iters don't count
    for tokens in (4, 3, 1):                 # 3+2+0 accepted of 4+4+4 drafted
        an.observe(IterationRecord(k=4, tokens=tokens, t_iter=1.0))
    assert an.accept_rate() == pytest.approx(5 / 12)
    # a long K=0 run (backed-off set phase, planner preemptions) must not
    # blank out the estimate: speculative records are filtered before the
    # window is taken
    for _ in range(2 * an.window):
        an.observe(IterationRecord(k=0, tokens=1, t_iter=1.0))
    assert an.accept_rate() == pytest.approx(5 / 12)
    # saturating acceptance stays below 1 (geometric series must converge)
    for _ in range(16):
        an.observe(IterationRecord(k=2, tokens=3, t_iter=1.0))
    assert an.accept_rate() <= 0.999


# ===================================================================== #
# Manager hold hook + planner staggering
# ===================================================================== #

def _drive_to_test(mgr):
    while mgr.phase != TEST:
        k = mgr.next_k()
        mgr.observe(IterationRecord(k=k, tokens=max(1, k), t_iter=1.0))


def test_manager_hold_freezes_fsm_one_iteration():
    mgr = SpeculationManager(cfg=CascadeConfig())
    _drive_to_test(mgr)
    left = mgr._phase_left
    trials = len(mgr._trial_records)
    k_hold = mgr.hold()
    assert k_hold == 0                      # no set-phase K yet -> K=0
    mgr.observe(IterationRecord(k=k_hold, tokens=1, t_iter=1.0))
    assert mgr.phase == TEST
    assert mgr._phase_left == left          # the trial did not tick
    assert len(mgr._trial_records) == trials
    # the next observe (un-held) advances normally again
    mgr.observe(IterationRecord(k=mgr.next_k(), tokens=1, t_iter=1.0))
    assert mgr._phase_left == left - 1


def test_manager_hold_outside_test_is_next_k():
    mgr = SpeculationManager(cfg=CascadeConfig())
    assert mgr.phase == BASELINE
    assert mgr.hold() == mgr.next_k() == 0
    mgr.observe(IterationRecord(k=0, tokens=1, t_iter=1.0))
    assert mgr._phase_left == mgr.cfg.baseline_iters - 1  # FSM advanced


def test_planner_staggers_to_one_trial_per_step():
    """Three controllers all in TEST: exactly one runs its trial; the
    others are held at their steady K with their FSMs frozen."""
    ctls = {}
    for i in range(3):
        c = CascadeController()
        _drive_to_test(c.manager)
        ctls[i] = c
    planner = BatchSpecPlanner(CFG, TPU_V5E)
    plan = planner.plan(ctls, [64, 64, 64])
    held = [i for i, d in plan.decisions.items() if d.held]
    assert len(held) == 2 and plan.held == 2
    trialing = [i for i in ctls if i not in held]
    assert len(trialing) == 1
    # trial row granted its probe in full
    d = plan.decisions[trialing[0]]
    assert d.granted == d.requested > 0
    # held rows' FSMs are frozen for this iteration
    for i in held:
        left = ctls[i].manager._phase_left
        ctls[i].observe(1, 1.0, k=plan.decisions[i].granted)
        assert ctls[i].manager._phase_left == left
        assert ctls[i].phase == TEST
    # round-robin: the next plan keeps a different trial row
    plan2 = planner.plan(ctls, [64, 64, 64])
    trialing2 = [i for i, d in plan2.decisions.items()
                 if not d.held and d.phase == TEST]
    assert trialing2 != trialing


def test_planner_bypass_single_request_and_independent():
    """At B=1 grants equal asks bit for bit (no holds, no capping), and
    policy="independent" does the same at any batch size."""
    c = CascadeController()
    _drive_to_test(c.manager)
    want = c.manager._k_now
    plan = BatchSpecPlanner(CFG, TPU_V5E).plan({0: c}, [64])
    assert plan.decisions[0].granted == plan.decisions[0].requested == want
    assert plan.held == 0 and plan.preempted == 0

    ctls = {i: CascadeController() for i in range(4)}
    for c in ctls.values():
        _drive_to_test(c.manager)
    planner = BatchSpecPlanner(
        CFG, TPU_V5E, config=PlannerConfig(policy="independent"))
    plan = planner.plan(ctls, [64] * 4)
    assert plan.held == 0
    for d in plan.decisions.values():
        assert d.granted == d.requested


def test_planner_predictions_populated():
    ctls = {i: CascadeController() for i in range(2)}
    plan = BatchSpecPlanner(CFG, TPU_V5E).plan(ctls, [64, 64])
    assert plan.t_base > 0 and plan.t_predicted >= plan.t_base
    # baseline-phase controllers ask 0 -> exactly one emission each
    assert plan.tokens_predicted == pytest.approx(2.0)
    assert plan.utility_predicted == pytest.approx(1.0)


def test_expected_emitted_series():
    assert expected_emitted(0.0, 4) == 1.0
    assert expected_emitted(0.5, 0) == 1.0
    assert expected_emitted(0.5, 2) == pytest.approx(1.75)
    # monotone in both arguments, bounded by k+1
    for k in range(5):
        assert expected_emitted(0.9, k) <= k + 1
        assert expected_emitted(0.9, k) <= expected_emitted(0.9, k + 1)
        assert expected_emitted(0.3, k) <= expected_emitted(0.6, k)


# ===================================================================== #
# Wall-clock calibration of the analytic cost model (docs/kernels.md)
# ===================================================================== #

def test_calibration_fit_recovers_scale_offset():
    """Synthetic measured = s*pred + off is recovered exactly and the
    post-fit residual collapses; the pre-fit residual is reported."""
    from repro.core import Calibration
    pred = [1e-3 * (i + 1) for i in range(20)]
    meas = [0.7 * p + 2e-4 for p in pred]
    cal = Calibration.fit(pred, meas)
    assert cal.time_scale == pytest.approx(0.7, rel=1e-6)
    assert cal.time_offset == pytest.approx(2e-4, rel=1e-6)
    assert cal.resid_after < 1e-8 < cal.resid_before
    for p, m in zip(pred, meas):
        assert cal.apply(p) == pytest.approx(m, rel=1e-6)


def test_calibration_fit_recovers_a2a_scale():
    """With a nonzero all-to-all column the collective gets its own scale,
    separate from the roofline's."""
    from repro.core import Calibration
    pred, a2a, meas = [], [], []
    for i in range(30):
        base = 1e-3 * (1 + (i % 7))
        aa = 2e-4 * (i % 5)
        pred.append(base + aa)
        a2a.append(aa)
        meas.append(0.8 * base + 1.5 * aa + 1e-4)
    cal = Calibration.fit(pred, meas, a2a)
    assert cal.time_scale == pytest.approx(0.8, rel=1e-5)
    assert cal.a2a_scale == pytest.approx(1.5, rel=1e-5)
    assert cal.time_offset == pytest.approx(1e-4, rel=1e-4)
    assert cal.resid_after < 1e-5 < cal.resid_before


def test_calibration_degenerate_falls_back_to_identity():
    """A rank-deficient system (constant predictions) must not produce a
    wild fit — the fallback is the identity transform."""
    from repro.core import Calibration
    cal = Calibration.fit([1e-3] * 8, [1.3e-3] * 8)
    assert cal.apply(5e-3) >= 0.0
    # either solved (constant maps to constant) or identity fallback
    assert cal.apply(1e-3) == pytest.approx(1.3e-3, rel=1e-6) or \
        cal.apply(1e-3) == pytest.approx(1e-3, rel=1e-6)


def test_calibration_adapted_util_floor_monotone():
    from repro.core import Calibration
    import dataclasses
    cal = Calibration.fit([1e-3 * (i + 1) for i in range(10)],
                          [1.1e-3 * (i + 1) + 1e-5 for i in range(10)])
    assert cal.adapted_util_floor(1.0) >= 1.0
    worse = dataclasses.replace(cal, resid_after=0.5)
    assert worse.adapted_util_floor(1.0) == pytest.approx(1.5)
    assert worse.adapted_util_floor(1.2) == pytest.approx(1.8)


def test_oracle_calibration_none_is_bit_identical():
    """BatchCostOracle(calibration=None) must price passes bit-for-bit as
    before the calibration hook existed (the planner-sweep drift gates
    depend on it), and a supplied calibration must equal the manual
    transform of the uncalibrated prediction."""
    from repro.core import BatchCostOracle, Calibration
    base = BatchCostOracle(CFG, TPU_V5E, [64, 128, 256])
    none = BatchCostOracle(CFG, TPU_V5E, [64, 128, 256], calibration=None)
    cal = Calibration(time_scale=0.75, time_offset=3e-4)
    with_cal = BatchCostOracle(CFG, TPU_V5E, [64, 128, 256],
                               calibration=cal)
    for ns in ([1, 1, 1], [4, 0, 2], [8, 8, 8]):
        t0 = base.t_batch(ns)
        assert none.t_batch(ns) == t0                      # bit-identical
        assert with_cal.t_batch(ns) == pytest.approx(
            cal.apply(t0, 0.0), rel=1e-12)


def test_planner_threads_calibration_into_oracle():
    """BatchSpecPlanner(calibration=) reaches the oracle: predicted pass
    times shrink under a <1 scale while grants stay grants."""
    from repro.core import Calibration
    cal = Calibration(time_scale=0.5, time_offset=0.0)
    ctls0 = {i: CascadeController() for i in range(2)}
    ctls1 = {i: CascadeController() for i in range(2)}
    p0 = BatchSpecPlanner(CFG, TPU_V5E).plan(ctls0, [64, 64])
    p1 = BatchSpecPlanner(CFG, TPU_V5E, calibration=cal).plan(
        ctls1, [64, 64])
    assert p1.t_predicted == pytest.approx(0.5 * p0.t_predicted, rel=1e-9)
    assert p1.t_base == pytest.approx(0.5 * p0.t_base, rel=1e-9)
