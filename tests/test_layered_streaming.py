"""Per-layer expert streaming (docs/offload.md, layered streaming):
`granularity="layer"` residency units, the layer-pipelined fetch schedule
(`moe_hide_fracs` / `fetch_hide_schedule` / `fetch_time_layered`), its
float-exactness between `BatchCostOracle` and `batch_iteration_time`,
bit-exact degradation to PR 7's whole-expert pricing, the engine's
layer-by-layer prefetcher (single-MoE-layer bit-identity, all-hbm
invisibility, layered-beats-whole-expert under a miss-forcing cap, and
the fetch-hide repricing regression), and the drafter-precision pricing
satellite (`draft_time(precision=)` threaded through both engines and
the planner)."""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

import jax

import repro.core.cost_model as cm
import repro.models.transformer as T
from repro.configs import get_config
from repro.core import (BatchCostOracle, BatchSpecPlanner, CascadeController,
                        ExpertPlacement, Hardware, Precision, ResidencyState,
                        batch_iteration_time, draft_time, expert_hbm_bytes,
                        fetch_hide_schedule, fetch_time_layered,
                        moe_hide_fracs, moe_layer_count)
from repro.core.cost_model import _fetch_time
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           NGramDrafter, Request, ServingEngine)

CFG = get_config("mixtral-8x7b").reduced()          # 4 experts, top-2
EB = expert_hbm_bytes(CFG)
EBL = expert_hbm_bytes(CFG, per_layer=True)
N_L = moe_layer_count(CFG)
HOST_HW = Hardware("offload-test", hbm_bw=1e9, peak_flops=1e10,
                   ici_bw=5e8, host_bw=1e9)

# the four planner-test hardware regimes, each given a host link (the
# layered fetch pipeline needs host_bw to price at all)
HWS = [
    Hardware("tpu-like", hbm_bw=819e9, peak_flops=197e12, ici_bw=5e8,
             host_bw=64e9),
    Hardware("slowmem", hbm_bw=1e9, peak_flops=197e12, ici_bw=5e8,
             host_bw=1e9),
    Hardware("slowflops", hbm_bw=819e9, peak_flops=2e9, ici_bw=5e8,
             host_bw=8e9),
    Hardware("crossover", hbm_bw=1e9, peak_flops=6e9, ici_bw=5e8,
             host_bw=1e9),
]


def _tiered(n_shards=1, host=None):
    pl = ExpertPlacement.contiguous(CFG.num_experts, n_shards)
    return pl.offload(host if host is not None
                      else [CFG.num_experts - 1])


# ===================================================================== #
# Residency units: per-(layer, expert) slices
# ===================================================================== #

def test_per_layer_bytes_exact_multiple():
    """The degradation keystone: whole-expert bytes are EXACTLY the MoE
    layer count times the per-layer slice — bitwise, so layered pricing
    can reproduce whole-expert figures bit for bit."""
    for name in ("mixtral-8x7b", "deepseek_v2_236b", "kimi_k2_1t_a32b"):
        cfg = get_config(name)
        for c in (cfg, cfg.reduced() if name == "mixtral-8x7b" else cfg):
            per = expert_hbm_bytes(c, per_layer=True)
            assert per > 0
            assert moe_layer_count(c) * per == expert_hbm_bytes(c)
    # precision threads through both views identically
    q = Precision.int8_experts()
    assert moe_layer_count(CFG) * expert_hbm_bytes(
        CFG, per_layer=True, precision=q) == expert_hbm_bytes(
        CFG, precision=q)


def test_layer_granularity_slots_and_capacity():
    off = _tiered(1, host=[2, 3])
    rs_e = ResidencyState(off, CFG)
    rs_l = ResidencyState(off, CFG, granularity="layer")
    assert rs_e.n_unit_layers == 1 and rs_l.n_unit_layers == N_L
    assert rs_l.expert_bytes == EBL
    # uncapped: every (layer, expert) slice fits; capacity in expert
    # equivalents matches the whole-expert view bitwise
    assert rs_l.slots == (N_L * 2,)
    assert rs_l.capacity_experts == rs_e.capacity_experts == [4.0]
    # a whole-expert cap maps to the same expert-equivalent capacity...
    cap = 2 * EB + EB
    e1 = ResidencyState(off, CFG, cap_bytes=cap)
    l1 = ResidencyState(off, CFG, cap_bytes=cap, granularity="layer")
    assert e1.slots == (1,) and l1.slots == (N_L,)
    assert e1.capacity_experts == l1.capacity_experts == [3.0]
    # ...while a fractional-expert cap only the finer units can use
    lf = ResidencyState(off, CFG, cap_bytes=2 * EB + 1.5 * EB,
                        granularity="layer")
    assert lf.slots == (3,)
    assert lf.capacity_experts == [2.0 + 3 / N_L]


def test_granularity_validation_and_unit_keys():
    off = _tiered(1, host=[2, 3])
    with pytest.raises(ValueError):
        ResidencyState(off, CFG, granularity="token")
    with pytest.raises(ValueError):                 # layer units need cfg
        ResidencyState(off, expert_bytes=EB, granularity="layer")
    rs_l = ResidencyState(off, CFG, granularity="layer")
    rs_e = ResidencyState(off, CFG)
    # mixing unit vocabularies is a caller bug, not a miss
    with pytest.raises(ValueError):
        rs_l.access([2], step=0)
    with pytest.raises(ValueError):
        rs_e.access([(0, 2)], step=0)
    with pytest.raises(ValueError):
        rs_l.fetch([(0, 1, 2)], step=0)
    # is_resident accepts both views in layer mode: an expert id is
    # resident iff ALL its layer slices are
    rs_l.fetch([(0, 2)], step=0)
    assert rs_l.is_resident((0, 2)) and not rs_l.is_resident((1, 2))
    assert not rs_l.is_resident(2)
    rs_l.fetch([(1, 2)], step=0)
    assert rs_l.is_resident(2)
    assert rs_l.is_resident(0)                      # hbm tier always


def test_layer_staging_semantics():
    """Unit-granularity staging: the pass reads staged slices as hits,
    note_step installs only the used slices and discards the rest —
    exactly the whole-expert contract, per (layer, expert) unit."""
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB + EB,
                        granularity="layer")     # N_L cache slots
    pf = rs.fetch([(0, 2), (1, 2), (0, 3)], step=0, stage=True)
    assert pf["fetched"] == 3 and pf["bytes"] == 3 * EBL
    assert pf["per_shard"] == [3]
    assert not rs.is_resident((0, 2))            # staged, not installed
    hit, missing = rs.access([(0, 2), (1, 3)], step=0)
    assert hit == [(0, 2)] and missing == [(1, 3)]
    df = rs.fetch(missing, step=0)               # demand-install
    assert df["fetched"] == 1 and rs.is_resident((1, 3))
    rs.note_step([(0, 2), (1, 3)], step=0)
    assert rs.is_resident((0, 2))                # used staged -> installed
    assert not rs.is_resident((1, 2))            # unused staged discarded
    assert not rs.is_resident((0, 3))
    assert rs.resident_counts == (2.0 + 2 / N_L,)
    assert rs.snapshot()["granularity"] == "layer"


def test_expected_misses_layer_generalization():
    off = _tiered(1, host=[2, 3])
    rs_e = ResidencyState(off, CFG)
    with pytest.raises(ValueError):              # no layer axis on experts
        rs_e.expected_layer_misses([2.0])
    # uncapped layer units: zero misses, same as the whole-expert tier
    rs = ResidencyState(off, CFG, granularity="layer")
    assert rs.expected_misses([3.0]) == [0.0]
    # capped: uniform per-layer rows, and expected_misses is their sum
    # (unit counts — times EBL they price the same bytes the expert
    # curve prices times EB at matching resident fractions)
    for slots_b in (0, 1, 2):
        rs = ResidencyState(off, CFG, cap_bytes=2 * EB + slots_b * EB,
                            granularity="layer")
        rows = rs.expected_layer_misses([3.0])
        assert len(rows) == 1 and len(rows[0]) == N_L
        assert len(set(rows[0])) == 1            # layer-blind: uniform
        assert rs.expected_misses([3.0]) == [sum(rows[0])]
        # resident fraction slots/(n_l*H): slots_b whole experts out of 2
        want = 3.0 * 0.5 * (1.0 - slots_b / 2.0)
        assert sum(rows[0]) * EBL == pytest.approx(want * EB)


# ===================================================================== #
# Layered fetch pricing: schedule, pipeline, degradation
# ===================================================================== #

def test_hide_schedule_monotone():
    """The layered hide window is nondecreasing in layer index — deeper
    layers overlap strictly more of the pass (the ISSUE's monotonicity
    pin)."""
    fracs = moe_hide_fracs(CFG)
    assert len(fracs) == N_L
    assert all(0.0 < f < 1.0 for f in fracs)
    assert all(b > a for a, b in zip(fracs, fracs[1:]))
    sched = fetch_hide_schedule(CFG, 1e-3, 2e-3)
    assert sched == [1e-3 + f * 2e-3 for f in fracs]
    assert all(b > a for a, b in zip(sched, sched[1:]))
    # zero basis: the schedule collapses to the flat base window
    assert fetch_hide_schedule(CFG, 5e-4, 0.0) == [5e-4] * N_L


def test_fetch_time_layered_expert_delegation():
    """Under granularity="expert" the generalized pricer delegates
    verbatim to `_fetch_time` — bit-identical tuple, no layer info — and
    rejects a schedule (whole experts price one scalar window)."""
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB + EB)
    for act, hide in (([3.0], 0.0), ([2.5], 1e-4), ([4.0], 1e-2)):
        ref = _fetch_time(rs, HOST_HW, act, None, hide)
        miss, t_fetch, t_unhid, info = fetch_time_layered(
            rs, HOST_HW, act, None, hide)
        assert (miss, t_fetch, t_unhid) == ref and info is None
        # measured counts delegate identically
        ref = _fetch_time(rs, HOST_HW, act, [2], hide)
        got = fetch_time_layered(rs, HOST_HW, act, [2], hide)
        assert got[:3] == ref and got[3] is None
    with pytest.raises(ValueError):
        fetch_time_layered(rs, HOST_HW, [3.0], None, [0.0, 0.0])
    # layer units on a host-link-less Hardware is a loud error
    rs_l = ResidencyState(off, CFG, granularity="layer")
    no_link = Hardware("no-host", hbm_bw=1e9, peak_flops=1e10, ici_bw=5e8)
    with pytest.raises(ValueError):
        fetch_time_layered(rs_l, no_link, [3.0], None, 0.0)
    with pytest.raises(ValueError):                # schedule length
        fetch_time_layered(rs_l, HOST_HW, [3.0], None, [0.0] * (N_L + 1))


def test_layered_pipeline_closed_form():
    """Hand-checked small case of the pipeline law:
    R_{s,l} = cum_misses * unit_bytes / host_bw,
    t_unhidden = max(0, max_l (R_l - hide_l)), t_fetch = R_{L-1}."""
    off = _tiered(1, host=[2, 3])
    rs = ResidencyState(off, CFG, cap_bytes=2 * EB, granularity="layer")
    bw = HOST_HW.host_bw
    hide = [0.5 * EBL / bw, 2.5 * EBL / bw]
    miss, t_fetch, t_unhid, info = fetch_time_layered(
        rs, HOST_HW, [2.0], [[2, 1]], hide)
    assert miss == [3.0]
    assert t_fetch == 3 * EBL / bw
    # layer 0 gates: R_0 - hide_0 = 1.5 u > R_1 - hide_1 = 0.5 u
    assert t_unhid == 2 * EBL / bw - hide[0]
    assert info["t_fetch_by_layer"] == [2 * EBL / bw, 1 * EBL / bw]
    assert info["miss_by_layer"] == [[2.0, 1.0]]
    # the staged-bytes cap credits only what was actually prefetched,
    # cumulatively: 1 slice staged for layer 0, none deeper
    _, _, capped, _ = fetch_time_layered(
        rs, HOST_HW, [2.0], [[2, 1]], hide, staged_per_shard=[[1, 0]])
    assert capped == 3 * EBL / bw - 1 * EBL / bw   # hide_eff = 1 slice
    # deeper misses hide more: the same units shifted one layer down
    # price no worse under the monotone schedule
    _, _, deep, _ = fetch_time_layered(rs, HOST_HW, [2.0], [[0, 3]], hide)
    _, _, shallow, _ = fetch_time_layered(rs, HOST_HW, [2.0], [[3, 0]],
                                          hide)
    assert deep <= shallow


def test_single_moe_layer_pricing_bit_identical():
    """With ONE MoE layer the pipeline has one rung: layer-granularity
    pricing must be bit-identical to whole-expert pricing (unit bytes
    coincide, the schedule is one window)."""
    cfg1 = dataclasses.replace(CFG, num_layers=1)
    assert moe_layer_count(cfg1) == 1
    eb1 = expert_hbm_bytes(cfg1)
    assert expert_hbm_bytes(cfg1, per_layer=True) == eb1
    pl = ExpertPlacement.contiguous(cfg1.num_experts, 1)
    off = pl.offload([2, 3])
    for ns, hide in (([3, 2], 0.0), ([1, 4], 2e-4), ([2, 0], 1e-3)):
        rs_e = ResidencyState(off, cfg1, cap_bytes=2 * eb1 + eb1)
        rs_l = ResidencyState(off, cfg1, cap_bytes=2 * eb1 + eb1,
                              granularity="layer")
        ref = batch_iteration_time(cfg1, HOST_HW, ns, [64, 64],
                                   placement=off, residency=rs_e,
                                   fetch_hide=hide)
        got = batch_iteration_time(cfg1, HOST_HW, ns, [64, 64],
                                   placement=off, residency=rs_l,
                                   fetch_hide=[hide])
        for k in ("t_iter", "t_fetch", "t_fetch_unhidden", "fetch_bytes"):
            assert ref[k] == got[k], k
        assert ref["fetch_miss"] == got["fetch_miss"]


def test_multi_layer_measured_counts_price_identically():
    """Measured integer misses under a FLAT scalar window: m whole
    experts == m slices in every MoE layer, priced bit-identically
    ((n_l * m) * per_layer_bytes == m * whole_bytes exactly — both
    integer-valued floats)."""
    off = _tiered(1, host=[2, 3])
    rs_e = ResidencyState(off, CFG, cap_bytes=2 * EB + EB)
    rs_l = ResidencyState(off, CFG, cap_bytes=2 * EB + EB,
                          granularity="layer")
    for m, hide in ((1, 0.0), (2, 3e-4), (2, 1e-2)):
        ref = batch_iteration_time(CFG, HOST_HW, [3, 2], [64, 64],
                                   placement=off, residency=rs_e,
                                   per_shard_miss=[m], fetch_hide=hide)
        got = batch_iteration_time(CFG, HOST_HW, [3, 2], [64, 64],
                                   placement=off, residency=rs_l,
                                   per_shard_miss=[[m] * N_L],
                                   fetch_hide=hide)
        # unit counts differ (n_l*m slices vs m experts) but every priced
        # figure coincides bitwise
        for k in ("t_iter", "t_fetch", "t_fetch_unhidden", "fetch_bytes"):
            assert ref[k] == got[k], k
        assert got["t_fetch_by_layer"] == [m * EBL / HOST_HW.host_bw] * N_L


@settings(max_examples=40, deadline=None)
@given(ns=st.lists(st.integers(0, 9), min_size=1, max_size=4),
       slots_b=st.integers(0, 2), base=st.floats(0.0, 1e-3),
       basis=st.floats(0.0, 5e-3), shards=st.integers(1, 2),
       hw_i=st.integers(0, 3))
def test_oracle_matches_layered_pricing(ns, slots_b, base, basis, shards,
                                        hw_i):
    """The float-exactness contract at layer granularity, across the four
    hardware regimes: `BatchCostOracle.t_batch` == `batch_iteration_time`
    t_iter and `fetch_unhidden` == `t_fetch_unhidden` at every allocation
    under a full per-layer hide schedule (shared `fetch_time_layered`)."""
    hw = HWS[hw_i]
    host = [2, 3] if shards == 1 else [3]
    off = _tiered(shards, host=host)
    rs = ResidencyState(off, CFG, granularity="layer",
                        cap_bytes=[c * EB + (slots_b * EB
                                             if s == shards - 1 else 0.0)
                                   for s, c in
                                   enumerate(off.resident_counts)])
    sched = fetch_hide_schedule(CFG, base, basis)
    ctx = [64] * len(ns)
    orc = BatchCostOracle(CFG, hw, ctx, placement=off, residency=rs,
                          fetch_hide=sched)
    ref = batch_iteration_time(CFG, hw, ns, ctx, placement=off,
                               residency=rs, fetch_hide=sched)
    assert orc.t_batch(ns) == ref["t_iter"]
    assert orc.fetch_unhidden(ns) == ref["t_fetch_unhidden"]
    assert np.isfinite(ref["t_iter"])


# ===================================================================== #
# Engine: layered prefetch pipeline
# ===================================================================== #

def _run_sched(cfg, params, residency, *, n_req=4, max_batch=3,
               prefetch=True, **engine_kw):
    engine_kw.setdefault("max_len", 256)
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                        max_batch=max_batch, temperature=0.0,
                        clock="model", seed=0, residency=residency,
                        prefetch=prefetch, **engine_kw)
    sched = ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController())
    reqs = [Request(request_id=f"r{i}", prompt=[3 + i, 4 + i, 5 + i] * 6,
                    max_new=10 + 2 * i) for i in range(n_req)]
    res = sched.run(reqs)
    return res, eng


LAYER_ONLY_FIELDS = ("t_fetch_by_layer", "prefetch_hits_by_layer",
                     "prefetch_misses_by_layer")


def _strip_layer_fields(step):
    d = dataclasses.asdict(step)
    for k in LAYER_ONLY_FIELDS:
        d.pop(k)
    return d


@pytest.fixture(scope="module")
def one_layer_moe():
    cfg = dataclasses.replace(CFG, num_layers=1)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("max_batch", [1, 4])
def test_engine_single_moe_layer_granularity_bit_identity(one_layer_moe,
                                                          max_batch):
    """With one MoE layer the layered pipeline degenerates to the
    whole-expert engine: token streams AND per-step telemetry must be
    bit-identical (double_buffer=False pins the window to the step's own
    work — the whole-expert contract; the per-layer tuple fields are the
    only new telemetry)."""
    cfg, params = one_layer_moe
    off = ExpertPlacement.contiguous(cfg.num_experts, 1).offload([2, 3])
    eb = expert_hbm_bytes(cfg)
    cap = 2 * eb + eb
    r_e, e_e = _run_sched(cfg, params,
                          ResidencyState(off, cfg, cap_bytes=cap),
                          max_batch=max_batch)
    r_l, e_l = _run_sched(cfg, params,
                          ResidencyState(off, cfg, cap_bytes=cap,
                                         granularity="layer"),
                          max_batch=max_batch, double_buffer=False)
    assert [r.tokens for r in r_e] == [r.tokens for r in r_l]
    assert len(e_e.telemetry.steps) == len(e_l.telemetry.steps)
    for a, b in zip(e_e.telemetry.steps, e_l.telemetry.steps):
        assert _strip_layer_fields(a) == _strip_layer_fields(b)
    for ra, rb in zip(r_e, r_l):
        assert ra.telemetry.iterations == rb.telemetry.iterations
        assert ra.telemetry.ttft == rb.telemetry.ttft


def test_engine_all_hbm_layer_residency_invisible(tiny_moe):
    """A layer-granularity residency over an all-hbm placement must leave
    the engine bit-identical to residency=None — every telemetry field,
    the per-layer tuples at their empty defaults."""
    cfg, params = tiny_moe
    pl = ExpertPlacement.contiguous(cfg.num_experts, 1)
    r_none, e_none = _run_sched(cfg, params, None)
    r_l, e_l = _run_sched(cfg, params,
                          ResidencyState(pl, cfg, granularity="layer"))
    assert [r.tokens for r in r_none] == [r.tokens for r in r_l]
    for a, b in zip(e_none.telemetry.steps, e_l.telemetry.steps):
        assert a == b                            # full dataclass equality
    assert all(s.t_fetch_by_layer == () for s in e_l.telemetry.steps)


def test_engine_layered_telemetry_and_lossless(tiny_moe):
    """Layer-granularity streaming under a miss-forcing cap: token
    streams stay lossless vs the residency-free engine (the tier changes
    pricing, never routing), and the per-layer telemetry is populated
    consistently with the flat counters."""
    cfg, params = tiny_moe
    pl = ExpertPlacement.contiguous(cfg.num_experts, 1)
    eb = expert_hbm_bytes(cfg)
    off = pl.offload([cfg.num_experts - 2, cfg.num_experts - 1])
    cap = (cfg.num_experts - 2) * eb + eb
    r_ref, _ = _run_sched(cfg, params, None)
    rs = ResidencyState(off, cfg, cap_bytes=cap, granularity="layer")
    r_l, e_l = _run_sched(cfg, params, rs)
    assert [r.tokens for r in r_ref] == [r.tokens for r in r_l]
    n_l = moe_layer_count(cfg)
    steps = [s for s in e_l.telemetry.steps if s.prefetch_hits_by_layer]
    assert steps, "no offloaded decode step produced layer telemetry"
    for s in steps:
        assert len(s.prefetch_hits_by_layer) == n_l
        assert sum(s.prefetch_hits_by_layer) == s.prefetch_hits
        assert sum(s.prefetch_misses_by_layer) == s.prefetch_misses
        if s.t_fetch_by_layer:
            assert len(s.t_fetch_by_layer) == n_l
            assert all(t >= 0.0 for t in s.t_fetch_by_layer)
    assert e_l.telemetry.fetch_bytes > 0
    snap = rs.snapshot()
    assert snap["bytes_fetched"] == pytest.approx(e_l.telemetry.fetch_bytes)


def test_engine_layered_beats_whole_expert_under_miss_cap(tiny_moe):
    """The tentpole's payoff, in-repo scale: with EVERY expert demoted to
    the host tier under a miss-forcing cap, layer-granularity streaming
    hides strictly more fetch than whole-expert streaming (deep layers'
    slices overlap the shallow layers' compute) — higher tokens/s, lower
    unhidden fetch — at B in {2, 4} (the --overlap-sweep gate's regime,
    reduced)."""
    cfg, params = tiny_moe
    eb = expert_hbm_bytes(cfg)
    pl = ExpertPlacement.contiguous(cfg.num_experts, 1)
    tiered = pl.offload(list(range(cfg.num_experts)))
    cap = 2 * eb
    rng = np.random.default_rng(11)

    def reqs(n, max_new=16):
        out = []
        for i in range(n):
            period = 4 + 2 * (i % 3)
            pat = [int(x) for x in rng.integers(3, cfg.vocab_size, period)]
            out.append(Request(request_id=f"r{i}",
                               prompt=pat * (32 // period),
                               max_new=max_new))
        return out

    def run(b, gran):
        rs = ResidencyState(tiered, cfg, cap_bytes=cap, granularity=gran)
        eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                            max_batch=b, max_len=256, temperature=0.0,
                            clock="model", seed=0, residency=rs,
                            prefetch=True, hw=HOST_HW, chunk=16)
        sched = ContinuousBatchingScheduler(
            eng, controller_factory=lambda: CascadeController())
        sched.run(reqs(2 * b))
        unhid = sum(s.t_fetch for s in eng.telemetry.steps)
        return sched.tokens_per_second(), unhid

    for b in (2, 4):
        tps_e, unhid_e = run(b, "expert")
        tps_l, unhid_l = run(b, "layer")
        assert unhid_l < unhid_e, \
            f"B={b}: layered unhidden fetch {unhid_l} !< {unhid_e}"
        assert tps_l > tps_e, \
            f"B={b}: layered {tps_l} tok/s !> whole-expert {tps_e}"


def test_engine_fetch_hide_repriced_after_churn(tiny_moe):
    """Regression (this PR's bugfix): the whole-expert engine's prefetch
    window used the PREVIOUS pass's t_iter for the pre-MoE compute
    credit, overstating the hide budget right after membership churn
    (retirements shrink the batch, the stale bigger pass inflates the
    window). The window must reprice from THIS pass's predicted base:
    fetch_hide <= t_overhead + pre_moe_frac * t_base_predicted, always."""
    cfg, params = tiny_moe
    eb = expert_hbm_bytes(cfg)
    pl = ExpertPlacement.contiguous(cfg.num_experts, 1)
    off = pl.offload([cfg.num_experts - 2, cfg.num_experts - 1])
    rs = ResidencyState(off, cfg, cap_bytes=2 * eb + eb)
    _, eng = _run_sched(cfg, params, rs, n_req=6, max_batch=4,
                        hw=HOST_HW)
    pre = moe_hide_fracs(cfg)[0]
    steps = [s for s in eng.telemetry.steps if s.planned]
    assert steps
    for s in steps:
        assert s.fetch_hide <= \
            s.t_overhead + pre * s.t_base_predicted + 1e-12
    # teeth: some step follows a strictly longer pass (the stale-window
    # bug inflates exactly these) AND prices its window uncapped — under
    # the old code that step's window would have exceeded the bound
    churned = [i for i in range(1, len(steps))
               if steps[i - 1].t_total > steps[i].t_base_predicted + 1e-9
               and abs(steps[i].fetch_hide - steps[i].t_overhead
                       - pre * steps[i].t_base_predicted) < 1e-15]
    assert churned, "no uncapped post-churn step — the regression " \
                    "assertion never engaged"


# ===================================================================== #
# Satellite: drafter precision pricing
# ===================================================================== #

INT8_DRAFTER = Precision(dense=1, expert=2, kv=2, label="int8-drafter")


def test_draft_time_precision_pricing():
    hw = HOST_HW
    ap = 10_000_000
    # None is bit-identical to Precision.DEFAULT
    assert draft_time(hw, 4, ap) == \
        draft_time(hw, 4, ap, precision=Precision.DEFAULT)
    # int8 dense class halves the model term exactly
    base = draft_time(hw, 4, ap)
    q = draft_time(hw, 4, ap, precision=INT8_DRAFTER)
    overhead = draft_time(hw, 4, 0)
    assert q - overhead == (base - overhead) / 2
    # an explicit wb byte width overrides the precision class
    assert draft_time(hw, 4, ap, wb=2, precision=INT8_DRAFTER) == base
    # zero-weight drafters (n-gram) are precision-blind
    assert draft_time(hw, 4, 0, precision=INT8_DRAFTER) == \
        draft_time(hw, 4, 0)
    assert draft_time(hw, 0, ap, precision=INT8_DRAFTER) == 0.0


def _weighted_ngram():
    d = NGramDrafter()
    d.active_params = 10_000_000       # price the table like real weights
    return d


def test_serving_engine_drafter_precision(tiny_moe):
    cfg, params = tiny_moe
    bf = ServingEngine(cfg, params, _weighted_ngram(), max_len=128,
                       clock="model", seed=0)
    q = ServingEngine(cfg, params, _weighted_ngram(), max_len=128,
                      clock="model", seed=0,
                      drafter_precision=INT8_DRAFTER)
    assert bf._draft_time(4) == draft_time(bf.hw, 4, 10_000_000)
    assert q._draft_time(4) == \
        draft_time(q.hw, 4, 10_000_000, precision=INT8_DRAFTER)
    assert q._draft_time(4) < bf._draft_time(4)


def test_batched_engine_drafter_precision_threading(tiny_moe):
    """An int8 drafter shrinks every step's draft overhead on the model
    clock; token streams are untouched (precision prices, never routes).
    The engine rejects a planner priced at a different drafter
    precision — a planner predicting bf16 draft windows against an int8
    engine would misprice every fetch deadline."""
    cfg, params = tiny_moe

    def run(precision):
        eng = BatchedEngine(cfg, params, _weighted_ngram, max_batch=2,
                            max_len=256, temperature=0.0, clock="model",
                            seed=0, drafter_precision=precision)
        sched = ContinuousBatchingScheduler(
            eng, controller_factory=lambda: CascadeController())
        reqs = [Request(request_id=f"r{i}",
                        prompt=[3 + i, 4 + i, 5 + i] * 6, max_new=12)
                for i in range(2)]
        res = sched.run(reqs)
        return res, eng

    r_bf, e_bf = run(None)
    r_q, e_q = run(INT8_DRAFTER)
    assert [r.tokens for r in r_bf] == [r.tokens for r in r_q]
    ov_bf = sum(s.t_overhead for s in e_bf.telemetry.steps
                if s.k_granted > 0)
    ov_q = sum(s.t_overhead for s in e_q.telemetry.steps
               if s.k_granted > 0)
    assert 0.0 < ov_q < ov_bf
    # planner/engine precision consistency is enforced loudly
    mismatched = BatchSpecPlanner(cfg, drafter_precision=None)
    with pytest.raises(ValueError):
        BatchedEngine(cfg, params, _weighted_ngram, max_batch=1,
                      max_len=128, drafter_precision=INT8_DRAFTER,
                      planner=mismatched)
    matched = BatchSpecPlanner(cfg, drafter_precision=INT8_DRAFTER)
    BatchedEngine(cfg, params, _weighted_ngram, max_batch=1, max_len=128,
                  drafter_precision=INT8_DRAFTER, planner=matched)
