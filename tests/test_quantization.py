"""Quantized expert paths (docs/quantization.md): the `Precision` spec's
bit-exact degradation contract across hardware regimes (precision=None
must price float-identically to `Precision()` everywhere, and the engine
must emit identical streams and telemetry with quantization off), the
int8 dequant-in-kernel numerics (error bounded by the absmax scale and
scaling with the calibration quantile, dead slots exactly zero,
non-divisible tiles, scale recovery), the quantized storage format
through `apply_moe`/`quantize_transformer_experts`, and the
`ResidencyState` HBM-cap validation against `Hardware.hbm_bytes`."""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (BatchCostOracle, ExpertPlacement, Hardware,
                        Precision, ResidencyState, TPU_V5E, RTX_6000_ADA,
                        batch_iteration_time, expert_hbm_bytes,
                        iteration_bytes)
from repro.core.cost_model import prefill_crossover_tokens
from repro.kernels.moe_gmm import (dequantize_int8, fake_quant_fp8,
                                   fit_expert_scales,
                                   fit_expert_scales_from_batches,
                                   moe_gmm_fused, moe_gmm_fused_quant,
                                   moe_gmm_fused_quant_ref, quantize_int8,
                                   quantize_moe_experts)

RNG = np.random.default_rng(7)

CFG = get_config("mixtral-8x7b").reduced()

#: the degradation contract must hold in every pricing regime, not just
#: the presets: memory-starved and flops-starved corners included
HARDWARES = [
    TPU_V5E,
    RTX_6000_ADA,
    Hardware("mem-starved", hbm_bw=1e9, peak_flops=1e13, ici_bw=5e8),
    Hardware("flops-starved", hbm_bw=1e12, peak_flops=1e9, ici_bw=5e8),
]


def _quant_inputs(u, c, d, f, activation="swiglu", scale=1.0):
    counts = RNG.integers(0, c + 1, u).astype(np.int32)
    x = RNG.normal(0, 1, (u, c, d)).astype(np.float32)
    for i, n in enumerate(counts):
        x[i, n:] = 0.0
    w = lambda *s: RNG.normal(0, scale, s).astype(np.float32)
    wg = jnp.asarray(w(u, d, f)) if activation == "swiglu" else None
    wu, wd = jnp.asarray(w(u, d, f)), jnp.asarray(w(u, f, d))
    return jnp.asarray(x), wg, wu, wd, jnp.asarray(counts)


def _quantized(wg, wu, wd, quantile=1.0):
    qg, sg = (quantize_int8(wg, quantile=quantile) if wg is not None
              else (None, None))
    qu, su = quantize_int8(wu, quantile=quantile)
    qd, sd = quantize_int8(wd, quantile=quantile)
    return qg, qu, qd, sg, su, sd


# ===================================================================== #
# Precision spec + bit-exact degradation of the pricing layer
# ===================================================================== #

def test_precision_spec():
    p = Precision()
    assert (p.dense, p.expert, p.kv) == (2, 2, 2)
    assert not p.quantized_experts
    i8 = Precision.int8_experts()
    f8 = Precision.fp8_experts()
    assert i8.expert == f8.expert == 1
    assert i8.dense == i8.kv == 2          # only experts quantize
    assert i8.quantized_experts and f8.quantized_experts
    assert i8.label != f8.label            # telemetry tags differ...
    assert Precision.DEFAULT == Precision()
    with pytest.raises(Exception):         # frozen
        p.expert = 1


@pytest.mark.parametrize("hw", HARDWARES, ids=lambda h: h.name)
def test_default_precision_prices_float_identical(hw):
    """precision=None and Precision() must agree on every float the batch
    pricing emits, in every regime — the int defaults substitute for the
    old wb=2 literals in the same float-op order, so equality is exact,
    not approximate."""
    ns, ctxs = [3, 1, 5], [100, 900, 40]
    base = batch_iteration_time(CFG, hw, ns, ctxs, affinity=0.2)
    expl = batch_iteration_time(CFG, hw, ns, ctxs, affinity=0.2,
                                precision=Precision())
    for k, v in base.items():
        if isinstance(v, float):
            assert expl[k] == v, f"{k} drifted under explicit default"
    assert expl["precision"] == "bf16"
    assert expl["expert_bytes_saved"] == 0.0

    o0 = BatchCostOracle(CFG, hw, ctxs, affinity=0.2)
    o1 = BatchCostOracle(CFG, hw, ctxs, affinity=0.2,
                         precision=Precision())
    assert o0.t_batch(ns) == o1.t_batch(ns)


def test_legacy_wb_override_equals_uniform_precision():
    """The legacy `wb` int resolves to a uniform Precision — byte helpers
    must price both spellings identically."""
    b_wb = iteration_bytes(CFG, 4, 512, wb=1)
    b_pr = iteration_bytes(CFG, 4, 512, precision=Precision(1, 1, 1))
    assert b_wb["total"] == b_pr["total"]


def test_int8_halves_expert_bytes_and_shifts_crossover():
    hw = Hardware("roofline", hbm_bw=1e9, peak_flops=1e10, ici_bw=5e8)
    i8 = Precision.int8_experts()
    bf = batch_iteration_time(CFG, hw, [4], [256])
    q8 = batch_iteration_time(CFG, hw, [4], [256], precision=i8)
    assert q8["expert_bytes"] == bf["expert_bytes"] / 2
    # saved == the bytes the pass did NOT move vs bf16 storage (exact)
    assert q8["expert_bytes_saved"] == q8["expert_bytes"]
    assert q8["t_iter"] <= bf["t_iter"]
    # widened to 8 experts so expert bytes dominate the chunk enough for
    # the halving to cross a pow-2 bucket (the stock reduced E=4 shifts
    # 29 -> 23 tokens, invisible at pow-2 resolution)
    import dataclasses
    wide = dataclasses.replace(CFG, num_experts=8)
    xo_bf = prefill_crossover_tokens(wide, hw)
    xo_i8 = prefill_crossover_tokens(wide, hw, precision=i8)
    assert xo_i8 < xo_bf  # fewer bytes, same FLOPs: crossover moves left


def test_engine_stream_identity_quant_off():
    """BatchedEngine(precision=None) vs explicit Precision(): identical
    token streams AND per-step telemetry — quantization off is the
    pre-quantization engine, bit for bit."""
    from repro.models import transformer as T
    from repro.serving import BatchedEngine
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    prompts = [[5, 6, 7, 8] * 4, [9, 3, 1] * 5]

    def run(precision):
        eng = BatchedEngine(CFG, params, max_batch=2, chunk=4, seed=3,
                            precision=precision)
        idxs = [eng.join(p, max_new=8) for p in prompts]
        while eng.active_slots:
            eng.step()
        toks = [eng.retire(i).tokens for i in idxs]
        tel = [(s.t_step, s.t_step_predicted, s.union_experts,
                s.precision, s.expert_bytes_saved)
               for s in eng.telemetry.steps]
        return toks, tel

    t0, tel0 = run(None)
    t1, tel1 = run(Precision())
    assert t0 == t1
    assert tel0 == tel1
    assert all(s[4] == 0.0 for s in tel0)


def test_engine_rejects_contradicting_planner_precision():
    from repro.core import BatchSpecPlanner, PlannerConfig
    from repro.models import transformer as T
    from repro.serving import BatchedEngine
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    pl = BatchSpecPlanner(CFG, TPU_V5E,
                          config=PlannerConfig(policy="joint"))
    with pytest.raises(ValueError):
        BatchedEngine(CFG, params, planner=pl,
                      precision=Precision.int8_experts())
    # None vs explicit default is NOT a contradiction
    pl2 = BatchSpecPlanner(CFG, TPU_V5E,
                           config=PlannerConfig(policy="joint"),
                           precision=Precision())
    BatchedEngine(CFG, params, planner=pl2)


# ===================================================================== #
# int8 kernel numerics
# ===================================================================== #

def test_int8_roundtrip_error_bounded_by_scale():
    """Round-to-nearest symmetric quantization: |dequant - w| <= scale/2
    per element at quantile=1.0 (no clipping)."""
    w = jnp.asarray(RNG.normal(0, 0.3, (5, 16, 8)), jnp.float32)
    q, s = quantize_int8(w)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(w))
    bound = np.asarray(s).reshape(-1, 1, 1) / 2 + 1e-7
    assert (err <= bound).all()


def test_quant_kernel_matches_quant_ref_exactly():
    """Kernel (interpret) vs oracle on quantized weights: both compute
    x @ (q * s) in f32, so parity is tight — including non-divisible
    C/F under small tiles (the tile-padding regression under quant)."""
    for u, c, d, f, act in [(5, 7, 8, 8, "swiglu"), (3, 10, 12, 20, "gelu"),
                            (1, 8, 16, 16, "swiglu")]:
        x, wg, wu, wd, counts = _quant_inputs(u, c, d, f, act)
        qg, qu, qd, sg, su, sd = _quantized(wg, wu, wd)
        y_ref = moe_gmm_fused_quant_ref(qg, qu, qd, sg, su, sd,
                                        counts, activation=act) \
            if False else moe_gmm_fused_quant_ref(
                x, qg if act == "swiglu" else qu, qu, qd,
                sg if act == "swiglu" else su, su, sd, counts,
                activation=act)
        y_k = moe_gmm_fused_quant(x, qg if act == "swiglu" else qu,
                                  qu, qd,
                                  sg if act == "swiglu" else su, su, sd,
                                  counts, activation=act,
                                  backend="interpret", bc=8, bf=8)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                                   atol=1e-5)


def test_quant_kernel_error_scales_with_quantile():
    """vs the bf16 kernel, int8 error is small at quantile=1.0 and grows
    as the calibration quantile clips harder — the outlier-robustness
    trade the calibration helpers expose."""
    x, wg, wu, wd, counts = _quant_inputs(4, 16, 24, 24, "swiglu",
                                          scale=0.5)
    y_bf = moe_gmm_fused(x, wg, wu, wd, counts, backend="ref")
    errs = []
    for q in (1.0, 0.8, 0.5):
        qg, qu, qd, sg, su, sd = _quantized(wg, wu, wd, quantile=q)
        y_q = moe_gmm_fused_quant(x, qg, qu, qd, sg, su, sd, counts,
                                  backend="ref")
        errs.append(float(jnp.abs(y_q - y_bf).max()))
    ref_mag = float(jnp.abs(y_bf).max())
    assert errs[0] < 0.05 * ref_mag       # absmax: faithful
    assert errs[0] < errs[1] < errs[2]    # clipping harder -> worse


def test_quant_kernel_dead_slots_exact_zero():
    x, wg, wu, wd, _ = _quant_inputs(4, 8, 16, 8)
    counts = jnp.asarray([0, 8, 0, 3], jnp.int32)
    x = x.at[0].set(0).at[2].set(0).at[3, 3:].set(0)
    qg, qu, qd, sg, su, sd = _quantized(wg, wu, wd)
    y = moe_gmm_fused_quant(x, qg, qu, qd, sg, su, sd, counts,
                            backend="interpret", bc=8, bf=8)
    assert float(jnp.abs(y[0]).max()) == 0.0
    assert float(jnp.abs(y[2]).max()) == 0.0
    assert float(jnp.abs(y[1]).max()) > 0.0


def test_scale_calibration_recovers_grid_weights():
    """Weights already on an int8 grid round-trip exactly, and the fitted
    scale equals the constructing one (absmax hits 127 * s)."""
    s_true = np.asarray([0.01, 0.05, 0.002], np.float32)
    q_true = RNG.integers(-127, 128, (3, 8, 4)).astype(np.float32)
    q_true[:, 0, 0] = 127.0  # pin the absmax so the scale is identified
    w = jnp.asarray(q_true * s_true.reshape(-1, 1, 1))
    s_fit = fit_expert_scales(w)
    np.testing.assert_allclose(np.asarray(s_fit), s_true, rtol=1e-6)
    q, s = quantize_int8(w)
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s)),
                               np.asarray(w), atol=1e-7)


def test_scale_fit_from_batches_pools_max():
    a = jnp.asarray(RNG.normal(0, 0.1, (2, 8)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.5, (2, 8)), jnp.float32)
    pooled = fit_expert_scales_from_batches([a, b])
    expect = jnp.maximum(fit_expert_scales(a), fit_expert_scales(b))
    np.testing.assert_array_equal(np.asarray(pooled), np.asarray(expect))
    with pytest.raises(ValueError):
        fit_expert_scales_from_batches([])
    with pytest.raises(ValueError):
        fit_expert_scales(a, quantile=0.0)


def test_fp8_fake_quant_idempotent():
    w = jnp.asarray(RNG.normal(0, 1, (4, 8)), jnp.float32)
    w1 = fake_quant_fp8(w)
    np.testing.assert_array_equal(np.asarray(fake_quant_fp8(w1)),
                                  np.asarray(w1))
    assert w1.dtype == w.dtype
    assert float(jnp.abs(w1 - w).max()) > 0.0  # it did quantize


# ===================================================================== #
# Quantized storage through the model layer
# ===================================================================== #

def test_quantize_moe_experts_storage_contract():
    from repro.models import moe
    p = moe.init_moe(CFG, jax.random.PRNGKey(1), jnp.float32)
    q = quantize_moe_experts(p)
    for name in ("w_gate", "w_up", "w_down"):
        assert name not in q                  # originals deleted
        assert q[name + "_q8"].dtype == jnp.int8
        assert q[name + "_s"].shape == (CFG.num_experts,)
    assert "router" in q                      # router untouched
    f8 = quantize_moe_experts(p, mode="fp8")
    assert f8["w_up"].dtype == p["w_up"].dtype
    with pytest.raises(ValueError):
        quantize_moe_experts({"router": p["router"]})
    with pytest.raises(ValueError):
        quantize_moe_experts(p, mode="int4")


def test_apply_moe_quant_paths_agree():
    """Packed-quant (gathered int8 + inline dequant) and dense-quant
    (dequant up front) must agree exactly; both sit within the
    quantization error of the bf16 path."""
    from repro.models import moe
    p = moe.init_moe(CFG, jax.random.PRNGKey(1), jnp.float32)
    q = quantize_moe_experts(p)
    x = jnp.asarray(RNG.normal(0, 1, (6, CFG.d_model)), jnp.float32)
    y_bf, _ = moe.apply_moe(CFG, p, x, capacity_policy="exact")
    y_qd, _ = moe.apply_moe(CFG, q, x, capacity_policy="exact")
    y_qp, _ = moe.apply_moe(CFG, q, x, capacity_policy="exact",
                            packed=True)
    np.testing.assert_array_equal(np.asarray(y_qd), np.asarray(y_qp))
    err = float(jnp.abs(y_qd - y_bf).max())
    assert 0.0 < err < 0.1 * float(jnp.abs(y_bf).max()) + 1e-3


def test_quantize_transformer_experts_slices_like_scan():
    """Per-layer slices of the stacked quantization must equal quantizing
    that layer's dict directly — the lax.scan contract."""
    from repro.models import transformer as T
    from repro.models.moe import quantize_transformer_experts
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    qp = quantize_transformer_experts(params)
    moe_p = params["blocks"]["moe"]
    moe_q = qp["blocks"]["moe"]
    lyr = 0
    per_layer = quantize_moe_experts(
        {k: v[lyr] for k, v in moe_p.items()})
    for k in ("w_up_q8", "w_up_s", "w_down_q8", "w_down_s"):
        np.testing.assert_array_equal(np.asarray(moe_q[k][lyr]),
                                      np.asarray(per_layer[k]))
    assert "w_up" not in moe_q
    with pytest.raises(ValueError):
        quantize_transformer_experts({"blocks": {}})


# ===================================================================== #
# ResidencyState vs Hardware.hbm_bytes (cap-validation bugfix)
# ===================================================================== #

def _host_placement():
    return ExpertPlacement.contiguous(CFG.num_experts, 1).offload(
        [CFG.num_experts - 1])


def test_residency_cap_defaults_to_hw_hbm():
    hw = Hardware("cap-test", hbm_bw=1e9, peak_flops=1e10,
                  hbm_bytes=8 * expert_hbm_bytes(CFG))
    rs = ResidencyState(_host_placement(), CFG, hw=hw)
    assert rs.cap_bytes == [float(hw.hbm_bytes)]
    # without hw, unset cap stays uncapped (legacy behavior)
    rs0 = ResidencyState(_host_placement(), CFG)
    assert rs0.cap_bytes == [None]


def test_residency_cap_over_hbm_warns_and_strict_raises():
    # _host_placement pins 3 experts in HBM, so caps must sit at or
    # above 3*eb to pass the pinned-footprint check.
    eb = expert_hbm_bytes(CFG)
    hw = Hardware("cap-test", hbm_bw=1e9, peak_flops=1e10,
                  hbm_bytes=4 * eb)
    with pytest.warns(UserWarning, match="exceeds"):
        ResidencyState(_host_placement(), CFG, cap_bytes=6 * eb, hw=hw)
    with pytest.raises(ValueError, match="exceeds"):
        ResidencyState(_host_placement(), CFG, cap_bytes=6 * eb, hw=hw,
                       strict=True)
    # a cap the device can hold is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ResidencyState(_host_placement(), CFG, cap_bytes=3.5 * eb, hw=hw)


def test_residency_precision_halves_footprint():
    i8 = Precision.int8_experts()
    assert expert_hbm_bytes(CFG, precision=i8) == expert_hbm_bytes(CFG) / 2
    # 3 pinned bf16 experts leave no slack at 3.5*eb, but the int8
    # pinned footprint is half, so the same byte cap admits the host
    # expert as a cache resident.
    cap = 3.5 * expert_hbm_bytes(CFG)
    rs_bf = ResidencyState(_host_placement(), CFG, cap_bytes=cap)
    rs_i8 = ResidencyState(_host_placement(), CFG, cap_bytes=cap,
                           precision=i8)
    assert rs_i8.expert_bytes == rs_bf.expert_bytes / 2
    assert rs_bf._slots == [0]
    assert rs_i8._slots == [1]
