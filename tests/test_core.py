"""Cascade core: utility math (Theorem 4.2), manager FSM behaviour
(disable / back-off / hill-climb / early exits), and cost-model properties.
Property-based tests use hypothesis."""

import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic in-repo fallback (requirements-dev.txt)
    from tests._hypothesis_compat import given, settings, st

from repro.core import (CascadeConfig, CascadeController, IterationRecord,
                        SpeculationManager, UtilityAnalyzer, TPU_V5E,
                        batch_iteration_time, expected_unique_experts,
                        iteration_bytes, iteration_time)
from repro.core.manager import BASELINE, SET, TEST
from repro.configs import get_config


# ===================================================================== #
# Theorem 4.2: t_spec = t_base / U
# ===================================================================== #

@settings(max_examples=200, deadline=None)
@given(etr=st.floats(1.0, 8.0), cost=st.floats(0.2, 5.0),
       t_base=st.floats(1e-4, 1.0))
def test_theorem_4_2(etr, cost, t_base):
    """TPOT under speculation equals TPOT_base / utility, exactly."""
    t_iter_spec = t_base * cost
    tpot_spec = t_iter_spec / etr
    utility = etr / cost
    assert math.isclose(tpot_spec, t_base / utility, rel_tol=1e-9)


@settings(max_examples=50, deadline=None)
@given(tokens=st.lists(st.integers(1, 8), min_size=8, max_size=40),
       cost=st.floats(0.5, 3.0))
def test_analyzer_utility_equals_measured_speedup(tokens, cost):
    """Windowed analyzer utility must equal the measured TPOT ratio when
    ETR/cost are stationary (the empirical Thm 4.2 check)."""
    t_base = 1.0
    an = UtilityAnalyzer(window=len(tokens) + 8)
    for _ in range(4):
        an.observe(IterationRecord(k=0, tokens=1, t_iter=t_base))
    for n in tokens:
        an.observe(IterationRecord(k=3, tokens=n, t_iter=t_base * cost))
    etr = sum(tokens) / len(tokens)
    u = an.utility(n=len(tokens), k=3)
    tpot_spec = (t_base * cost) / etr
    assert math.isclose(u, t_base / tpot_spec, rel_tol=1e-6)


@settings(max_examples=25, deadline=None)
@given(ks=st.lists(st.integers(1, 6), min_size=2, max_size=4),
       m=st.integers(6, 20), aff=st.floats(0.0, 0.9))
def test_theorem_4_2_under_batching(ks, m, aff):
    """Theorem 4.2 survives continuous batching per request: when a
    request's iteration time is its *attributed share* of the shared pass
    (the cost model's marginal-bytes split), its measured TPOT still
    equals its attributed baseline TPOT divided by its windowed utility —
    the invariant that makes per-request Cascade control meaningful at
    B>1, and that the batch planner's water level is calibrated against."""
    cfg = get_config("mixtral-8x7b")
    b = len(ks)
    ctxs = [128 * (i + 1) for i in range(b)]
    base = batch_iteration_time(cfg, TPU_V5E, [1] * b, ctxs, affinity=aff)
    spec = batch_iteration_time(cfg, TPU_V5E, [k + 1 for k in ks], ctxs,
                                affinity=aff)
    for i in range(b):
        t_base_i = base["per_request"][i]["t_attr"]
        t_spec_i = spec["per_request"][i]["t_attr"]
        tokens_i = 1 + (ks[i] + i) % (ks[i] + 1)   # 1..k_i+1 emissions
        an = UtilityAnalyzer(window=m + 8)
        for _ in range(4):
            an.observe(IterationRecord(k=0, tokens=1, t_iter=t_base_i,
                                       batch=b))
        for _ in range(m):
            an.observe(IterationRecord(k=ks[i], tokens=tokens_i,
                                       t_iter=t_spec_i, t_verify=t_spec_i,
                                       batch=b))
        u = an.utility(n=m, k=ks[i])
        tpot_spec = t_spec_i / tokens_i
        assert math.isclose(tpot_spec, t_base_i / u, rel_tol=1e-6)


# ===================================================================== #
# Manager FSM
# ===================================================================== #

def drive(mgr, k_to_util, iters, t_base=1.0):
    """Drive the manager with a deterministic utility landscape:
    k -> (etr, cost) chosen so utility(k) = k_to_util(k)."""
    seq = []
    for _ in range(iters):
        k = mgr.next_k()
        if k == 0:
            mgr.observe(IterationRecord(k=0, tokens=1, t_iter=t_base))
        else:
            u = k_to_util(k)
            cost = 2.0
            toks = max(1, round(u * cost))
            # recompute cost so utility is exact despite integer tokens
            cost = toks / u
            mgr.observe(IterationRecord(k=k, tokens=toks,
                                        t_iter=t_base * cost))
        seq.append((k, mgr.phase))
    return seq


def test_manager_disables_when_utility_below_one():
    mgr = SpeculationManager(cfg=CascadeConfig())
    drive(mgr, lambda k: 0.5, 40)
    # after baseline+test it must park at K=0 in set phases
    ks = [mgr.next_k()]
    assert mgr.phase == SET
    assert ks[0] == 0


def test_manager_backoff_doubles_set_length():
    cfg = CascadeConfig()
    mgr = SpeculationManager(cfg=cfg)
    lens = []
    for _ in range(400):
        k = mgr.next_k()
        was_set = mgr.phase == SET
        drive(mgr, lambda k: 0.4, 1)
        if mgr.phase == SET and not was_set:
            lens.append(mgr._set_len_now)
    assert len(lens) >= 3
    assert lens[1] >= lens[0] and lens[2] >= lens[1]  # monotone growth
    assert lens[-1] <= cfg.max_set_len
    assert any(b == 2 * a for a, b in zip(lens, lens[1:]))


def test_manager_no_backoff_flag():
    cfg = CascadeConfig(enable_backoff=False)
    mgr = SpeculationManager(cfg=cfg)
    drive(mgr, lambda k: 0.4, 300)
    assert mgr._set_len_now == cfg.set_len


def test_hillclimb_finds_peak():
    """Utility peaked at k=5: hill-climbing should adopt k near 5 for the
    set phase."""
    peak = lambda k: 2.0 - 0.3 * abs(k - 5)  # noqa: E731
    cfg = CascadeConfig(k_start=3, k_max=8)
    mgr = SpeculationManager(cfg=cfg)
    chosen = []
    for _ in range(300):
        k = mgr.next_k()
        if mgr.phase == SET:
            chosen.append(k)
        drive(mgr, peak, 1)
    assert chosen, "never reached a set phase"
    # most set phases should sit at the peak +/- 1
    close = sum(1 for k in chosen if abs(k - 5) <= 1)
    assert close / len(chosen) > 0.5, chosen


def test_hillclimb_early_exit_on_convergence():
    cfg = CascadeConfig()
    mgr = SpeculationManager(cfg=cfg)
    # flat utility: trials converge within 10% -> exit after 2 trials
    drive(mgr, lambda k: 1.5, cfg.baseline_iters)  # baseline
    n_trials = 0
    while mgr.phase == TEST:
        n_trials += 1
        drive(mgr, lambda k: 1.5, cfg.trial_len)
        assert n_trials <= cfg.max_trials
    assert n_trials <= 2


def test_static_mode_fig18_baseline():
    cfg = CascadeConfig(enable_disable=False)
    mgr = SpeculationManager(cfg=cfg)
    drive(mgr, lambda k: 0.5, cfg.baseline_iters + 5)
    assert mgr.next_k() == cfg.k_start  # static K, never disables


def test_k_always_in_range():
    cfg = CascadeConfig(k_max=6)
    mgr = SpeculationManager(cfg=cfg)
    rngs = np.random.default_rng(3)
    for _ in range(500):
        k = mgr.next_k()
        assert 0 <= k <= cfg.k_max
        u = float(rngs.uniform(0.3, 2.5))
        drive(mgr, lambda kk: u, 1)


# ===================================================================== #
# Cost model
# ===================================================================== #

@settings(max_examples=100, deadline=None)
@given(e=st.integers(2, 512), k=st.integers(1, 16), t=st.integers(1, 16),
       aff=st.floats(0.0, 1.0))
def test_expected_unique_experts_bounds(e, k, t, aff):
    k = min(k, e)
    u = expected_unique_experts(e, k, t, aff)
    assert k - 1e-9 <= u <= min(e, k * t) + 1e-6
    # monotone in t at fixed affinity
    assert u <= expected_unique_experts(e, k, t + 1, aff) + 1e-9


def test_unique_experts_matches_paper_example():
    """Paper §2.4: Mixtral at K=7 (8 tokens, top-2 of 8) activates >7 unique
    experts on average under uniform routing (~3.5x data movement)."""
    u = expected_unique_experts(8, 2, 8, affinity=0.0)
    assert 7.0 < u < 8.0


def test_iteration_time_moe_cost_grows_with_inflight_tokens():
    cfg = get_config("mixtral-8x7b")
    t1 = iteration_time(cfg, TPU_V5E, 1, 1024, affinity=0.0)["t_iter"]
    t4 = iteration_time(cfg, TPU_V5E, 4, 1024, affinity=0.0)["t_iter"]
    t8 = iteration_time(cfg, TPU_V5E, 8, 1024, affinity=0.0)["t_iter"]
    assert t1 < t4 < t8
    # paper: 2-3x verification overhead in the K=3..7 range
    assert 1.5 < t8 / t1 < 4.0


def test_iteration_time_dense_cost_flat():
    """Dense models re-read all weights regardless of token count: the
    paper's 'verification is free' baseline."""
    cfg = get_config("stablelm-1.6b")
    t1 = iteration_time(cfg, TPU_V5E, 1, 1024)["t_iter"]
    t8 = iteration_time(cfg, TPU_V5E, 8, 1024)["t_iter"]
    assert t8 / t1 < 1.05


def test_iteration_bytes_mla_cache_small():
    ds = get_config("deepseek-v2-236b")
    b = iteration_bytes(ds, 1, 32768)
    # MLA latent cache read per layer is (512+64)*2 bytes/token
    assert b["kv"] == pytest.approx(
        32768 * (512 + 64) * 2 * ds.num_layers, rel=0.01)


def test_cost_model_k_prior():
    """Beyond-paper: the analytic K prior must be conservative for
    low-affinity MoEs and aggressive for dense models."""
    from repro.core.cost_model import suggest_k_start
    from repro.core import cascade_for_model
    mixtral = get_config("mixtral-8x7b")
    dense = get_config("stablelm-1.6b")
    k_moe = suggest_k_start(mixtral, affinity=0.0, accept_rate=0.5)
    k_dense = suggest_k_start(dense, affinity=0.0, accept_rate=0.5)
    assert k_dense >= k_moe
    assert k_dense >= 5       # dense verification ~free -> speculate deep
    assert 1 <= k_moe <= 4    # MoE expert-activation curve caps it
    ctl = cascade_for_model(mixtral)
    assert ctl.config.k_start == k_moe


def test_slo_constrained_cascade():
    """Beyond-paper: with a tight TPOT SLO, the manager must never settle
    on a K whose measured TPOT violates the bound, even when that K has
    utility > 1."""
    # K=4 has utility 1.6 (best) but cost 2.5 -> TPOT 2.5/4.0=0.625*t_base
    # ... build a landscape where high K is fast-but-bursty: utility grows
    # with K but iteration time (cost) grows too; SLO excludes K >= 3.
    def util(k):
        return 1.0 + 0.15 * k          # utility increasing in K

    def run(slo):
        cfg = CascadeConfig(slo_tpot=slo)
        mgr = SpeculationManager(cfg=cfg)
        chosen = []
        for _ in range(400):
            k = mgr.next_k()
            if mgr.phase == SET:
                chosen.append(k)
            if k == 0:
                mgr.observe(IterationRecord(k=0, tokens=1, t_iter=1.0))
            else:
                u = util(k)
                cost = 1.0 + 0.5 * k          # t_iter grows with K
                toks = max(1, round(u * cost))
                cost = toks / u
                mgr.observe(IterationRecord(k=k, tokens=toks,
                                            t_iter=cost))
        return chosen

    unconstrained = run(None)
    assert max(unconstrained) >= 5      # climbs high without SLO
    # SLO: per-iteration TPOT estimate = cost/toks = 1/util(k);
    # require TPOT <= 0.87 => util >= 1.15 => k>=1 ok; but cap cost-side:
    # use a bound that measured tpot of k>=4 violates
    bounded = run(0.80)
    # measured tpot(k) = cost/tokens; tokens=round(u*c) => tpot ~ 1/u
    # 1/util(4)=0.625 <= 0.8 ok; make the bound really tight instead:
    tight = run(0.62)
    assert max(tight, default=0) <= max(bounded, default=0)
    for k in tight:
        if k > 0:
            assert 1.0 / util(k) <= 0.62 + 0.05, (k, tight)


def test_multi_start_recovers_nonmonotone_peak():
    """Beyond-paper: tree-drafter-style non-monotone utility (bad at K=3,
    good at K>=5). Plain hill-climbing from k_start=3 descends to K=0;
    multi-start probes k_max and recovers the high-K peak."""
    def util(k):
        return {1: 0.9, 2: 0.92, 3: 0.94, 4: 0.97, 5: 1.2, 6: 1.25,
                7: 1.28, 8: 1.3}[k]

    def run(multi):
        mgr = SpeculationManager(cfg=CascadeConfig(multi_start=multi,
                                                   k_start=3, k_max=8))
        chosen = []
        for _ in range(300):
            k = mgr.next_k()
            if mgr.phase == SET:
                chosen.append(k)
            drive(mgr, util, 1)
        return chosen

    plain = run(False)
    multi = run(True)
    assert max(multi, default=0) >= 5, multi
    # the multi-start policy must strictly dominate on this landscape
    assert (sum(multi) / max(len(multi), 1)
            > sum(plain) / max(len(plain), 1))
