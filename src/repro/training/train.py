"""Training substrate: loss, train-step builder (grad, clip, optimizer),
usable both for the example ~100M runs on CPU and as the `train_step` the
multi-pod dry-run lowers for the train_4k input shape."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T

from .optimizer import (Optimizer, apply_updates, clip_by_global_norm,
                        make_optimizer, warmup_cosine)

LB_LOSS_COEF = 0.01  # MoE load-balance auxiliary loss weight


def cross_entropy(logits, labels, mask=None):
    """logits [B,S,V], labels [B,S] -> scalar mean NLL."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(cfg, params, batch, *, window: int = 0):
    """batch: {"tokens": [B,S], "labels": [B,S], optional "mask", "embeds",
    "enc_out", "rope_pos"}."""
    logits, aux = T.train_forward(
        cfg, params, batch.get("tokens"),
        embeds=batch.get("embeds"),
        rope_pos=batch.get("rope_pos"),
        enc_out=batch.get("enc_out"),
        window=window)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    lb = aux.get("lb_loss", jnp.zeros((), jnp.float32))
    loss = ce + LB_LOSS_COEF * lb
    return loss, {"ce": ce, "lb": lb}


def make_train_step(cfg, optimizer: Optional[Optimizer] = None, *,
                    window: int = 0, max_grad_norm: float = 1.0):
    """Returns (init_state, train_step).

    train_step(state, batch) -> (state, metrics); state = (params, opt_state).
    The returned train_step is what launch/dryrun.py lowers for train_4k."""
    if optimizer is None:
        optimizer = make_optimizer(cfg.optimizer,
                                   warmup_cosine(3e-4, 100, 10_000))

    def init_state(key):
        params = T.init_params(cfg, key)
        return params, optimizer.init(params)

    def train_step(state, batch):
        params, opt_state = state
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, window=window), has_aux=True
        )(params)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "ce": parts["ce"], "lb": parts["lb"],
                   "grad_norm": gnorm}
        return (params, opt_state), metrics

    return init_state, train_step
