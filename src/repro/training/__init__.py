from .optimizer import (adafactor, adamw, apply_updates, clip_by_global_norm,
                        global_norm, make_optimizer, warmup_cosine)
from .train import cross_entropy, loss_fn, make_train_step
