"""Optimizers from scratch (no optax in this environment): AdamW and
Adafactor. Adafactor exists because Adam's per-parameter m,v for the 1T-param
Kimi-K2 config needs ~8 TB of optimizer state — beyond the assigned meshes —
while Adafactor's factored second moment is sublinear (DESIGN.md §6)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


class _Out(NamedTuple):
    """Marker for per-leaf multi-value returns inside tree_map (params may
    legitimately contain plain tuples — blocks_list — so unpacking must key
    on this type, not on tuple)."""
    u: Any
    a: Any
    b: Any


def _split3(out):
    is_leaf = lambda x: isinstance(x, _Out)  # noqa: E731
    return (jax.tree.map(lambda o: o.u, out, is_leaf=is_leaf),
            jax.tree.map(lambda o: o.a, out, is_leaf=is_leaf),
            jax.tree.map(lambda o: o.b, out, is_leaf=is_leaf))


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


# --------------------------------------------------------------------- #
# Schedules
# --------------------------------------------------------------------- #

def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------- #

def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32),
                        {"m": zeros, "v": jax.tree.map(jnp.copy, zeros)})

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1t
            vh = v / b2t
            u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return _Out((-lr_t * u).astype(p.dtype), m, v)

        out = jax.tree.map(upd, grads, state.inner["m"], state.inner["v"],
                           params)
        updates, m, v = _split3(out)
        return updates, OptState(step, {"m": m, "v": v})

    return Optimizer(init, update)


# --------------------------------------------------------------------- #
# Adafactor (Shazeer & Stern '18), factored second moment
# --------------------------------------------------------------------- #

def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def make(p):
            if _factored(p.shape):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"row": row, "col": col}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(make, params,
                                     is_leaf=lambda x: hasattr(x, "shape")))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "row" in s:
                row = beta * s["row"] + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * s["col"] + (1 - beta) * jnp.mean(g2, axis=-2)
                row_mean = jnp.mean(row, axis=-1, keepdims=True)
                r = (row / jnp.maximum(row_mean, eps))[..., None]
                u = g * jax.lax.rsqrt(jnp.maximum(r * col[..., None, :], eps))
                new_s = {"row": row, "col": col}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return _Out((-lr_t * u).astype(p.dtype), new_s, None)

        def map_states(fn, g_tree, s_tree, p_tree):
            # state leaves are {"row","col"} / {"v"} dicts aligned with params
            if isinstance(s_tree, dict) and ("row" in s_tree or "v" in s_tree):
                return fn(g_tree, s_tree, p_tree)
            if isinstance(s_tree, dict):
                return {k: map_states(fn, g_tree[k], s_tree[k], p_tree[k])
                        for k in s_tree}
            if isinstance(s_tree, (list, tuple)):
                return type(s_tree)(map_states(fn, g, st, pp) for g, st, pp
                                    in zip(g_tree, s_tree, p_tree))
            return fn(g_tree, s_tree, p_tree)

        out = map_states(upd, grads, state.inner, params)
        updates, new_inner, _ = _split3(out)
        return updates, OptState(step, new_inner)

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adafactor":
        return adafactor(lr, **kw)
    return adamw(lr, **kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm
