"""Whisper large-v3 — encoder-decoder audio model. The mel-spectrogram +
conv frontend/encoder is a STUB per the assignment: input_specs provides
precomputed 1500-frame encoder embeddings; this config is the decoder
backbone. [arXiv:2212.04356]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    encoder_len=1500,
    rope_variant="none",   # sinusoid positions (learned in the original)
    norm="layernorm",
    activation="gelu",
    source="arXiv:2212.04356",
)
