"""Qwen2-VL-7B language backbone: M-RoPE (t/h/w sections), dynamic
resolution. The ViT vision tower is a STUB per the assignment: input_specs
provides precomputed patch embeddings + 3-D position ids.
[arXiv:2409.12191]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope_variant="mrope",
    mrope_sections=(16, 24, 24),
    vision_stub=True,
    source="arXiv:2409.12191",
)
