"""Mixtral 8x7B — the paper's primary evaluation MoE (Table 1)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    moe_d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    source="arXiv:2401.04088 (paper Table 1)",
)
