"""RWKV-6 'Finch' 3B — attention-free SSM with data-dependent decay.
[arXiv:2404.05892]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    d_ff=8960,
    vocab_size=65536,
    rwkv_head_size=64,
    rope_variant="none",
    source="arXiv:2404.05892",
)
