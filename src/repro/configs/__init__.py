"""Architecture registry: 10 assigned architectures (public-literature pool)
plus the 5 MoEs the paper itself evaluates (Table 1). Select with
``--arch <id>``."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig, InputShape, INPUT_SHAPES

_ASSIGNED = [
    "kimi_k2_1t_a32b",
    "stablelm_1_6b",
    "chatglm3_6b",
    "whisper_large_v3",
    "rwkv6_3b",
    "recurrentgemma_9b",
    "stablelm_3b",
    "minitron_4b",
    "qwen2_vl_7b",
    "deepseek_v2_236b",
]
_PAPER = [
    "mixtral_8x7b",
    "phi_3_5_moe",
    "olmoe_1b_7b",
    "deepseek_moe_16b",
    "qwen15_moe_a2_7b",
]

ASSIGNED_ARCHS = [m.replace("_", "-") for m in _ASSIGNED]
PAPER_ARCHS = [m.replace("_", "-") for m in _PAPER]
ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS

_REGISTRY: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    """Look up an architecture id like 'kimi-k2-1t-a32b'."""
    key = arch.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        mod = importlib.import_module(f"repro.configs.{key}")
        _REGISTRY[key] = mod.CONFIG
    return _REGISTRY[key]


def list_configs():
    return {a: get_config(a) for a in ALL_ARCHS}
