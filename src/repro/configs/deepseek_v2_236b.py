"""DeepSeek-V2 236B: MLA (kv_lora=512, q_lora=1536), 160 routed experts
top-6 + 2 shared. [arXiv:2405.04434]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,            # qk_nope + qk_rope
    d_ff=1536,
    moe_d_ff=1536,
    vocab_size=102400,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    source="arXiv:2405.04434",
)
