"""Kimi K2 — trillion-parameter MoE, per the assigned paper-table config.
[arXiv:2501.kimi2]. Assigned as GQA (kv=8); 384 routed experts, top-8,
sigmoid (DeepSeek-V3-style) router scores."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    moe_d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    router_score="sigmoid",
    rope_theta=50000.0,
    optimizer="adafactor",  # Adam m,v for ~1T params cannot fit the mesh
    source="arXiv:2501.kimi2 (paper-table)",
)
