"""RecurrentGemma-9B (Griffin): RG-LRU recurrent blocks + local attention,
pattern 2 recurrent : 1 local-attention ('RRA'). MQA (kv=1), window 2048.
[arXiv:2402.19427]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern="RRA",
    d_rnn=4096,
    local_window=2048,
    source="arXiv:2402.19427",
)
