"""DeepSeekMoE 16.4B — 64 routed top-6 + 2 shared (paper Table 1)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    source="arXiv:2401.06066 (paper Table 1)",
)
