"""Phi-3.5-MoE 16x3.8B (paper Table 1)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3.5-moe",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    moe_d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    source="arXiv:2404.14219 (paper Table 1)",
)
