"""ChatGLM3-6B dense decoder: 2-D RoPE, aggressive GQA (kv=2).
[arXiv:2406.12793]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_variant="2d",
    source="arXiv:2406.12793",
)
