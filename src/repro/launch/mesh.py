"""Production mesh definitions (deliverable e).

Functions — never module-level constants — so importing this module does not
touch jax device state (the dry-run must set XLA_FLAGS before first init)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16); two pods: (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes kept for spec reuse)."""
    return jax.make_mesh((1, 1), ("data", "model"))
