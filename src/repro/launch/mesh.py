"""Production mesh definitions (deliverable e).

Functions — never module-level constants — so importing this module does not
touch jax device state (the dry-run must set XLA_FLAGS before first init)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16); two pods: (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (axes kept for spec reuse)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_abstract_mesh(shape, axes):
    """Device-free mesh for sharding-rule checks (no real devices needed).

    Absorbs the AbstractMesh constructor change: jax <= 0.4.35 took
    `(shape_tuple, axis_names)` like Mesh; 0.4.36+ takes a single tuple of
    `(name, size)` pairs (and 0.5+ re-adds a two-argument form)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axes))
