"""Training launcher: runs train_step for any --arch on the local devices
(reduced config on CPU) or lowers it against the production mesh
(--dry-run delegates to dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
        --reduced --steps 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.data import batch_iterator
from repro.training import make_train_step
from repro.training.optimizer import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active)")

    init_state, step = make_train_step(cfg, optimizer=adamw(args.lr))
    state = init_state(jax.random.PRNGKey(0))
    step = jax.jit(step, donate_argnums=0)
    it = batch_iterator("all-3", args.batch, args.seq,
                        vocab=min(cfg.vocab_size, 512))
    t0 = time.time()
    for i in range(args.steps):
        raw = next(it)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.is_encoder_decoder:
            batch["enc_out"] = jnp.zeros(
                (args.batch, cfg.encoder_len, cfg.encoder_d_model),
                jnp.dtype(cfg.dtype))
        if cfg.vision_stub:
            batch["embeds"] = jnp.zeros(
                (args.batch, args.seq, cfg.d_model), jnp.dtype(cfg.dtype))
            batch.pop("tokens")
        state, m = step(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")


if __name__ == "__main__":
    main()
