"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production mesh with 512 placeholder
host devices, then extract memory / FLOP / collective-byte telemetry for
the roofline analysis (deliverable g).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape decode_32k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

# The XLA device-count override MUST precede any other import (jax locks the
# device count on first init).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch import specs as S                              # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo                # noqa: E402
from repro.models.config import INPUT_SHAPES                     # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[dims] group in an HLO result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type byte totals parsed from post-SPMD HLO. Bytes are
    the op result size (per participating device)."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start)?\(", line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(result_type)
        count[op] += 1
    out_total = sum(out.values())
    return {"bytes_by_type": out, "count_by_type": count,
            "total_bytes": out_total}


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            spec_k: int = 3, builder=None, opts=None) -> dict:
    """Lower + compile one (arch, shape, mesh) combo; return telemetry."""
    from repro.distributed.sharding import set_options
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_options(opts, mesh=mesh)
    build = builder or S.build
    t0 = time.time()
    fn, arg_sds, arg_shardings = build(cfg, shape_name, mesh)
    with mesh:
        jitted = jax.jit(fn, in_shardings=arg_shardings)
        lowered = jitted.lower(*arg_sds)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    # trip-count-aware re-analysis: XLA's cost_analysis counts while (scan)
    # bodies once; this recovers the true per-step totals (hlo_analysis.py)
    trip = analyze_hlo(hlo_text)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(mesh.devices.size),
        "compile_s": round(t_compile, 1),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "trip_aware": {
            "flops_per_device": trip["flops"],
            "bytes_per_device": trip["bytes"],
            "collective_bytes_per_device": trip["collective_bytes"],
            "collectives": trip["collectives"],
        },
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "window": S.decode_window(cfg, INPUT_SHAPES[shape_name]),
        "opts": sorted(opts or []),
        "ok": True,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x all shapes on this mesh")
    ap.add_argument("--assigned-only", action="store_true", default=True)
    ap.add_argument("--spec-k", type=int, default=3)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma-separated perf options (§Perf): "
                         "serve-capacity,dispatch-shard,residual-shard,"
                         "chunked-wkv")
    args = ap.parse_args()
    opts = [o for o in args.opts.split(",") if o]

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            try:
                rec = run_one(arch, shape, multi_pod=args.multi_pod,
                              spec_k=args.spec_k, opts=opts)
                ta = rec["trip_aware"]
                print(f"OK   {tag}: compile={rec['compile_s']}s "
                      f"flops/dev={ta['flops_per_device']:.3e} "
                      f"bytes/dev={ta['bytes_per_device']:.3e} "
                      f"coll={ta['collective_bytes_per_device']:.3e}B "
                      f"temp={rec['memory']['temp_bytes']}")
            except Exception as e:  # a failure here is a sharding bug
                rec = {"arch": arch, "shape": shape, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"FAIL {tag}: {rec['error']}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            results.append(rec)

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} combinations compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
