"""Trip-count-aware HLO analysis.

XLA's `compiled.cost_analysis()` counts a `while` body **once**, so for a
`lax.scan`-over-layers model the reported FLOPs/bytes understate the true
per-step cost by ~num_layers. The compiled HLO carries
`backend_config={"known_trip_count":{"n":...}}` for counted loops, so this
module re-derives:

    * flops            — 2·prod(result)·prod(contracting) per dot, with
                         while-body totals multiplied by trip count
                         (descends into fusions and control flow)
    * bytes            — per-op operand+result sizes at fusion granularity
                         (a fused op reads its inputs and writes its output
                         once — XLA's own bytes-accessed convention),
                         trip-aware
    * collective bytes — result sizes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute,
                         trip-aware, per type

Used by launch/dryrun.py for the §Roofline terms."""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_ARRAY_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred|"
    r"f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")

_COMP_START_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*{")

_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation|"
    r"comparator|scatter|select|update_computation)=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations={([^}]*)}")
_TRIP_RE = re.compile(r'known_trip_count[\\\"{:n ]+(\d+)')

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _array_dims(type_str: str) -> Optional[List[int]]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str              # operand list + attrs (raw remainder of line)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # %name -> type


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_START_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = Computation(m.group(2), bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, rtype, opcode, rest = om.groups()
        operands = re.findall(r"%[\w.\-]+", rest.split(", ", 1)[0]
                              if opcode != "fusion" else rest)
        op = Op(name, rtype, opcode, rest, operands)
        cur.ops.append(op)
        cur.symbols[name] = rtype
    return comps


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    dims = _array_dims(op.result_type)
    if dims is None:
        return 0.0
    cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.rest)
    ops_in_line = re.findall(r"%[\w.\-]+", op.rest)
    if not ops_in_line:
        return 0.0
    lhs_type = symbols.get(ops_in_line[0], "")
    lhs_dims = _array_dims(lhs_type) or []
    contract = 1
    if cm:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    n = 1
    for d in dims:
        n *= d
    return 2.0 * n * contract


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.entry = next((c for c in self.comps.values() if c.is_entry),
                          None)
        self._memo_flops: Dict[str, float] = {}
        self._memo_bytes: Dict[str, float] = {}
        self._memo_coll: Dict[str, Dict[str, float]] = {}

    # ---- helpers ----

    def _callees(self, op: Op) -> List[Tuple[str, float]]:
        """(computation, multiplier) pairs invoked by this op."""
        out = []
        mult = 1.0
        if op.opcode == "while":
            tm = _TRIP_RE.search(op.rest)
            mult = float(tm.group(1)) if tm else 1.0
        for name in _CALL_ATTR_RE.findall(op.rest):
            if name in self.comps:
                # condition bodies run trip+1 times; treat as trip (small)
                out.append((name, mult))
        bm = _BRANCHES_RE.search(op.rest)
        if bm:
            for name in re.findall(r"%[\w.\-]+", bm.group(1)):
                if name in self.comps:
                    out.append((name, 1.0))
        return out

    # ---- flops (descends into fusions + control flow) ----

    def flops_of(self, comp_name: str) -> float:
        if comp_name in self._memo_flops:
            return self._memo_flops[comp_name]
        self._memo_flops[comp_name] = 0.0  # cycle guard
        comp = self.comps[comp_name]
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, comp.symbols)
            for callee, mult in self._callees(op):
                total += mult * self.flops_of(callee)
        self._memo_flops[comp_name] = total
        return total

    # ---- bytes (fusion = boundary; control flow descended) ----

    _CONTROL = {"while", "conditional", "call"}
    _SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast"}

    def _fusion_inplace_credit(self, op: Op) -> float:
        """Bytes to SUBTRACT for a fusion whose internals slice/update a
        large parameter buffer in place (XLA aliases dynamic-update-slice
        and reads only the slice for dynamic-slice): without this credit a
        scan that carries a [L, ...] stacked KV cache appears to copy the
        whole cache every layer."""
        credit = 0.0
        for name in _CALL_ATTR_RE.findall(op.rest):
            fused = self.comps.get(name)
            if fused is None:
                continue
            for fop in fused.ops:
                if fop.opcode == "dynamic-update-slice":
                    buf = fused.symbols.get(fop.operands[0], "") \
                        if fop.operands else ""
                    upd = fused.symbols.get(fop.operands[1], "") \
                        if len(fop.operands) > 1 else ""
                    bb, ub = _type_bytes(buf), _type_bytes(upd)
                    if bb > 4 * ub:
                        # full buffer read + write replaced by update-sized
                        credit += 2 * (bb - ub)
                elif fop.opcode == "dynamic-slice":
                    buf = fused.symbols.get(fop.operands[0], "") \
                        if fop.operands else ""
                    sb = _type_bytes(fop.result_type)
                    bb = _type_bytes(buf)
                    if bb > 4 * sb:
                        credit += bb - sb
        return credit

    def bytes_of(self, comp_name: str) -> float:
        if comp_name in self._memo_bytes:
            return self._memo_bytes[comp_name]
        self._memo_bytes[comp_name] = 0.0
        comp = self.comps[comp_name]
        total = 0.0
        for op in comp.ops:
            if op.opcode in self._CONTROL:
                for callee, mult in self._callees(op):
                    total += mult * self.bytes_of(callee)
                continue
            if op.opcode in self._SKIP_BYTES:
                continue
            b = _type_bytes(op.result_type)
            for o in op.operands:
                t = comp.symbols.get(o)
                if t:
                    b += _type_bytes(t)
            if op.opcode == "fusion":
                b = max(b - self._fusion_inplace_credit(op), 0.0)
            elif op.opcode == "dynamic-update-slice":
                upd = (comp.symbols.get(op.operands[1], "")
                       if len(op.operands) > 1 else "")
                b = min(b, 2 * _type_bytes(upd) + 64)
            elif op.opcode == "dynamic-slice":
                b = 2 * _type_bytes(op.result_type)
            total += b
        self._memo_bytes[comp_name] = total
        return total

    # ---- collectives ----

    def collectives_of(self, comp_name: str) -> Dict[str, float]:
        if comp_name in self._memo_coll:
            return self._memo_coll[comp_name]
        self._memo_coll[comp_name] = {}
        comp = self.comps[comp_name]
        acc: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
        counts: Dict[str, float] = {c + "_count": 0.0 for c in _COLLECTIVES}
        for op in comp.ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in _COLLECTIVES:
                acc[base] += _type_bytes(op.result_type)
                counts[base + "_count"] += 1
            for callee, mult in self._callees(op):
                sub = self.collectives_of(callee)
                for k, v in sub.items():
                    if k in acc:
                        acc[k] += mult * v
                    else:
                        counts[k] = counts.get(k, 0.0) + mult * v
        acc.update(counts)
        self._memo_coll[comp_name] = acc
        return acc

    # ---- public ----

    def analyze(self) -> dict:
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
        coll = self.collectives_of(self.entry.name)
        total = sum(v for k, v in coll.items() if not k.endswith("_count"))
        return {
            "flops": self.flops_of(self.entry.name),
            "bytes": self.bytes_of(self.entry.name),
            "collective_bytes": total,
            "collectives": coll,
        }


def analyze_hlo(text: str) -> dict:
    return HloCostModel(text).analyze()
