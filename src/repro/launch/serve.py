"""Serving launcher: run the speculative-decoding engine with Cascade for
any --arch (reduced on CPU) over a synthetic mixed request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --policy cascade --requests 4
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.core import CascadeController, StaticKController
from repro.data import make_sample
from repro.models import transformer as T
from repro.serving import NGramDrafter, Request, Scheduler, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=ALL_ARCHS)
    ap.add_argument("--policy", default="cascade",
                    choices=["cascade", "k0", "k1", "k2", "k3"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.attention_free or cfg.layer_pattern:
        print(f"note: {cfg.name} decodes through staged recurrent states")
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    enc = None
    if cfg.is_encoder_decoder:
        import jax.numpy as jnp
        enc = jnp.zeros((1, cfg.encoder_len, cfg.encoder_d_model),
                        jnp.dtype(cfg.dtype))

    factory = (CascadeController if args.policy == "cascade"
               else lambda: StaticKController(int(args.policy[1:])))
    engine = ServingEngine(cfg, params, NGramDrafter(), max_len=512,
                           temperature=0.0, clock="model")
    sched = Scheduler(engine, controller_factory=factory)
    rng = np.random.default_rng(args.seed)
    tasks = ["code", "math", "extract"]
    reqs = [Request(request_id=f"r{i}",
                    prompt=make_sample(tasks[i % 3], rng,
                                       vocab=cfg.vocab_size,
                                       prompt_len=48, cont_len=1).prompt,
                    max_new=args.max_new, task=tasks[i % 3], enc_out=enc)
            for i in range(args.requests)]
    sched.run(reqs)
    print(f"{cfg.name} policy={args.policy}: "
          f"{sched.tokens_per_second():.1f} tok/s (virtual v5e), "
          f"TPOT {sched.mean_tpot()*1e3:.3f} ms")
    for r in sched.results:
        t = r.telemetry
        print(f"  {t.request_id} [{t.task:8s}] out={t.output_tokens} "
              f"iters={len(t.iterations)} etr={t.etr:.2f}")


if __name__ == "__main__":
    main()
