"""Abstract input construction (ShapeDtypeStruct — never allocated) and
step-function builders for every (architecture x input shape) pair. Used by
the multi-pod dry-run (deliverable e) and the roofline benchmark (g).

  train_4k    -> train_step(state, batch)
  prefill_32k -> prefill_step(params, tokens [, enc/embeds])
  decode_32k  -> serve_step(params, cache, tokens[B, K+1])   (K=3: paper max)
  long_500k   -> serve_step with a sliding-window (8192) variant for
                 full-attention archs (DESIGN.md §5) — SSM/hybrid run native
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import transformer as T
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.training.train import make_train_step

SPEC_K = 3           # paper's static-K ceiling; verification step = K+1
LONG_WINDOW = 8192   # sliding-window variant used at long_500k
CACHE_HEADROOM = 64


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Window override: full-attention archs get the sliding-window variant
    at long_500k (otherwise their KV cache would be 0.5M entries)."""
    if shape.name != "long_500k":
        return cfg.window
    kinds = set(cfg.layer_kinds())
    if kinds & {"A", "X"} and not cfg.layer_pattern and not cfg.window:
        return LONG_WINDOW
    return cfg.window


# --------------------------------------------------------------------- #
# Abstract batches
# --------------------------------------------------------------------- #

def train_batch_specs(cfg: ModelConfig, shape: InputShape, mesh):
    b, s = shape.global_batch, shape.seq_len
    dp = sh.data_axes(mesh)
    batch: Dict[str, Any] = {}
    shard: Dict[str, Any] = {}

    def add(name, shp, dtype, spec):
        batch[name] = sds(shp, dtype)
        shard[name] = NamedSharding(mesh, spec)

    if cfg.vision_stub:
        # carve-out: precomputed patch/frame embeddings of the right shape
        add("embeds", (b, s, cfg.d_model), cfg.dtype, P(dp, None, None))
        add("rope_pos", (3, b, s), jnp.int32, P(None, dp, None))
    else:
        add("tokens", (b, s), jnp.int32, P(dp, None))
    add("labels", (b, s), jnp.int32, P(dp, None))
    add("mask", (b, s), jnp.float32, P(dp, None))
    if cfg.is_encoder_decoder:
        add("enc_out", (b, cfg.encoder_len, cfg.encoder_d_model), cfg.dtype,
            P(dp, None, None))
    return batch, shard


def token_specs(cfg, mesh, b, t):
    dp = sh.data_axes(mesh)
    lead = dp if b % sh.axis_size(mesh, dp) == 0 else None
    return sds((b, t), jnp.int32), NamedSharding(mesh, P(lead, None))


# --------------------------------------------------------------------- #
# Step builders: (fn, arg_specs, arg_shardings)
# --------------------------------------------------------------------- #

def build_train(cfg: ModelConfig, shape: InputShape, mesh):
    init_state, train_step = make_train_step(cfg)
    state_sds = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    state_shard = sh.param_shardings(cfg, state_sds, mesh)
    batch_sds, batch_shard = train_batch_specs(cfg, shape, mesh)
    return train_step, (state_sds, batch_sds), (state_shard, batch_shard)


def build_prefill(cfg: ModelConfig, shape: InputShape, mesh):
    b, s = shape.global_batch, shape.seq_len
    win = decode_window(cfg, shape)

    def prefill_step(params, batch):
        cache = T.init_cache(cfg, b, s + CACHE_HEADROOM, window=win)
        logits, cache, _ = T.prefill(
            cfg, params, batch.get("tokens"), cache,
            embeds=batch.get("embeds"), rope_pos=batch.get("rope_pos"),
            enc_out=batch.get("enc_out"), window=win, moe_exact=False)
        return logits[:, -1], cache

    params_sds = jax.eval_shape(
        functools.partial(T.init_params, cfg), jax.random.PRNGKey(0))
    params_shard = sh.param_shardings(cfg, params_sds, mesh)
    batch_sds, batch_shard = train_batch_specs(cfg, shape, mesh)
    for k in ("labels", "mask"):
        batch_sds.pop(k), batch_shard.pop(k)
    return prefill_step, (params_sds, batch_sds), (params_shard, batch_shard)


def build_decode(cfg: ModelConfig, shape: InputShape, mesh, spec_k=SPEC_K):
    b, s = shape.global_batch, shape.seq_len
    win = decode_window(cfg, shape)
    t = spec_k + 1

    def serve_step(params, cache, tokens):
        logits, new_cache, aux, _ = T.decode_step(cfg, params, cache, tokens,
                                                  window=win)
        return logits, new_cache

    params_sds = jax.eval_shape(
        functools.partial(T.init_params, cfg), jax.random.PRNGKey(0))
    params_shard = sh.param_shardings(cfg, params_sds, mesh)
    cache_sds = jax.eval_shape(
        lambda: T.init_cache(cfg, b, s + CACHE_HEADROOM, window=win))
    cache_shard = sh.cache_shardings(cfg, cache_sds, mesh, b)
    tok_sds, tok_shard = token_specs(cfg, mesh, b, t)
    return serve_step, (params_sds, cache_sds, tok_sds), \
        (params_shard, cache_shard, tok_shard)


def build(cfg: ModelConfig, shape_name: str, mesh, spec_k=SPEC_K):
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh, spec_k=spec_k)
