"""Model configuration covering all six assigned architecture families.

One frozen dataclass describes every architecture this framework can build:
dense decoder-only, MoE (top-k routed, optional shared experts, optional MLA),
attention-free SSM (RWKV-6), recurrent/attention hybrid (RecurrentGemma),
audio encoder-decoder backbone (Whisper decoder; encoder stubbed), and VLM
backbone (Qwen2-VL; vision tower stubbed).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

Family = str  # "dense" | "moe" | "ssm" | "hybrid" | "audio" | "vlm"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    num_heads: int = 0          # 0 for attention-free families (rwkv)
    num_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // num_heads
    window: int = 0             # 0 = full attention; >0 = sliding window
    qk_norm: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0           # expert intermediate size; 0 -> d_ff
    router_score: str = "softmax"   # "softmax" | "sigmoid" (DeepSeek-V3/Kimi)

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- positional encoding ---
    rope_variant: str = "standard"  # "standard" | "2d" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t/h/w split of head_dim//2

    # --- hybrid (RecurrentGemma / Griffin) ---
    layer_pattern: str = ""     # e.g. "RRA" repeated; "" = uniform family block
    d_rnn: int = 0              # RG-LRU recurrence width; 0 -> d_model
    local_window: int = 2048    # window of the hybrid's local-attention layers
    conv1d_width: int = 4

    # --- SSM (RWKV-6) ---
    rwkv_head_size: int = 64

    # --- encoder-decoder (Whisper) ---
    is_encoder_decoder: bool = False
    encoder_len: int = 1500     # stub: precomputed mel/conv frames
    encoder_d_model: int = 0    # 0 -> d_model

    # --- VLM (Qwen2-VL) ---
    vision_stub: bool = False
    vision_d_model: int = 0     # dim of precomputed patch embeddings (0 -> d_model)

    # --- misc ---
    norm: str = "rmsnorm"       # "rmsnorm" | "layernorm"
    activation: str = "swiglu"  # "swiglu" | "gelu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # optimizer choice used by the training launcher / dry-run
    optimizer: str = "adamw"    # "adamw" | "adafactor"
    source: str = ""            # citation / model card

    # ------------------------------------------------------------------ #

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads and not self.num_kv_heads:
            object.__setattr__(self, "num_kv_heads", self.num_heads)
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.family == "hybrid" and not self.d_rnn:
            object.__setattr__(self, "d_rnn", self.d_model)
        if self.is_encoder_decoder and not self.encoder_d_model:
            object.__setattr__(self, "encoder_d_model", self.d_model)

    # --- derived sizes ------------------------------------------------ #

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind. 'A' attention+ffn, 'R' recurrent+ffn,
        'W' rwkv (time-mix + channel-mix), 'X' attention+cross-attn+ffn."""
        if self.layer_pattern:
            pat = self.layer_pattern
            kinds = [pat[i % len(pat)] for i in range(self.num_layers)]
            return tuple(kinds)
        if self.family == "ssm":
            return tuple("W" * self.num_layers)
        if self.is_encoder_decoder:
            return tuple("X" * self.num_layers)
        return tuple("A" * self.num_layers)

    # --- parameter counting (used by the roofline / cost model) ------- #

    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            q = (d * self.q_lora_rank
                 + self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                 ) if self.q_lora_rank else d * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_dim)
            kv += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
            o = self.num_heads * self.v_head_dim * d
            return q + kv + o
        hd = self.head_dim
        return (d * self.num_heads * hd          # Q
                + 2 * d * self.num_kv_heads * hd  # K, V
                + self.num_heads * hd * d)        # O

    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.activation == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _rwkv_layer_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,w,g projections + output + small lora for decay/mix
        tm = 5 * d * d + d * d + 6 * 32 * d * 2
        # channel-mix: k (d->d_ff), v (d_ff->d), r (d->d)
        cm = d * self.d_ff * 2 + d * d
        return tm + cm

    def _rglru_layer_params(self) -> int:
        d, dr = self.d_model, self.d_rnn
        # two input branches (x, gate), conv1d, rg-lru gates (a, input), out proj
        return 2 * d * dr + self.conv1d_width * dr + 2 * dr * dr // 1 + dr * d

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active-per-token) parameter count, embeddings included."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for kind in self.layer_kinds():
            if kind == "W":
                n += self._rwkv_layer_params()
                continue
            if kind == "R":
                n += self._rglru_layer_params() + self._ffn_params(self.d_ff)
                continue
            n += self._attn_params()
            if kind == "X":
                n += self._attn_params()  # cross-attention
            if self.is_moe and kind == "A":
                e = self.experts_per_token if active_only else self.num_experts
                n += (e + self.num_shared_experts) * self._ffn_params(self.moe_d_ff)
                n += self.d_model * self.num_experts  # router
            else:
                n += self._ffn_params(self.d_ff)
        return n

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)

    # --- reduced variant for CPU smoke tests -------------------------- #

    def reduced(self) -> "ModelConfig":
        """Same family/topology, shrunk to run a step on CPU (<=2 layers,
        d_model<=256, <=4 experts)."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        head_dim = (d_model // num_heads) if num_heads else 0
        kv = min(self.num_kv_heads, num_heads) if num_heads else 0
        kv = max(kv, 1) if num_heads else 0
        # keep the GQA ratio flavor: if original had fewer kv heads, halve
        if num_heads and self.num_kv_heads < self.num_heads:
            kv = max(1, num_heads // 2)
        n_layers = min(self.num_layers, 2)
        if self.layer_pattern:
            n_layers = max(n_layers, len(self.layer_pattern))  # cover pattern
            n_layers = min(n_layers, 3)
        changes = dict(
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 64) if self.window else 0,
            local_window=min(self.local_window, 32),
            d_rnn=min(self.d_rnn, d_model) if self.d_rnn else 0,
            rwkv_head_size=min(self.rwkv_head_size, 32),
            encoder_len=min(self.encoder_len, 16),
            encoder_d_model=d_model if self.is_encoder_decoder else 0,
            vision_d_model=d_model if self.vision_stub else 0,
            dtype="float32",
        )
        if self.is_moe:
            changes.update(
                num_experts=min(self.num_experts, 4),
                experts_per_token=min(self.experts_per_token, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                moe_d_ff=min(self.moe_d_ff, 256),
            )
        if self.use_mla:
            changes.update(kv_lora_rank=64, q_lora_rank=64,
                           qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
                           head_dim=32 + 16)
        if self.rope_variant == "mrope":
            half = (changes.get("head_dim") or head_dim) // 2
            t = half // 4
            hw = (half - t) // 2
            changes["mrope_sections"] = (half - 2 * hw, hw, hw)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------- #
# Input shape grid assigned to this paper.
# ---------------------------------------------------------------------- #

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
