"""Grouped-query attention (full / sliding-window / cross) used by every
attention-bearing family. This is the canonical jnp implementation the models
run on CPU and in the dry-run; `repro.kernels.flash_attention` and
`repro.kernels.decode_attention` provide the Pallas TPU versions validated
against the same math."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rope as rope_mod
from .layers import _dense_init

NEG_INF = -1e30


def init_attention(cfg, key, dtype):
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, hk * hd), dtype),
        "wv": _dense_init(ks[2], (d, hk * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_cross_attention(cfg, key, dtype):
    """Cross-attention (whisper decoder): keys/values from encoder states."""
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    de = cfg.encoder_d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (de, h * hd), dtype),
        "wv": _dense_init(ks[2], (de, h * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def attend(q, k, v, q_pos, kv_pos, *, window: int = 0, causal: bool = True):
    """Masked GQA attention core.

    q: [B,T,H,D]; k,v: [B,S,Hkv,D]
    q_pos: [B,T] absolute positions of queries
    kv_pos: [B,S] absolute positions of keys (-1 marks empty cache slots)
    window: if >0, keys older than q_pos - window are masked (sliding window)
    """
    b, t, h, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[3]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv

    qf = q.astype(jnp.float32) / jnp.sqrt(d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # [B,T,S] scores per kv-group, queries grouped onto kv heads
    qg = qf.reshape(b, t, hkv, group, d)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, kf)   # [B,Hkv,G,T,S]

    valid = kv_pos[:, None, :] >= 0                    # [B,1,S]
    if causal:
        valid = valid & (kv_pos[:, None, :] <= q_pos[:, :, None])
    if window and window > 0:
        valid = valid & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
    mask = valid[:, None, None, :, :]                  # [B,1,1,T,S]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (shouldn't happen for causal self-attn) -> zeros
    probs = jnp.where(mask.any(-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, vf)
    return out.reshape(b, t, h, dv).astype(q.dtype)


def qkv(cfg, p, x, positions):
    """Project + rope. Returns q [B,T,H,D], k/v [B,T,Hkv,D]."""
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ p["wq"], h, hd)
    k = _split_heads(x @ p["wk"], hk, hd)
    v = _split_heads(x @ p["wv"], hk, hd)
    if "q_norm" in p:
        q = _rms(q) * p["q_norm"]
        k = _rms(k) * p["k_norm"]
    pos2d = positions if positions.ndim == 2 else positions[0]
    del pos2d
    q = rope_mod.apply_positional(cfg, q, positions)
    k = rope_mod.apply_positional(cfg, k, positions)
    return q, k, v


def _rms(x, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)).astype(x.dtype)


def self_attention(cfg, p, x, positions, *, window: int = 0):
    """Full-sequence self attention (train / prefill)."""
    q, k, v = qkv(cfg, p, x, positions)
    pos2d = positions if positions.ndim == 2 else positions[0]
    out = attend(q, k, v, pos2d, pos2d, window=window, causal=True)
    b, t = out.shape[:2]
    return out.reshape(b, t, -1) @ p["wo"], (k, v)


def cached_attention(cfg, p, x, positions, k_cache, v_cache, cache_pos,
                     *, window: int = 0):
    """Decode/verify step: new tokens x [B,T,:] attend over cache + selves.

    k_cache/v_cache: [B,S_max,Hkv,D] with new keys already written.
    cache_pos: [B,S_max] absolute position per slot, -1 where empty.
    """
    q, k_new, v_new = qkv(cfg, p, x, positions)
    del k_new, v_new  # caller already wrote them into the cache
    pos2d = positions if positions.ndim == 2 else positions[0]
    out = attend(q, k_cache, v_cache, pos2d, cache_pos, window=window, causal=True)
    b, t = out.shape[:2]
    return out.reshape(b, t, -1) @ p["wo"]


def cross_attention(cfg, p, x, enc_k, enc_v):
    """x: [B,T,d]; enc_k/enc_v: [B,S_enc,H,D] precomputed at prefill."""
    h, hd = cfg.num_heads, cfg.head_dim
    q = _split_heads(x @ p["wq"], h, hd)
    b, t = q.shape[:2]
    s_enc = enc_k.shape[1]
    q_pos = jnp.zeros((b, t), jnp.int32)
    kv_pos = jnp.zeros((b, s_enc), jnp.int32)
    out = attend(q, enc_k, enc_v, q_pos, kv_pos, window=0, causal=False)
    return out.reshape(b, t, -1) @ p["wo"]


def encode_cross_kv(cfg, p, enc_out):
    """Precompute K/V of the encoder output for one decoder layer."""
    h, hd = cfg.num_heads, cfg.head_dim
    k = _split_heads(enc_out @ p["wk"], h, hd)
    v = _split_heads(enc_out @ p["wv"], h, hd)
    return k, v
