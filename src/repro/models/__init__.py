"""Model substrate: configs, layers, and the six architecture families."""

from .config import ModelConfig, InputShape, INPUT_SHAPES
