"""Shared primitive layers: norms, MLPs, embeddings. Pure functional JAX —
params are plain dicts of jnp arrays; every layer has init_* and a matching
apply function."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #

def init_norm(cfg, dim, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype)}


def apply_norm(cfg, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Feed-forward
# --------------------------------------------------------------------- #

def init_mlp(cfg, key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "w_gate": _dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": _dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": _dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w_up": _dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": _dense_init(ks[1], (d_ff, d_model), dtype),
    }


def apply_mlp(cfg, p, x):
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------------- #
# Embeddings / logits
# --------------------------------------------------------------------- #

def init_embed(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    p = {"embedding": _dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_tokens(p, tokens):
    return p["embedding"][tokens]


def unembed(cfg, p, x):
    if cfg.tie_embeddings:
        return x @ p["embedding"].T
    return x @ p["unembed"]
