"""Rotary position embeddings: standard, ChatGLM 2-D, and Qwen2-VL M-RoPE.

All functions operate on tensors shaped [..., seq, heads, head_dim] and take
explicit integer position ids so the same code serves prefill (positions
0..S-1) and speculative decode steps (positions L..L+K).
"""

from __future__ import annotations

import jax.numpy as jnp


def _rotate_half_pairs(x):
    """Rotate interleaved pairs (x0,x1) -> (-x1,x0) on the last dim."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    return jnp.stack([-x2, x1], axis=-1).reshape(x.shape)


def rope_angles(positions, dim: int, theta: float):
    """positions [...,S] -> angles [...,S,dim//2]."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(x, positions, theta: float = 10000.0):
    """Standard RoPE over the full head_dim. x: [B,S,H,D], positions: [B,S]."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)          # [B,S,d/2]
    cos = jnp.repeat(jnp.cos(ang), 2, axis=-1)[:, :, None, :]
    sin = jnp.repeat(jnp.sin(ang), 2, axis=-1)[:, :, None, :]
    out = x.astype(jnp.float32) * cos + _rotate_half_pairs(x.astype(jnp.float32)) * sin
    return out.astype(x.dtype)


def apply_rope_2d(x, positions, theta: float = 10000.0):
    """ChatGLM-style 2-D RoPE: rotate only the first half of head_dim,
    leave the second half untouched (the '2d' scheme of GLM)."""
    d = x.shape[-1]
    half = d // 2
    x_rot, x_pass = x[..., :half], x[..., half:]
    ang = rope_angles(positions, half, theta)
    cos = jnp.repeat(jnp.cos(ang), 2, axis=-1)[:, :, None, :]
    sin = jnp.repeat(jnp.sin(ang), 2, axis=-1)[:, :, None, :]
    rot = x_rot.astype(jnp.float32) * cos + _rotate_half_pairs(x_rot.astype(jnp.float32)) * sin
    return jnp.concatenate([rot.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x, positions_3d, sections, theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE. positions_3d: [3,B,S] (t,h,w ids);
    `sections` splits head_dim//2 frequency slots among (t,h,w)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    # angles per modality: [3,B,S,d/2]
    ang = positions_3d[..., None].astype(jnp.float32) * inv_freq
    # select which modality drives each frequency slot
    sel = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                              # [d/2]
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1),                   # [B,S,d/2,3]
        sel[None, None, :, None], axis=-1)[..., 0]  # [B,S,d/2]
    cos = jnp.repeat(jnp.cos(ang), 2, axis=-1)[:, :, None, :]
    sin = jnp.repeat(jnp.sin(ang), 2, axis=-1)[:, :, None, :]
    out = x.astype(jnp.float32) * cos + _rotate_half_pairs(x.astype(jnp.float32)) * sin
    return out.astype(x.dtype)


def apply_positional(cfg, x, positions):
    """Dispatch on cfg.rope_variant. `positions` is [B,S] int32, or [3,B,S]
    for mrope."""
    if cfg.rope_variant == "none":
        return x
    if cfg.rope_variant == "2d":
        return apply_rope_2d(x, positions, cfg.rope_theta)
    if cfg.rope_variant == "mrope":
        if positions.ndim == 2:  # text-only fallback: same id on all three
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)
