"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

The KV cache stores only the compressed latent c_kv (kv_lora_rank dims) plus
a single shared RoPE key (qk_rope_dim dims) per position. Prefill/train use
the naive expanded form; decode uses the *absorbed* form (W_UK folded into
the query, W_UV folded into the output) so per-step work reads only the
latent cache — the property that makes MLA decode cheap and that shifts the
MoE verification bottleneck squarely onto the experts (paper §2.4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attend, NEG_INF
from .layers import _dense_init
from .rope import apply_rope


def init_mla(cfg, key, dtype):
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_kva": _dense_init(ks[0], (d, cfg.kv_lora_rank), dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        "w_kr": _dense_init(ks[1], (d, rope), dtype),
        "w_uk": _dense_init(ks[2], (cfg.kv_lora_rank, h, nope), dtype),
        "w_uv": _dense_init(ks[3], (cfg.kv_lora_rank, h, vdim), dtype),
        "wo": _dense_init(ks[4], (h * vdim, d), dtype),
    }
    if cfg.q_lora_rank:
        p["w_qa"] = _dense_init(ks[5], (d, cfg.q_lora_rank), dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["w_qb"] = _dense_init(ks[6], (cfg.q_lora_rank, h, nope + rope), dtype)
    else:
        p["w_q"] = _dense_init(ks[7], (d, h, nope + rope), dtype)
    return p


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def _queries(cfg, p, x, pos2d):
    """-> q_nope [B,T,H,nope], q_rope [B,T,H,rope] (roped)."""
    nope = cfg.qk_nope_dim
    if cfg.q_lora_rank:
        qa = _rms(x @ p["w_qa"], p["q_norm"])
        q = jnp.einsum("btl,lhd->bthd", qa, p["w_qb"])
    else:
        q = jnp.einsum("btd,dhe->bthe", x, p["w_q"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos2d, cfg.rope_theta)
    return q_nope, q_rope


def latent_kv(cfg, p, x, pos2d):
    """Compress x -> (c_kv [B,T,R], k_rope [B,T,rope]) — what gets cached."""
    c_kv = _rms(x @ p["w_kva"], p["kv_norm"])
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], pos2d, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_full(cfg, p, x, pos2d):
    """Train/prefill: expand the latent into per-head K/V and run standard
    MHA. Returns (out [B,T,d], (c_kv, k_rope)) for caching."""
    b, t, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _queries(cfg, p, x, pos2d)
    c_kv, k_rope = latent_kv(cfg, p, x, pos2d)
    k_nope = jnp.einsum("btl,lhd->bthd", c_kv, p["w_uk"])
    v = jnp.einsum("btl,lhd->bthd", c_kv, p["w_uv"])
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = attend(q, k, v, pos2d, pos2d, window=0, causal=True)
    out = out.reshape(b, t, -1) @ p["wo"]
    return out, (c_kv, k_rope)


def mla_absorbed(cfg, p, x, pos2d, ckv_cache, krope_cache, cache_pos,
                 *, window: int = 0):
    """Decode/verify: attention in latent space over the compressed cache.

    ckv_cache: [B,R,kv_lora] (new entries already written)
    krope_cache: [B,R,rope]
    cache_pos: [B,R] absolute positions, -1 = empty.
    """
    b, t, _ = x.shape
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = _queries(cfg, p, x, pos2d)
    # absorb W_UK into the query: q_lat [B,T,H,R]
    q_lat = jnp.einsum("bthd,lhd->bthl", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    scores = (jnp.einsum("bthl,bsl->bhts", q_lat, ckv_cache.astype(jnp.float32))
              + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                           krope_cache.astype(jnp.float32))) * scale
    valid = (cache_pos[:, None, :] >= 0) & (cache_pos[:, None, :] <= pos2d[:, :, None])
    if window:
        valid = valid & (cache_pos[:, None, :] > pos2d[:, :, None] - window)
    scores = jnp.where(valid[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(valid[:, None, :, :].any(-1, keepdims=True), probs, 0.0)
    out_lat = jnp.einsum("bhts,bsl->bthl", probs, ckv_cache.astype(jnp.float32))
    out = jnp.einsum("bthl,lhd->bthd", out_lat, p["w_uv"].astype(jnp.float32))
    return (out.reshape(b, t, -1) @ p["wo"].astype(jnp.float32)).astype(x.dtype)
