"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427).

Block: x -> {branch1: linear -> causal conv1d -> RG-LRU} * gelu(branch2)
          -> out projection.

RG-LRU per channel:
    r_t = sigmoid(x_t W_a + b_a)             (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)             (input gate)
    log a_t = -c * softplus(Lambda) * r_t    (c = 8)
    h_t = exp(log a_t) * h_{t-1} + sqrt(1 - exp(2 log a_t)) * (i_t * x_t)

The serial scan here is the oracle; repro.kernels.linear_scan provides the
blocked associative-scan Pallas kernel for the same recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init

RG_LRU_C = 8.0


def init_rglru_block(cfg, key, dtype):
    d, dr, cw = cfg.d_model, cfg.d_rnn, cfg.conv1d_width
    ks = jax.random.split(key, 7)
    return {
        "w_in": _dense_init(ks[0], (d, dr), dtype),
        "w_gate": _dense_init(ks[1], (d, dr), dtype),
        "conv_w": _dense_init(ks[2], (cw, dr), dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": _dense_init(ks[3], (dr, dr), dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_x": _dense_init(ks[4], (dr, dr), dtype),
        "b_x": jnp.zeros((dr,), dtype),
        # Lambda init so that a^c ~ uniform(0.9, 0.999) at r=1 (Griffin A.2)
        "lam": (jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
                ).astype(jnp.float32),
        "w_out": _dense_init(ks[6], (dr, d), dtype),
    }


def causal_conv1d(p, x, conv_state, *, want_states: bool = False):
    """Depthwise causal conv. x: [B,T,dr]; conv_state: [B,cw-1,dr] history.
    Returns (y [B,T,dr], new_state [B,cw-1,dr], staged [T+1,B,cw-1,dr]|None)."""
    cw = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_state, x], axis=1)          # [B,cw-1+T,dr]
    t = x.shape[1]
    y = sum(full[:, i:i + t] * p["conv_w"][i] for i in range(cw))
    y = y + p["conv_b"]
    new_state = full[:, -(cw - 1):] if cw > 1 else conv_state
    staged = None
    if want_states and cw > 1:
        # conv history as of having consumed j of the T new tokens
        staged = jnp.stack([full[:, j:j + cw - 1] for j in range(t + 1)], axis=0)
    return y, new_state, staged


def rg_lru(p, x, h0, *, want_states: bool = False):
    """x: [B,T,dr], h0: [B,dr] -> (y [B,T,dr], h_last, states [T+1,B,dr]|None)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(-jnp.log(p["lam"])) * r   # [B,T,dr], <0
    a = jnp.exp(log_a)
    gated_x = i * xf
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))

    def step(h, inp):
        a_t, bx_t = inp
        h_new = a_t * h + bx_t
        return h_new, h_new

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(beta * gated_x, 1, 0))
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    states = None
    if want_states:
        states = jnp.concatenate([h0.astype(jnp.float32)[None], hs], axis=0)
    return y, h_last, states


def apply_rglru_block(cfg, p, x, state, *, want_states: bool = False):
    """x: [B,T,d]; state: {"h": [B,dr], "conv": [B,cw-1,dr]}.
    Returns (out [B,T,d], new_state, staged {"h": [T+1,B,dr]}|None)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    u, conv_state, conv_staged = causal_conv1d(p, u, state["conv"],
                                               want_states=want_states)
    y, h_last, hs = rg_lru(p, u, state["h"], want_states=want_states)
    out = (y * gate) @ p["w_out"]
    new_state = {"h": h_last, "conv": conv_state}
    staged = {"h": hs, "conv": conv_staged} if want_states else None
    return out, new_state, staged
