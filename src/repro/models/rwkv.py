"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free token mixing with
data-dependent per-channel decay.

Per head (size n), state S in R^{n_k x n_v}:
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + lora_w(x-shift-mix))) data-dependent (the Finch
novelty vs RWKV-5's static decay).

The pure-`lax.scan` implementation here is the oracle; the blocked Pallas
kernel lives in repro.kernels.rwkv_scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init

LORA_DIM = 32


def init_time_mix(cfg, key, dtype):
    d = cfg.d_model
    h, n = cfg.rwkv_num_heads, cfg.rwkv_head_size
    ks = jax.random.split(key, 16)
    p = {
        # token-shift mixing coefficients (static part) for x,w,k,v,r,g
        "mu_x": jnp.zeros((d,), dtype),
        "mu_w": jnp.zeros((d,), dtype), "mu_k": jnp.zeros((d,), dtype),
        "mu_v": jnp.zeros((d,), dtype), "mu_r": jnp.zeros((d,), dtype),
        "mu_g": jnp.zeros((d,), dtype),
        # data-dependent mix loras (rank LORA_DIM), one per of w,k,v,r,g
        "lora_a": _dense_init(ks[0], (5, d, LORA_DIM), dtype),
        "lora_b": _dense_init(ks[1], (5, LORA_DIM, d), dtype),
        # decay lora (deeper, per RWKV6) + base decay
        "w0": (jnp.zeros((d,), jnp.float32) - 4.0).astype(dtype),
        "wa": _dense_init(ks[2], (d, 2 * LORA_DIM), dtype),
        "wb": _dense_init(ks[3], (2 * LORA_DIM, d), dtype),
        # projections
        "wr": _dense_init(ks[4], (d, d), dtype),
        "wk": _dense_init(ks[5], (d, d), dtype),
        "wv": _dense_init(ks[6], (d, d), dtype),
        "wg": _dense_init(ks[7], (d, d), dtype),
        "wo": _dense_init(ks[8], (d, d), dtype),
        # per-channel bonus
        "u": (jax.random.normal(ks[9], (h, n), jnp.float32) * 0.1).astype(dtype),
        # group-norm over heads
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
    }
    return p


def init_channel_mix(cfg, key, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype), "mu_r": jnp.zeros((d,), dtype),
        "wk": _dense_init(ks[0], (d, cfg.d_ff), dtype),
        "wv": _dense_init(ks[1], (cfg.d_ff, d), dtype),
        "wr": _dense_init(ks[2], (d, d), dtype),
    }


def _group_norm(x, scale, bias, n_heads, eps=1e-5):
    """x: [..., d] normalized per head group."""
    shp = x.shape
    xh = x.reshape(shp[:-1] + (n_heads, shp[-1] // n_heads)).astype(jnp.float32)
    mu = jnp.mean(xh, -1, keepdims=True)
    var = jnp.var(xh, -1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(shp) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def _mix_inputs(p, x, x_prev):
    """RWKV6 data-dependent token-shift. x,x_prev: [B,T,d].
    Returns xw,xk,xv,xr,xg each [B,T,d]."""
    dx = x_prev - x
    xx = x + dx * p["mu_x"]
    # 5 low-rank data-dependent deltas
    delta = jnp.einsum("btd,sdr->sbtr", jnp.tanh(xx), p["lora_a"])
    delta = jnp.einsum("sbtr,srd->sbtd", delta, p["lora_b"])  # [5,B,T,d]
    mus = jnp.stack([p["mu_w"], p["mu_k"], p["mu_v"], p["mu_r"], p["mu_g"]])
    mixed = x[None] + dx[None] * (mus[:, None, None, :] + delta)
    return tuple(mixed[i] for i in range(5))


def wkv_scan(r, k, v, w, u, s0):
    """The serial WKV recurrence (oracle).
    r,k,v: [B,T,H,N]; w: [B,T,H,N] decay in (0,1); u: [H,N]; s0: [B,H,N,N].
    Returns y [B,T,H,N], states [T+1,B,H,N,N] (for speculative rollback)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]            # [B,H,Nk,Nv]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., :, None] * kv)
        s_new = w_t[..., :, None] * s + kv
        return s_new, (y, s_new)

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))   # [T,B,H,N]
    s_last, (ys, states) = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                # [B,T,H,N]
    states = jnp.concatenate([s0[None], states], axis=0)      # [T+1,...]
    return y, states


def wkv_chunked(r, k, v, w, u, s0, chunk: int = 32):
    """Chunked WKV (§Perf 'chunked-wkv'): materialize the N x N state once
    per chunk instead of once per token, turning the serial per-step
    rank-1 recurrence into three MXU matmuls per chunk (the standard
    linear-attention chunking, adapted to RWKV-6's per-channel decay).

    For chunk step i (0-based), with cum_i = sum_{l<=i} log w_l:
        y_i = (r_i * e^{cum_{i-1}})^T S_0                      (inter-chunk)
            + sum_{j<i} [ (r_i e^{cum_{i-1}}) . (k_j e^{-cum_j}) ] v_j
            + ((r_i*u) . k_i) v_i                              (bonus diag)
        S_next = diag(e^{cum_last}) S_0 + sum_j (k_j e^{cum_last-cum_j}) v_j^T

    exp(-cum) is clamped at e^25: when a channel has decayed by more than
    e^-25 within one chunk its contribution is below f32 noise anyway."""
    b, t, h, n = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    def reshape(x_):
        return x_.reshape(b, nc, chunk, h, n)

    r_, k_, v_ = reshape(r), reshape(k), reshape(v)
    logw = jnp.log(jnp.maximum(reshape(w), 1e-38))
    cum = jnp.cumsum(logw, axis=2)                       # inclusive
    cum_prev = cum - logw                                # exclusive
    cum_last = cum[:, :, -1:]                            # [B,nc,1,H,N]

    r_t = r_ * jnp.exp(cum_prev)                         # decay from start
    k_t = k_ * jnp.exp(jnp.minimum(-cum, 25.0))          # inverse decay
    k_end = k_ * jnp.exp(cum_last - cum)                 # decay to chunk end

    # intra-chunk pairwise scores, strictly causal + bonus diagonal
    scores = jnp.einsum("bcihn,bcjhn->bchij", r_t, k_t)  # [B,nc,H,C,C]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    diag = jnp.einsum("bcihn,hn,bcihn->bchi", r_, u, k_)
    y_intra = (jnp.einsum("bchij,bcjhn->bcihn", scores, v_)
               + diag[..., None].transpose(0, 1, 3, 2, 4) * v_)

    # inter-chunk: sequential scan over per-chunk state updates
    kv_chunk = jnp.einsum("bcihk,bcihv->bchkv", k_end, v_)   # [B,nc,H,N,N]
    a_chunk = jnp.exp(cum_last[:, :, 0])                     # [B,nc,H,N]

    def step(s, inp):
        a_c, kv_c, r_c = inp          # [B,H,Nk], [B,H,Nk,Nv], [B,C,H,Nk]
        y_inter = jnp.einsum("bihk,bhkv->bihv", r_c, s)
        s_new = a_c[..., None] * s + kv_c
        return s_new, y_inter

    xs = (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(kv_chunk, 1, 0),
          jnp.moveaxis(r_t, 1, 0))
    s_last, y_inter = jax.lax.scan(step, s0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, t, h, n), s_last


def time_mix(cfg, p, x, x_prev_tok, s0, *, want_states: bool = False):
    """x: [B,T,d]; x_prev_tok: [B,d] last token of the previous chunk.
    Returns (out [B,T,d], last_x [B,d], s_last [B,H,N,N], states or None)."""
    b, t, d = x.shape
    h, n = cfg.rwkv_num_heads, cfg.rwkv_head_size
    x_prev = jnp.concatenate([x_prev_tok[:, None, :], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _mix_inputs(p, x, x_prev)

    r = (xr @ p["wr"]).reshape(b, t, h, n)
    k = (xk @ p["wk"]).reshape(b, t, h, n)
    v = (xv @ p["wv"]).reshape(b, t, h, n)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay
    ww = p["w0"].astype(jnp.float32) + (jnp.tanh(xw @ p["wa"]) @ p["wb"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(b, t, h, n)

    from repro.distributed.sharding import opt as _perf_opt
    if _perf_opt("chunked-wkv") and not want_states and t > 1:
        chunk = 32 if t % 32 == 0 else (8 if t % 8 == 0 else 1)
        if chunk > 1:
            y, s_last_c = wkv_chunked(
                r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), w, p["u"].astype(jnp.float32),
                s0.astype(jnp.float32), chunk=chunk)
            y = y.reshape(b, t, d).astype(x.dtype)
            y = _group_norm(y, p["gn_scale"], p["gn_bias"], h)
            out = (y * g) @ p["wo"]
            return out, x[:, -1], s_last_c, None
    y, states = wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w,
                         p["u"].astype(jnp.float32),
                         s0.astype(jnp.float32))
    y = y.reshape(b, t, d).astype(x.dtype)
    y = _group_norm(y, p["gn_scale"], p["gn_bias"], h)
    out = (y * g) @ p["wo"]
    s_last = states[-1]
    return out, x[:, -1], s_last, (states if want_states else None)


def channel_mix(cfg, p, x, x_prev_tok):
    """RWKV6 FFN with token shift. Returns (out, last_x)."""
    x_prev = jnp.concatenate([x_prev_tok[:, None, :], x[:, :-1]], axis=1)
    dx = x_prev - x
    xk = x + dx * p["mu_k"]
    xr = x + dx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"]), x[:, -1]
