"""Model assembly for all six families with three entry points:

    train_forward(cfg, params, tokens, ...)    -> logits, aux
    prefill(cfg, params, tokens, cache, ...)   -> logits, cache, aux
    decode_step(cfg, params, cache, tokens,..) -> logits, cache, aux

Uniform-kind architectures (dense / moe / ssm / audio / vlm) stack per-layer
params with a leading L dim and run `lax.scan` over layers, keeping compile
time O(1) in depth (the 61-layer Kimi-K2 config must compile on one CPU core
with 512 host devices for the dry-run). The hybrid pattern architecture
(RecurrentGemma "RRA") uses a python loop over its 38 heterogeneous layers.

KV caches are ring buffers: ring size = full length for full attention, or
window + SPEC_PAD for sliding-window variants, so `long_500k` decode on a
windowed model allocates O(window), not O(seq). Speculative rollback is a
pure metadata operation for attention caches and an indexed select into
staged states for recurrent caches (`rollback_cache`)."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import layers as L
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv as rwkv_mod

SPEC_PAD = 16  # ring-buffer slack so speculative writes never clobber window


# ===================================================================== #
# Parameter init
# ===================================================================== #

def _init_block(cfg, kind: str, key, dtype):
    ks = jax.random.split(key, 6)
    if kind == "W":  # rwkv
        return {
            "ln1": L.init_norm(cfg, cfg.d_model, dtype),
            "tmix": rwkv_mod.init_time_mix(cfg, ks[0], dtype),
            "ln2": L.init_norm(cfg, cfg.d_model, dtype),
            "cmix": rwkv_mod.init_channel_mix(cfg, ks[1], dtype),
        }
    if kind == "R":  # rg-lru recurrent block + ffn
        return {
            "ln1": L.init_norm(cfg, cfg.d_model, dtype),
            "rec": rglru_mod.init_rglru_block(cfg, ks[0], dtype),
            "ln2": L.init_norm(cfg, cfg.d_model, dtype),
            "ffn": L.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype),
        }
    # attention-bearing kinds
    p = {"ln1": L.init_norm(cfg, cfg.d_model, dtype)}
    p["attn"] = (mla_mod.init_mla(cfg, ks[0], dtype) if cfg.use_mla
                 else attn_mod.init_attention(cfg, ks[0], dtype))
    if kind == "X":
        p["lnx"] = L.init_norm(cfg, cfg.d_model, dtype)
        p["xattn"] = attn_mod.init_cross_attention(cfg, ks[1], dtype)
    p["ln2"] = L.init_norm(cfg, cfg.d_model, dtype)
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(cfg, ks[2], dtype)
    else:
        p["ffn"] = L.init_mlp(cfg, ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    k_embed, k_blocks = jax.random.split(key)
    params: Dict[str, Any] = {"embed": L.init_embed(cfg, k_embed, dtype)}
    if len(set(kinds)) == 1:  # uniform: stacked params + scan
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_block(cfg, kinds[0], k, dtype))(keys)
    else:
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params["blocks_list"] = tuple(
            _init_block(cfg, kind, k, dtype) for kind, k in zip(kinds, keys))
    params["final_norm"] = L.init_norm(cfg, cfg.d_model, dtype)
    return params


# ===================================================================== #
# Cache
# ===================================================================== #

def ring_size(cfg, max_len: int, window: int) -> int:
    """Ring slots for a sliding-window cache: `window + SPEC_PAD` live slots
    (modulus) so writing position p only ever evicts p-window-SPEC_PAD —
    outside the window for every in-flight query — plus SPEC_PAD spill slots
    so a contiguous dynamic-update-slice write never wraps."""
    if window and window > 0:
        return min(max_len, window + 2 * SPEC_PAD)
    return max_len


def init_cache(cfg, batch: int, max_len: int, *, window: int = 0,
               dtype=None):
    """Allocate an empty cache for `batch` sequences of up to `max_len`
    tokens. `window` (0=full) selects sliding-window attention and sizes the
    ring buffer accordingly."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    cache: Dict[str, Any] = {
        "length": jnp.zeros((), jnp.int32),
    }
    n_attn = sum(1 for k in kinds if k in ("A", "X"))
    n_rec = sum(1 for k in kinds if k == "R")
    n_rwkv = sum(1 for k in kinds if k == "W")

    if n_attn:
        w_eff = window if window else (cfg.window or 0)
        r = ring_size(cfg, max_len, w_eff)
        cache["pos"] = jnp.full((batch, r), -1, jnp.int32)
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((n_attn, batch, r, cfg.kv_lora_rank), dtype)
            cache["krope"] = jnp.zeros((n_attn, batch, r, cfg.qk_rope_dim), dtype)
        else:
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            cache["k"] = jnp.zeros((n_attn, batch, r, hkv, hd), dtype)
            cache["v"] = jnp.zeros((n_attn, batch, r, hkv, hd), dtype)
        if cfg.is_encoder_decoder:
            cache["enc_k"] = jnp.zeros(
                (n_attn, batch, cfg.encoder_len, cfg.num_heads, cfg.head_dim), dtype)
            cache["enc_v"] = jnp.zeros_like(cache["enc_k"])
    if n_rwkv:
        h, n = cfg.rwkv_num_heads, cfg.rwkv_head_size
        cache["wkv"] = jnp.zeros((n_rwkv, batch, h, n, n), jnp.float32)
        cache["sx_att"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
        cache["sx_ffn"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
    if n_rec:
        cache["h"] = jnp.zeros((n_rec, batch, cfg.d_rnn), jnp.float32)
        cache["conv"] = jnp.zeros(
            (n_rec, batch, cfg.conv1d_width - 1, cfg.d_rnn), dtype)
    return cache


def cache_slots(cache, positions_1d):
    """Map absolute positions [T] to ring slots [T]."""
    r = cache["pos"].shape[1]
    return positions_1d % r


def rollback_cache(cfg, cache, staged, n_accept, length_before):
    """Rewind the cache to `length_before + n_accept` after verification.

    Attention caches: metadata-only (invalidate pos of rejected slots).
    Recurrent caches: select the staged state at index n_accept."""
    new_len = length_before + n_accept
    cache = dict(cache)
    cache["length"] = jnp.asarray(new_len, jnp.int32)
    if "pos" in cache:
        cache["pos"] = jnp.where(cache["pos"] >= new_len, -1, cache["pos"])
    if staged:
        for name in ("wkv", "sx_att", "sx_ffn", "h", "conv"):
            if name in staged and staged[name] is not None:
                # staged[name]: [L, T+1, ...] -> pick index n_accept
                cache[name] = jnp.take(staged[name], n_accept, axis=1).astype(
                    cache[name].dtype)
    return cache


# ===================================================================== #
# Block application
# ===================================================================== #

def _write_ring(buf_l, vals, wctx):
    """Write T new entries into a cache buffer [B,R,...].

    Two modes (wctx from _forward):
      * slots scatter (baseline): buf.at[:, slots].set(vals)
      * contiguous dynamic_update_slice (§Perf "dus-cache"): in-place, no
        SPMD resharding copy — the scatter path triggers XLA "involuntary
        full rematerialization" of the whole stacked cache per layer."""
    vals = vals.astype(buf_l.dtype)
    if wctx.get("offset") is not None:
        starts = (jnp.zeros((), jnp.int32), wctx["offset"]) + tuple(
            jnp.zeros((), jnp.int32) for _ in range(buf_l.ndim - 2))
        return jax.lax.dynamic_update_slice(buf_l, vals, starts)
    return buf_l.at[:, wctx["slots"]].set(vals)


def _attn_block(cfg, p, x, lc, ctx, kind):
    """Attention(+cross)(+ffn/moe) block.

    lc: layer cache dict ({"k","v"} or {"ckv","krope"}, + enc_*) or None.
    ctx: dict with mode, seq_pos [B,T], rope_pos, cache_pos [B,R] (updated),
         slots [T], window, enc_out.
    Returns (x, new_layer_cache, aux)."""
    mode = ctx["mode"]
    window = ctx["window"]
    seq_pos, rope_pos = ctx["seq_pos"], ctx["rope_pos"]
    h = L.apply_norm(cfg, p["ln1"], x)
    new_lc = {}
    if cfg.use_mla:
        if mode == "decode":
            ckv_new, krope_new = mla_mod.latent_kv(cfg, p["attn"], h, seq_pos)
            ckv = _write_ring(lc["ckv"], ckv_new, ctx)
            krope = _write_ring(lc["krope"], krope_new, ctx)
            out = mla_mod.mla_absorbed(cfg, p["attn"], h, seq_pos, ckv, krope,
                                       ctx["cache_pos"], window=window)
            new_lc.update(ckv=ckv, krope=krope)
        else:
            out, (ckv_new, krope_new) = mla_mod.mla_full(cfg, p["attn"], h, seq_pos)
            if mode == "prefill":
                t_w = ctx["t_w"]
                new_lc["ckv"] = _write_ring(lc["ckv"], ckv_new[:, -t_w:],
                                            ctx)
                new_lc["krope"] = _write_ring(lc["krope"],
                                              krope_new[:, -t_w:], ctx)
    else:
        q, k, v = attn_mod.qkv(cfg, p["attn"], h, rope_pos)
        if mode == "decode":
            kb = _write_ring(lc["k"], k, ctx)
            vb = _write_ring(lc["v"], v, ctx)
            out = attn_mod.attend(q, kb.astype(q.dtype), vb.astype(q.dtype),
                                  seq_pos, ctx["cache_pos"],
                                  window=window, causal=True)
            new_lc.update(k=kb, v=vb)
        else:
            out = attn_mod.attend(q, k, v, seq_pos, seq_pos,
                                  window=window, causal=True)
            if mode == "prefill":
                t_w = ctx["t_w"]
                new_lc["k"] = _write_ring(lc["k"], k[:, -t_w:], ctx)
                new_lc["v"] = _write_ring(lc["v"], v[:, -t_w:], ctx)
        b, t = out.shape[:2]
        out = out.reshape(b, t, -1) @ p["attn"]["wo"]
    x = x + out

    if kind == "X":  # cross-attention to (stub) encoder states
        hx = L.apply_norm(cfg, p["lnx"], x)
        if mode == "prefill":
            enc_k, enc_v = attn_mod.encode_cross_kv(cfg, p["xattn"],
                                                    ctx["enc_out"])
            new_lc["enc_k"], new_lc["enc_v"] = enc_k, enc_v
        elif mode == "decode":
            enc_k, enc_v = lc["enc_k"], lc["enc_v"]
            new_lc["enc_k"], new_lc["enc_v"] = enc_k, enc_v
        else:  # train
            enc_k, enc_v = attn_mod.encode_cross_kv(cfg, p["xattn"],
                                                    ctx["enc_out"])
        x = x + attn_mod.cross_attention(cfg, p["xattn"], hx,
                                         enc_k.astype(hx.dtype),
                                         enc_v.astype(hx.dtype))

    h2 = L.apply_norm(cfg, p["ln2"], x)
    aux = {}
    if cfg.is_moe:
        b, t, d = h2.shape
        y2d, moe_aux = moe_mod.apply_moe(cfg, p["moe"], h2.reshape(b * t, d),
                                         capacity_policy=ctx["moe_policy"])
        x = x + y2d.reshape(b, t, d)
        aux["lb_loss"] = moe_aux["lb_loss"]
        aux["unique_experts"] = moe_aux["unique_experts"]
    else:
        x = x + L.apply_mlp(cfg, p["ffn"], h2)
        aux["lb_loss"] = jnp.zeros((), jnp.float32)
        aux["unique_experts"] = jnp.zeros((), jnp.int32)
    return x, new_lc, aux


def _rwkv_block(cfg, p, x, lc, ctx):
    mode = ctx["mode"]
    want = mode == "decode"
    h = L.apply_norm(cfg, p["ln1"], x)
    if mode == "train":
        b = x.shape[0]
        sx_att = jnp.zeros((b, cfg.d_model), x.dtype)
        sx_ffn = jnp.zeros((b, cfg.d_model), x.dtype)
        s0 = jnp.zeros((b, cfg.rwkv_num_heads, cfg.rwkv_head_size,
                        cfg.rwkv_head_size), jnp.float32)
    else:
        sx_att, sx_ffn, s0 = lc["sx_att"], lc["sx_ffn"], lc["wkv"]
    out, last_x, s_last, states = rwkv_mod.time_mix(
        cfg, p["tmix"], h, sx_att.astype(h.dtype), s0, want_states=want)
    x = x + out
    h2 = L.apply_norm(cfg, p["ln2"], x)
    out2, last_x2 = rwkv_mod.channel_mix(cfg, p["cmix"], h2,
                                         sx_ffn.astype(h2.dtype))
    x = x + out2
    new_lc = {"wkv": s_last, "sx_att": last_x, "sx_ffn": last_x2}
    staged = None
    if want:
        # staged token-shift states: value after consuming j tokens
        sx_att_staged = jnp.concatenate(
            [sx_att.astype(h.dtype)[None], jnp.moveaxis(h, 1, 0)], axis=0)
        sx_ffn_staged = jnp.concatenate(
            [sx_ffn.astype(h2.dtype)[None], jnp.moveaxis(h2, 1, 0)], axis=0)
        staged = {"wkv": states, "sx_att": sx_att_staged,
                  "sx_ffn": sx_ffn_staged}
    return x, new_lc, staged


def _rec_block(cfg, p, x, lc, ctx):
    mode = ctx["mode"]
    want = mode == "decode"
    h = L.apply_norm(cfg, p["ln1"], x)
    if mode == "train":
        b = x.shape[0]
        state = {"h": jnp.zeros((b, cfg.d_rnn), jnp.float32),
                 "conv": jnp.zeros((b, cfg.conv1d_width - 1, cfg.d_rnn),
                                   x.dtype)}
    else:
        state = {"h": lc["h"], "conv": lc["conv"]}
    out, new_state, staged = rglru_mod.apply_rglru_block(
        cfg, p["rec"], h, state, want_states=want)
    x = x + out
    h2 = L.apply_norm(cfg, p["ln2"], x)
    x = x + L.apply_mlp(cfg, p["ffn"], h2)
    return x, new_state, staged


# ===================================================================== #
# Forward passes
# ===================================================================== #

def _sinusoid(positions, dim):
    """[B,T] -> [B,T,dim] sinusoidal embedding (whisper decoder positions)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_inputs(cfg, params, tokens, embeds, seq_pos):
    if embeds is None:
        embeds = L.embed_tokens(params["embed"], tokens)
    if cfg.is_encoder_decoder:  # whisper-style learned/sinusoid positions
        embeds = embeds + _sinusoid(seq_pos, cfg.d_model).astype(embeds.dtype)
    return embeds


def _layer_cache_slice(cfg, cache, mode):
    """Split the stacked cache into per-kind stacked dicts for scan xs."""
    kinds = cfg.layer_kinds()
    kind = kinds[0]
    if mode == "train" and kind != "X":
        return None
    names = {
        "A": ["k", "v"] if not cfg.use_mla else ["ckv", "krope"],
        "X": ["k", "v", "enc_k", "enc_v"],
        "W": ["wkv", "sx_att", "sx_ffn"],
    }[kind]
    if mode == "train":
        return None
    return {n: cache[n] for n in names if n in cache}


def _run_uniform(cfg, params, x, cache, ctx):
    """lax.scan over stacked homogeneous layers."""
    kind = cfg.layer_kinds()[0]
    mode = ctx["mode"]
    lc_stack = _layer_cache_slice(cfg, cache, mode) if cache is not None else None

    def body(carry, xs):
        h = carry
        from repro.distributed.sharding import constrain as _con, opt as _po
        if _po("residual-shard"):
            # §Perf: 2-D activation sharding — remat-stored residuals live
            # (batch over data) x (d_model over model) instead of replicated
            # over the model axis
            h = _con(h, ("pod", "data"), None, "model")
        p_l, lc_l = xs
        if kind == "W":
            h, new_lc, staged = _rwkv_block(cfg, p_l, h, lc_l, ctx)
            aux = {}
        else:
            h, new_lc, aux = _attn_block(cfg, p_l, h, lc_l, ctx, kind)
            staged = None
        ys = {"cache": new_lc, "staged": staged, "aux": aux}
        ys = {k: v for k, v in ys.items() if v}
        return h, ys

    if mode == "train":
        body = jax.checkpoint(body)
    xs = (params["blocks"], lc_stack)
    x, ys = jax.lax.scan(body, x, xs)
    return x, ys


def _run_pattern(cfg, params, x, cache, ctx):
    """Python loop over heterogeneous layers (hybrid RecurrentGemma)."""
    kinds = cfg.layer_kinds()
    mode = ctx["mode"]
    i_rec = i_attn = 0
    new_rec = {"h": [], "conv": []}
    new_attn = {"k": [], "v": []}
    staged_rec = {"h": [], "conv": []}
    for kind, p_l in zip(kinds, params["blocks_list"]):
        if kind == "R":
            lc = (None if cache is None or mode == "train" else
                  {"h": cache["h"][i_rec], "conv": cache["conv"][i_rec]})
            if mode == "train":
                x = jax.checkpoint(
                    lambda p, h: _rec_block(cfg, p, h, None, ctx)[0])(p_l, x)
                st = staged = None
            else:
                x, st, staged = _rec_block(cfg, p_l, x, lc, ctx)
                new_rec["h"].append(st["h"])
                new_rec["conv"].append(st["conv"])
            if staged is not None:
                staged_rec["h"].append(staged["h"])
                staged_rec["conv"].append(staged["conv"])
            i_rec += 1
        else:  # local attention layer
            lc = (None if cache is None or mode == "train" else
                  {"k": cache["k"][i_attn], "v": cache["v"][i_attn]})
            lctx = dict(ctx, window=cfg.local_window)
            if mode == "train":
                x = jax.checkpoint(
                    lambda p, h: _attn_block(cfg, p, h, None, lctx, "A")[0])(p_l, x)
            else:
                x, new_lc, _ = _attn_block(cfg, p_l, x, lc, lctx, "A")
                new_attn["k"].append(new_lc["k"])
                new_attn["v"].append(new_lc["v"])
            i_attn += 1
    ys = {}
    if mode != "train":
        ys["cache"] = {}
        if new_rec["h"]:
            ys["cache"]["h"] = jnp.stack(new_rec["h"])
            ys["cache"]["conv"] = jnp.stack(new_rec["conv"])
        if new_attn["k"]:
            ys["cache"]["k"] = jnp.stack(new_attn["k"])
            ys["cache"]["v"] = jnp.stack(new_attn["v"])
    if staged_rec["h"]:
        ys["staged"] = {"h": jnp.stack(staged_rec["h"]),
                        "conv": jnp.stack(staged_rec["conv"])}
    return x, ys


def _forward(cfg, params, tokens, *, embeds, cache, mode, seq_pos, rope_pos,
             window, enc_out, moe_exact):
    x = _embed_inputs(cfg, params, tokens, embeds, seq_pos)
    n_inflight = x.shape[0] * x.shape[1]
    if not moe_exact:
        moe_policy = "train"
    elif n_inflight <= 64:
        moe_policy = "exact"     # single-request verification: bit-exact
    else:
        from repro.distributed.sharding import opt as _opt
        moe_policy = "serve" if _opt("serve-capacity") else "exact"
    from repro.distributed.sharding import opt as _perf_opt
    ctx = {"mode": mode, "seq_pos": seq_pos, "rope_pos": rope_pos,
           "window": window, "enc_out": enc_out, "moe_policy": moe_policy,
           "cache_pos": None if cache is None else cache.get("pos"),
           "slots": None, "offset": None, "t_w": 0}
    if cache is not None and "pos" in cache:
        t = x.shape[1]
        r = cache["pos"].shape[1]
        # effective ring modulus: ring caches (window + SPEC_PAD slots) wrap
        # at `window` so a contiguous write of <= SPEC_PAD entries never
        # splits; full caches never wrap.
        is_ring = window and r == ring_size(cfg, 1 << 62, window)
        m_eff = (r - SPEC_PAD) if is_ring else r
        t_w = min(t, m_eff)
        ctx["t_w"] = t_w
        write_pos = seq_pos[0, -t_w:]          # positions shared across batch
        if _perf_opt("dus-cache") and mode == "decode":
            ctx["offset"] = write_pos[0] % m_eff
        else:
            # slot mapping uses the same modulus as the DUS path so mixed
            # prefill(scatter)/decode(DUS) runs agree on slot placement
            ctx["slots"] = write_pos % m_eff
        if mode in ("prefill", "decode"):
            if ctx["offset"] is not None:
                new_pos = jax.lax.dynamic_update_slice(
                    cache["pos"], seq_pos[:, -t_w:],
                    (jnp.zeros((), jnp.int32), ctx["offset"]))
            else:
                new_pos = cache["pos"].at[:, ctx["slots"]].set(
                    seq_pos[:, -t_w:])
            ctx["cache_pos"] = new_pos
    uniform = len(set(cfg.layer_kinds())) == 1
    run = _run_uniform if uniform else _run_pattern
    x, ys = run(cfg, params, x, cache, ctx)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)

    aux = {}
    if "aux" in ys:
        aux["lb_loss"] = jnp.mean(ys["aux"]["lb_loss"])
        aux["unique_experts"] = ys["aux"]["unique_experts"]  # [L]
    staged = ys.get("staged")

    new_cache = None
    if cache is not None and mode in ("prefill", "decode"):
        new_cache = dict(cache)
        new_cache.update(ys.get("cache", {}))
        if "pos" in cache:
            new_cache["pos"] = ctx["cache_pos"]
        new_cache["length"] = seq_pos[0, -1] + 1
    return logits, new_cache, aux, staged


# --------------------------------------------------------------------- #
# Public entry points
# --------------------------------------------------------------------- #

def train_forward(cfg, params, tokens, *, embeds=None, seq_pos=None,
                  rope_pos=None, window=0, enc_out=None, moe_exact=False):
    b, t = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    if seq_pos is None:
        seq_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if rope_pos is None:
        rope_pos = seq_pos
    window = window or cfg.window
    logits, _, aux, _ = _forward(cfg, params, tokens, embeds=embeds,
                                 cache=None, mode="train", seq_pos=seq_pos,
                                 rope_pos=rope_pos, window=window,
                                 enc_out=enc_out, moe_exact=moe_exact)
    return logits, aux


def prefill(cfg, params, tokens, cache, *, embeds=None, rope_pos=None,
            enc_out=None, window: int = 0, moe_exact: bool = True):
    b, t = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    seq_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if rope_pos is None:
        rope_pos = seq_pos
    window = window or cfg.window
    logits, cache, aux, _ = _forward(cfg, params, tokens, embeds=embeds,
                                     cache=cache, mode="prefill",
                                     seq_pos=seq_pos, rope_pos=rope_pos,
                                     window=window, enc_out=enc_out,
                                     moe_exact=moe_exact)
    return logits, cache, aux


def decode_step(cfg, params, cache, tokens, *, embeds=None, rope_pos=None,
                window: int = 0, moe_exact: bool = True):
    """Verify/decode T tokens starting at cache['length'].
    Returns (logits [B,T,V], new_cache, aux, staged)."""
    b, t = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    start = cache["length"]
    seq_pos = start + jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if rope_pos is None:
        rope_pos = seq_pos
    window = window or cfg.window
    logits, cache, aux, staged = _forward(cfg, params, tokens, embeds=embeds,
                                          cache=cache, mode="decode",
                                          seq_pos=seq_pos, rope_pos=rope_pos,
                                          window=window, enc_out=None,
                                          moe_exact=moe_exact)
    return logits, cache, aux, staged
