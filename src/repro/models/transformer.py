"""Model assembly for all six families with three entry points:

    train_forward(cfg, params, tokens, ...)    -> logits, aux
    prefill(cfg, params, tokens, cache, ...)   -> logits, cache, aux
    decode_step(cfg, params, cache, tokens,..) -> logits, cache, aux

Uniform-kind architectures (dense / moe / ssm / audio / vlm) stack per-layer
params with a leading L dim and run `lax.scan` over layers, keeping compile
time O(1) in depth (the 61-layer Kimi-K2 config must compile on one CPU core
with 512 host devices for the dry-run). The hybrid pattern architecture
(RecurrentGemma "RRA") uses a python loop over its 38 heterogeneous layers.

KV caches are ring buffers: ring size = full length for full attention, or
window + SPEC_PAD for sliding-window variants, so `long_500k` decode on a
windowed model allocates O(window), not O(seq). Speculative rollback is a
pure metadata operation for attention caches and an indexed select into
staged states for recurrent caches (`rollback_cache`)."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import layers as L
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv as rwkv_mod

SPEC_PAD = 16  # ring-buffer slack so speculative writes never clobber window


# ===================================================================== #
# Parameter init
# ===================================================================== #

def _init_block(cfg, kind: str, key, dtype):
    ks = jax.random.split(key, 6)
    if kind == "W":  # rwkv
        return {
            "ln1": L.init_norm(cfg, cfg.d_model, dtype),
            "tmix": rwkv_mod.init_time_mix(cfg, ks[0], dtype),
            "ln2": L.init_norm(cfg, cfg.d_model, dtype),
            "cmix": rwkv_mod.init_channel_mix(cfg, ks[1], dtype),
        }
    if kind == "R":  # rg-lru recurrent block + ffn
        return {
            "ln1": L.init_norm(cfg, cfg.d_model, dtype),
            "rec": rglru_mod.init_rglru_block(cfg, ks[0], dtype),
            "ln2": L.init_norm(cfg, cfg.d_model, dtype),
            "ffn": L.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype),
        }
    # attention-bearing kinds
    p = {"ln1": L.init_norm(cfg, cfg.d_model, dtype)}
    p["attn"] = (mla_mod.init_mla(cfg, ks[0], dtype) if cfg.use_mla
                 else attn_mod.init_attention(cfg, ks[0], dtype))
    if kind == "X":
        p["lnx"] = L.init_norm(cfg, cfg.d_model, dtype)
        p["xattn"] = attn_mod.init_cross_attention(cfg, ks[1], dtype)
    p["ln2"] = L.init_norm(cfg, cfg.d_model, dtype)
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(cfg, ks[2], dtype)
    else:
        p["ffn"] = L.init_mlp(cfg, ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    k_embed, k_blocks = jax.random.split(key)
    params: Dict[str, Any] = {"embed": L.init_embed(cfg, k_embed, dtype)}
    if len(set(kinds)) == 1:  # uniform: stacked params + scan
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_block(cfg, kinds[0], k, dtype))(keys)
    else:
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params["blocks_list"] = tuple(
            _init_block(cfg, kind, k, dtype) for kind, k in zip(kinds, keys))
    params["final_norm"] = L.init_norm(cfg, cfg.d_model, dtype)
    return params


# ===================================================================== #
# Cache
# ===================================================================== #

def ring_size(cfg, max_len: int, window: int) -> int:
    """Ring slots for a sliding-window cache: `window + SPEC_PAD` live slots
    (modulus) so writing position p only ever evicts p-window-SPEC_PAD —
    outside the window for every in-flight query — plus SPEC_PAD spill slots
    so a contiguous dynamic-update-slice write never wraps."""
    if window and window > 0:
        return min(max_len, window + 2 * SPEC_PAD)
    return max_len


def init_cache(cfg, batch: int, max_len: int, *, window: int = 0,
               dtype=None, per_row: bool = False):
    """Allocate an empty cache for `batch` sequences of up to `max_len`
    tokens. `window` (0=full) selects sliding-window attention and sizes the
    ring buffer accordingly.

    `per_row=True` adds a `lengths` [B] vector so every row keeps its own
    sequence length — the continuous-batching layout where rows join, draft
    different K_i, and roll back independently. The scalar `length` is kept
    alongside (as the row maximum) for code that only needs an upper bound."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    cache: Dict[str, Any] = {
        "length": jnp.zeros((), jnp.int32),
    }
    if per_row:
        cache["lengths"] = jnp.zeros((batch,), jnp.int32)
    n_attn = sum(1 for k in kinds if k in ("A", "X"))
    n_rec = sum(1 for k in kinds if k == "R")
    n_rwkv = sum(1 for k in kinds if k == "W")

    if n_attn:
        w_eff = window if window else (cfg.window or 0)
        r = ring_size(cfg, max_len, w_eff)
        cache["pos"] = jnp.full((batch, r), -1, jnp.int32)
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((n_attn, batch, r, cfg.kv_lora_rank), dtype)
            cache["krope"] = jnp.zeros((n_attn, batch, r, cfg.qk_rope_dim), dtype)
        else:
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            cache["k"] = jnp.zeros((n_attn, batch, r, hkv, hd), dtype)
            cache["v"] = jnp.zeros((n_attn, batch, r, hkv, hd), dtype)
        if cfg.is_encoder_decoder:
            cache["enc_k"] = jnp.zeros(
                (n_attn, batch, cfg.encoder_len, cfg.num_heads, cfg.head_dim), dtype)
            cache["enc_v"] = jnp.zeros_like(cache["enc_k"])
    if n_rwkv:
        h, n = cfg.rwkv_num_heads, cfg.rwkv_head_size
        cache["wkv"] = jnp.zeros((n_rwkv, batch, h, n, n), jnp.float32)
        cache["sx_att"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
        cache["sx_ffn"] = jnp.zeros((n_rwkv, batch, cfg.d_model), dtype)
    if n_rec:
        cache["h"] = jnp.zeros((n_rec, batch, cfg.d_rnn), jnp.float32)
        cache["conv"] = jnp.zeros(
            (n_rec, batch, cfg.conv1d_width - 1, cfg.d_rnn), dtype)
    return cache


def bucket_length(t: int, minimum: int = 1) -> int:
    """Round a span length up to the next power of two. Chunked prefill pads
    every [B, T] pass to a bucketed T so the jitted pass is traced once per
    bucket instead of once per distinct prompt/chunk length — the blocking
    prefill's retrace-per-prompt-length pathology does not come back through
    the chunked path."""
    t = max(int(t), int(minimum), 1)
    return 1 << (t - 1).bit_length()


def cache_slots(cache, positions_1d):
    """Map absolute positions [T] to ring slots [T]."""
    r = cache["pos"].shape[1]
    return positions_1d % r


def rollback_cache(cfg, cache, staged, n_accept, length_before):
    """Rewind the cache to `length_before + n_accept` after verification.

    Attention caches: metadata-only (invalidate pos of rejected slots).
    Recurrent caches: select the staged state at index n_accept.

    Scalar `n_accept`/`length_before` rewind every row uniformly (the legacy
    single-request path). [B]-shaped arrays rewind each row to its own
    accepted length — one vectorized truncation for the whole batch, the
    continuous-batching equivalent of B independent rollbacks."""
    n_accept = jnp.asarray(n_accept, jnp.int32)
    length_before = jnp.asarray(length_before, jnp.int32)
    new_len = length_before + n_accept
    cache = dict(cache)
    if new_len.ndim == 0:
        cache["length"] = new_len
        if "lengths" in cache:
            cache["lengths"] = jnp.broadcast_to(new_len,
                                                cache["lengths"].shape)
        row_len = new_len          # broadcasts over [B,R] pos
        staged_idx = n_accept      # same staged index for every row
    else:
        cache["lengths"] = new_len
        cache["length"] = jnp.max(new_len)
        row_len = new_len[:, None]
        staged_idx = None
    if "pos" in cache:
        cache["pos"] = jnp.where(cache["pos"] >= row_len, -1, cache["pos"])
    if staged:
        for name in ("wkv", "sx_att", "sx_ffn", "h", "conv"):
            if name in staged and staged[name] is not None:
                st = staged[name]  # [L, T+1, B, ...]
                if staged_idx is not None:
                    sel = jnp.take(st, staged_idx, axis=1)
                else:
                    # per-row gather: row b keeps the state after consuming
                    # its own n_accept[b] tokens
                    sel = st[:, n_accept, jnp.arange(n_accept.shape[0])]
                cache[name] = sel.astype(cache[name].dtype)
    return cache


def write_cache_row(cache, slot: int, row_cache):
    """Copy a batch-1 cache (e.g. a freshly prefilled request) into row
    `slot` of a per-row batched cache — the join half of continuous
    batching. Both caches must share ring size / layer layout."""
    out = dict(cache)
    for name, buf in cache.items():
        if name in ("length", "lengths"):
            continue
        src = row_cache[name]
        if name == "pos":                       # [B,R] <- [1,R]
            out[name] = buf.at[slot].set(src[0])
        else:                                   # [L,B,...] <- [L,1,...]
            out[name] = buf.at[:, slot].set(src[:, 0].astype(buf.dtype))
    row_len = (row_cache["lengths"][0] if "lengths" in row_cache
               else row_cache["length"])
    if "lengths" in cache:
        lengths = cache["lengths"].at[slot].set(row_len)
        out["lengths"] = lengths
        out["length"] = jnp.max(lengths)
    else:
        out["length"] = jnp.maximum(cache["length"], row_len)
    return out


def clear_cache_row(cache, slot: int):
    """Retire row `slot`: zero its length and invalidate its ring positions
    (stale K/V content is masked out by pos == -1, no data wipe needed)."""
    out = dict(cache)
    if "pos" in cache:
        out["pos"] = cache["pos"].at[slot].set(-1)
    if "lengths" in cache:
        lengths = cache["lengths"].at[slot].set(0)
        out["lengths"] = lengths
        out["length"] = jnp.max(lengths)
    return out


# ===================================================================== #
# Block application
# ===================================================================== #

def _write_ring(buf_l, vals, wctx):
    """Write T new entries into a cache buffer [B,R,...].

    Three modes (wctx from _forward):
      * slots scatter (baseline): buf.at[:, slots].set(vals) — one slot
        vector shared by every row
      * per-row scatter (continuous batching): rows sit at different
        lengths, so row b writes to its own slots_bt[b] ring positions
      * contiguous dynamic_update_slice (§Perf "dus-cache"): in-place, no
        SPMD resharding copy — the scatter path triggers XLA "involuntary
        full rematerialization" of the whole stacked cache per layer."""
    vals = vals.astype(buf_l.dtype)
    if wctx.get("offset") is not None:
        starts = (jnp.zeros((), jnp.int32), wctx["offset"]) + tuple(
            jnp.zeros((), jnp.int32) for _ in range(buf_l.ndim - 2))
        return jax.lax.dynamic_update_slice(buf_l, vals, starts)
    if wctx.get("slots_bt") is not None:
        slots_bt = wctx["slots_bt"]                       # [B,T]
        rows = jnp.arange(slots_bt.shape[0])[:, None]     # [B,1]
        return buf_l.at[rows, slots_bt].set(vals)
    return buf_l.at[:, wctx["slots"]].set(vals)


def _attn_block(cfg, p, x, lc, ctx, kind):
    """Attention(+cross)(+ffn/moe) block.

    lc: layer cache dict ({"k","v"} or {"ckv","krope"}, + enc_*) or None.
    ctx: dict with mode, seq_pos [B,T], rope_pos, cache_pos [B,R] (updated),
         slots [T], window, enc_out.
    Returns (x, new_layer_cache, aux)."""
    mode = ctx["mode"]
    window = ctx["window"]
    seq_pos, rope_pos = ctx["seq_pos"], ctx["rope_pos"]
    h = L.apply_norm(cfg, p["ln1"], x)
    new_lc = {}
    if cfg.use_mla:
        if mode == "decode":
            ckv_new, krope_new = mla_mod.latent_kv(cfg, p["attn"], h, seq_pos)
            ckv = _write_ring(lc["ckv"], ckv_new, ctx)
            krope = _write_ring(lc["krope"], krope_new, ctx)
            out = mla_mod.mla_absorbed(cfg, p["attn"], h, seq_pos, ckv, krope,
                                       ctx["cache_pos"], window=window)
            new_lc.update(ckv=ckv, krope=krope)
        else:
            out, (ckv_new, krope_new) = mla_mod.mla_full(cfg, p["attn"], h, seq_pos)
            if mode == "prefill":
                t_w = ctx["t_w"]
                new_lc["ckv"] = _write_ring(lc["ckv"], ckv_new[:, -t_w:],
                                            ctx)
                new_lc["krope"] = _write_ring(lc["krope"],
                                              krope_new[:, -t_w:], ctx)
    else:
        q, k, v = attn_mod.qkv(cfg, p["attn"], h, rope_pos)
        if mode == "decode":
            kb = _write_ring(lc["k"], k, ctx)
            vb = _write_ring(lc["v"], v, ctx)
            out = attn_mod.attend(q, kb.astype(q.dtype), vb.astype(q.dtype),
                                  seq_pos, ctx["cache_pos"],
                                  window=window, causal=True)
            new_lc.update(k=kb, v=vb)
        else:
            out = attn_mod.attend(q, k, v, seq_pos, seq_pos,
                                  window=window, causal=True)
            if mode == "prefill":
                t_w = ctx["t_w"]
                new_lc["k"] = _write_ring(lc["k"], k[:, -t_w:], ctx)
                new_lc["v"] = _write_ring(lc["v"], v[:, -t_w:], ctx)
        b, t = out.shape[:2]
        out = out.reshape(b, t, -1) @ p["attn"]["wo"]
    x = x + out

    if kind == "X":  # cross-attention to (stub) encoder states
        hx = L.apply_norm(cfg, p["lnx"], x)
        if mode == "prefill":
            enc_k, enc_v = attn_mod.encode_cross_kv(cfg, p["xattn"],
                                                    ctx["enc_out"])
            new_lc["enc_k"], new_lc["enc_v"] = enc_k, enc_v
        elif mode == "decode":
            enc_k, enc_v = lc["enc_k"], lc["enc_v"]
            new_lc["enc_k"], new_lc["enc_v"] = enc_k, enc_v
        else:  # train
            enc_k, enc_v = attn_mod.encode_cross_kv(cfg, p["xattn"],
                                                    ctx["enc_out"])
        x = x + attn_mod.cross_attention(cfg, p["xattn"], hx,
                                         enc_k.astype(hx.dtype),
                                         enc_v.astype(hx.dtype))

    h2 = L.apply_norm(cfg, p["ln2"], x)
    aux = {}
    if cfg.is_moe:
        b, t, d = h2.shape
        y2d, moe_aux = moe_mod.apply_moe(cfg, p["moe"], h2.reshape(b * t, d),
                                         capacity_policy=ctx["moe_policy"],
                                         packed=ctx.get("moe_packed", False))
        x = x + y2d.reshape(b, t, d)
        aux["lb_loss"] = moe_aux["lb_loss"]
        aux["unique_experts"] = moe_aux["unique_experts"]
        if mode == "decode" and "expert_idx" in moe_aux:
            # batch-aware accounting: per-row counts always; the union
            # replaces the raw all-token count when a padding mask marks
            # ragged [1+K_i] spans (padding must not inflate the cost driver)
            idx_btk = moe_aux["expert_idx"].reshape(b, t, -1)
            union, per_row = moe_mod.unique_expert_stats(
                cfg, idx_btk, ctx.get("token_mask"))
            aux["unique_experts_row"] = per_row
            if ctx.get("token_mask") is not None:
                aux["unique_experts"] = union
            # per-expert activation bitmap [E] for residency tracking
            # (docs/offload.md): padding routes to the sentinel bucket e
            e = cfg.num_experts
            flat = idx_btk
            if ctx.get("token_mask") is not None:
                flat = jnp.where(ctx["token_mask"][:, :, None], idx_btk, e)
            hits = jnp.zeros((e + 1,), jnp.int32).at[
                flat.reshape(-1)].add(1)
            aux["experts_active"] = hits[:e] > 0
            if ctx.get("want_moe_h"):
                # the MoE input (post-ln2 hidden state) feeding this
                # layer's router — the layered prefetcher probes NEXT
                # pass's per-layer routing from it (docs/offload.md)
                aux["moe_h"] = h2
            sid = ctx.get("ep_shard_ids")
            if sid is not None:
                # EP-shard accounting: the hottest shard's local activated
                # experts gate a sharded pass (docs/expert_parallel.md)
                per_shard, row_shard = moe_mod.shard_expert_stats(
                    cfg, idx_btk, sid, ctx.get("token_mask"),
                    n_shards=ctx.get("ep_n_shards"))
                aux["unique_experts_shard"] = per_shard
                aux["unique_experts_row_shard"] = row_shard
    else:
        x = x + L.apply_mlp(cfg, p["ffn"], h2)
        aux["lb_loss"] = jnp.zeros((), jnp.float32)
        aux["unique_experts"] = jnp.zeros((), jnp.int32)
        if mode == "decode":
            aux["unique_experts_row"] = jnp.zeros((x.shape[0],), jnp.int32)
            aux["experts_active"] = jnp.zeros((cfg.num_experts,), bool)
            sid = ctx.get("ep_shard_ids")
            if sid is not None:
                s_n = (int(ctx["ep_n_shards"]) if ctx.get("ep_n_shards")
                       else int(max(sid)) + 1)
                aux["unique_experts_shard"] = jnp.zeros((s_n,), jnp.int32)
                aux["unique_experts_row_shard"] = jnp.zeros(
                    (x.shape[0], s_n), jnp.int32)
    return x, new_lc, aux


def _rwkv_block(cfg, p, x, lc, ctx):
    mode = ctx["mode"]
    want = mode == "decode"
    h = L.apply_norm(cfg, p["ln1"], x)
    if mode == "train":
        b = x.shape[0]
        sx_att = jnp.zeros((b, cfg.d_model), x.dtype)
        sx_ffn = jnp.zeros((b, cfg.d_model), x.dtype)
        s0 = jnp.zeros((b, cfg.rwkv_num_heads, cfg.rwkv_head_size,
                        cfg.rwkv_head_size), jnp.float32)
    else:
        sx_att, sx_ffn, s0 = lc["sx_att"], lc["sx_ffn"], lc["wkv"]
    out, last_x, s_last, states = rwkv_mod.time_mix(
        cfg, p["tmix"], h, sx_att.astype(h.dtype), s0, want_states=want)
    x = x + out
    h2 = L.apply_norm(cfg, p["ln2"], x)
    out2, last_x2 = rwkv_mod.channel_mix(cfg, p["cmix"], h2,
                                         sx_ffn.astype(h2.dtype))
    x = x + out2
    new_lc = {"wkv": s_last, "sx_att": last_x, "sx_ffn": last_x2}
    staged = None
    if want:
        # staged token-shift states: value after consuming j tokens
        sx_att_staged = jnp.concatenate(
            [sx_att.astype(h.dtype)[None], jnp.moveaxis(h, 1, 0)], axis=0)
        sx_ffn_staged = jnp.concatenate(
            [sx_ffn.astype(h2.dtype)[None], jnp.moveaxis(h2, 1, 0)], axis=0)
        staged = {"wkv": states, "sx_att": sx_att_staged,
                  "sx_ffn": sx_ffn_staged}
    return x, new_lc, staged


def _rec_block(cfg, p, x, lc, ctx):
    mode = ctx["mode"]
    want = mode == "decode"
    h = L.apply_norm(cfg, p["ln1"], x)
    if mode == "train":
        b = x.shape[0]
        state = {"h": jnp.zeros((b, cfg.d_rnn), jnp.float32),
                 "conv": jnp.zeros((b, cfg.conv1d_width - 1, cfg.d_rnn),
                                   x.dtype)}
    else:
        state = {"h": lc["h"], "conv": lc["conv"]}
    out, new_state, staged = rglru_mod.apply_rglru_block(
        cfg, p["rec"], h, state, want_states=want)
    x = x + out
    h2 = L.apply_norm(cfg, p["ln2"], x)
    x = x + L.apply_mlp(cfg, p["ffn"], h2)
    return x, new_state, staged


# ===================================================================== #
# Forward passes
# ===================================================================== #

def _sinusoid(positions, dim):
    """[B,T] -> [B,T,dim] sinusoidal embedding (whisper decoder positions)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed_inputs(cfg, params, tokens, embeds, seq_pos):
    if embeds is None:
        embeds = L.embed_tokens(params["embed"], tokens)
    if cfg.is_encoder_decoder:  # whisper-style learned/sinusoid positions
        embeds = embeds + _sinusoid(seq_pos, cfg.d_model).astype(embeds.dtype)
    return embeds


def _layer_cache_slice(cfg, cache, mode):
    """Split the stacked cache into per-kind stacked dicts for scan xs."""
    kinds = cfg.layer_kinds()
    kind = kinds[0]
    if mode == "train" and kind != "X":
        return None
    names = {
        "A": ["k", "v"] if not cfg.use_mla else ["ckv", "krope"],
        "X": ["k", "v", "enc_k", "enc_v"],
        "W": ["wkv", "sx_att", "sx_ffn"],
    }[kind]
    if mode == "train":
        return None
    return {n: cache[n] for n in names if n in cache}


def _run_uniform(cfg, params, x, cache, ctx):
    """lax.scan over stacked homogeneous layers."""
    kind = cfg.layer_kinds()[0]
    mode = ctx["mode"]
    lc_stack = _layer_cache_slice(cfg, cache, mode) if cache is not None else None

    def body(carry, xs):
        h = carry
        from repro.distributed.sharding import constrain as _con, opt as _po
        if _po("residual-shard"):
            # §Perf: 2-D activation sharding — remat-stored residuals live
            # (batch over data) x (d_model over model) instead of replicated
            # over the model axis
            h = _con(h, ("pod", "data"), None, "model")
        p_l, lc_l = xs
        if kind == "W":
            h, new_lc, staged = _rwkv_block(cfg, p_l, h, lc_l, ctx)
            aux = {}
        else:
            h, new_lc, aux = _attn_block(cfg, p_l, h, lc_l, ctx, kind)
            staged = None
        ys = {"cache": new_lc, "staged": staged, "aux": aux}
        ys = {k: v for k, v in ys.items() if v}
        return h, ys

    if mode == "train":
        body = jax.checkpoint(body)
    xs = (params["blocks"], lc_stack)
    x, ys = jax.lax.scan(body, x, xs)
    return x, ys


def _run_pattern(cfg, params, x, cache, ctx):
    """Python loop over heterogeneous layers (hybrid RecurrentGemma)."""
    kinds = cfg.layer_kinds()
    mode = ctx["mode"]
    i_rec = i_attn = 0
    new_rec = {"h": [], "conv": []}
    new_attn = {"k": [], "v": []}
    staged_rec = {"h": [], "conv": []}
    for kind, p_l in zip(kinds, params["blocks_list"]):
        if kind == "R":
            lc = (None if cache is None or mode == "train" else
                  {"h": cache["h"][i_rec], "conv": cache["conv"][i_rec]})
            if mode == "train":
                x = jax.checkpoint(
                    lambda p, h: _rec_block(cfg, p, h, None, ctx)[0])(p_l, x)
                st = staged = None
            else:
                x, st, staged = _rec_block(cfg, p_l, x, lc, ctx)
                new_rec["h"].append(st["h"])
                new_rec["conv"].append(st["conv"])
            if staged is not None:
                staged_rec["h"].append(staged["h"])
                staged_rec["conv"].append(staged["conv"])
            i_rec += 1
        else:  # local attention layer
            lc = (None if cache is None or mode == "train" else
                  {"k": cache["k"][i_attn], "v": cache["v"][i_attn]})
            lctx = dict(ctx, window=cfg.local_window)
            if mode == "train":
                x = jax.checkpoint(
                    lambda p, h: _attn_block(cfg, p, h, None, lctx, "A")[0])(p_l, x)
            else:
                x, new_lc, _ = _attn_block(cfg, p_l, x, lc, lctx, "A")
                new_attn["k"].append(new_lc["k"])
                new_attn["v"].append(new_lc["v"])
            i_attn += 1
    ys = {}
    if mode != "train":
        ys["cache"] = {}
        if new_rec["h"]:
            ys["cache"]["h"] = jnp.stack(new_rec["h"])
            ys["cache"]["conv"] = jnp.stack(new_rec["conv"])
        if new_attn["k"]:
            ys["cache"]["k"] = jnp.stack(new_attn["k"])
            ys["cache"]["v"] = jnp.stack(new_attn["v"])
    if staged_rec["h"]:
        ys["staged"] = {"h": jnp.stack(staged_rec["h"]),
                        "conv": jnp.stack(staged_rec["conv"])}
    return x, ys


def _forward(cfg, params, tokens, *, embeds, cache, mode, seq_pos, rope_pos,
             window, enc_out, moe_exact, token_mask=None, ep_shard_ids=None,
             ep_n_shards=None, moe_packed=False, want_moe_h=False):
    x = _embed_inputs(cfg, params, tokens, embeds, seq_pos)
    n_inflight = x.shape[0] * x.shape[1]
    if not moe_exact:
        moe_policy = "train"
    elif n_inflight <= 64:
        moe_policy = "exact"     # single-request verification: bit-exact
    else:
        from repro.distributed.sharding import opt as _opt
        moe_policy = "serve" if _opt("serve-capacity") else "exact"
    from repro.distributed.sharding import opt as _perf_opt
    # per-row layout: rows sit at independent lengths, so ring slots (and
    # pos updates) are computed per row rather than shared across the batch
    per_row = cache is not None and "lengths" in cache
    ctx = {"mode": mode, "seq_pos": seq_pos, "rope_pos": rope_pos,
           "window": window, "enc_out": enc_out, "moe_policy": moe_policy,
           "cache_pos": None if cache is None else cache.get("pos"),
           "slots": None, "slots_bt": None, "offset": None, "t_w": 0,
           "token_mask": token_mask, "ep_shard_ids": ep_shard_ids,
           "ep_n_shards": ep_n_shards, "moe_packed": moe_packed,
           "want_moe_h": want_moe_h}
    if cache is not None and "pos" in cache:
        t = x.shape[1]
        r = cache["pos"].shape[1]
        # effective ring modulus: ring caches (window + SPEC_PAD slots) wrap
        # at `window` so a contiguous write of <= SPEC_PAD entries never
        # splits; full caches never wrap.
        is_ring = window and r == ring_size(cfg, 1 << 62, window)
        m_eff = (r - SPEC_PAD) if is_ring else r
        t_w = min(t, m_eff)
        ctx["t_w"] = t_w
        if per_row:
            # a contiguous DUS is impossible when offsets differ per row
            ctx["slots_bt"] = seq_pos[:, -t_w:] % m_eff
        elif _perf_opt("dus-cache") and mode == "decode":
            ctx["offset"] = seq_pos[0, -t_w:][0] % m_eff
        else:
            # slot mapping uses the same modulus as the DUS path so mixed
            # prefill(scatter)/decode(DUS) runs agree on slot placement
            ctx["slots"] = seq_pos[0, -t_w:] % m_eff
        if mode in ("prefill", "decode"):
            if ctx["offset"] is not None:
                new_pos = jax.lax.dynamic_update_slice(
                    cache["pos"], seq_pos[:, -t_w:],
                    (jnp.zeros((), jnp.int32), ctx["offset"]))
            elif ctx["slots_bt"] is not None:
                rows = jnp.arange(ctx["slots_bt"].shape[0])[:, None]
                new_pos = cache["pos"].at[rows, ctx["slots_bt"]].set(
                    seq_pos[:, -t_w:])
            else:
                new_pos = cache["pos"].at[:, ctx["slots"]].set(
                    seq_pos[:, -t_w:])
            ctx["cache_pos"] = new_pos
    uniform = len(set(cfg.layer_kinds())) == 1
    run = _run_uniform if uniform else _run_pattern
    x, ys = run(cfg, params, x, cache, ctx)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)

    aux = {}
    if "aux" in ys:
        aux["lb_loss"] = jnp.mean(ys["aux"]["lb_loss"])
        aux["unique_experts"] = ys["aux"]["unique_experts"]  # [L]
        if "unique_experts_row" in ys["aux"]:
            aux["unique_experts_row"] = ys["aux"]["unique_experts_row"]  # [L,B]
        if "experts_active" in ys["aux"]:
            aux["experts_active"] = ys["aux"]["experts_active"]  # [L,E]
        if "moe_h" in ys["aux"]:
            aux["moe_h"] = ys["aux"]["moe_h"]                    # [L,B,T,D]
        if "unique_experts_shard" in ys["aux"]:
            aux["unique_experts_shard"] = \
                ys["aux"]["unique_experts_shard"]            # [L,S]
            aux["unique_experts_row_shard"] = \
                ys["aux"]["unique_experts_row_shard"]        # [L,B,S]
    staged = ys.get("staged")

    new_cache = None
    if cache is not None and mode in ("prefill", "decode"):
        new_cache = dict(cache)
        new_cache.update(ys.get("cache", {}))
        if "pos" in cache:
            new_cache["pos"] = ctx["cache_pos"]
        if per_row:
            new_cache["lengths"] = seq_pos[:, -1] + 1
            new_cache["length"] = jnp.max(new_cache["lengths"])
        else:
            new_cache["length"] = seq_pos[0, -1] + 1
    return logits, new_cache, aux, staged


# --------------------------------------------------------------------- #
# Public entry points
# --------------------------------------------------------------------- #

def train_forward(cfg, params, tokens, *, embeds=None, seq_pos=None,
                  rope_pos=None, window=0, enc_out=None, moe_exact=False):
    b, t = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    if seq_pos is None:
        seq_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if rope_pos is None:
        rope_pos = seq_pos
    window = window or cfg.window
    logits, _, aux, _ = _forward(cfg, params, tokens, embeds=embeds,
                                 cache=None, mode="train", seq_pos=seq_pos,
                                 rope_pos=rope_pos, window=window,
                                 enc_out=enc_out, moe_exact=moe_exact)
    return logits, aux


def prefill(cfg, params, tokens, cache, *, embeds=None, rope_pos=None,
            enc_out=None, window: int = 0, moe_exact: bool = True):
    b, t = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    seq_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if rope_pos is None:
        rope_pos = seq_pos
    window = window or cfg.window
    logits, cache, aux, _ = _forward(cfg, params, tokens, embeds=embeds,
                                     cache=cache, mode="prefill",
                                     seq_pos=seq_pos, rope_pos=rope_pos,
                                     window=window, enc_out=enc_out,
                                     moe_exact=moe_exact)
    return logits, cache, aux


def decode_step(cfg, params, cache, tokens, *, embeds=None, rope_pos=None,
                window: int = 0, moe_exact: bool = True, token_mask=None,
                ep_shard_ids=None, ep_n_shards=None, moe_packed=False,
                want_moe_h=False):
    """Verify/decode T tokens per row. Single-request caches start every row
    at the scalar cache['length']; per-row caches (init_cache(per_row=True))
    start row b at cache['lengths'][b], which is how a continuous batch
    verifies ragged [1+K_i] spans padded to a common T in one pass.
    `token_mask` [B,T] marks the real tokens of each span — padding tokens
    still flow through the network (their writes are rolled back) but are
    excluded from the expert-union accounting.
    `ep_shard_ids` (length-E expert -> EP shard map; see
    core/cost_model.ExpertPlacement) additionally emits per-shard and
    per-row-per-shard distinct-expert counts (`unique_experts_shard` [L,S],
    `unique_experts_row_shard` [L,B,S]) — the hottest-shard telemetry an
    EP-sharded serving deployment prices its passes with.  It may be a
    static tuple or a traced array (the engine's online replica routing
    passes one); in the traced case `ep_n_shards` must carry the static
    shard count.  `moe_packed=True` runs MoE layers on the union-packed
    verification path (see models/moe.apply_moe) — bit-identical outputs,
    union-scaled weight traffic.  `want_moe_h=True` additionally returns
    the per-layer MoE inputs (`aux["moe_h"]` [L,B,T,D], the post-ln2
    hidden states feeding each layer's router) — the layered prefetcher's
    per-layer probe basis (docs/offload.md).
    Returns (logits [B,T,V], new_cache, aux, staged)."""
    b, t = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    offs = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if "lengths" in cache:
        seq_pos = cache["lengths"][:, None] + offs
    else:
        seq_pos = cache["length"] + offs
    if rope_pos is None:
        rope_pos = seq_pos
    window = window or cfg.window
    logits, cache, aux, staged = _forward(cfg, params, tokens, embeds=embeds,
                                          cache=cache, mode="decode",
                                          seq_pos=seq_pos, rope_pos=rope_pos,
                                          window=window, enc_out=None,
                                          moe_exact=moe_exact,
                                          token_mask=token_mask,
                                          ep_shard_ids=ep_shard_ids,
                                          ep_n_shards=ep_n_shards,
                                          moe_packed=moe_packed,
                                          want_moe_h=want_moe_h)
    return logits, cache, aux, staged


def prefill_chunk(cfg, params, cache, tokens, *, token_mask=None,
                  rope_pos=None, window: int = 0, moe_exact: bool = True):
    """Advance cache rows by their masked prompt-chunk tokens — the chunked
    half of non-blocking admission.

    Chunked prefill is verification-shaped compute: row b's chunk enters at
    positions lengths[b]..lengths[b]+T-1, attends causally to its own cached
    context plus the in-chunk prefix, and writes its KV exactly like a
    decode span. It is therefore the decode pass with `token_mask` doing the
    ragged-chunk bookkeeping, which is what lets a serving engine pack
    prefill chunks and speculative [1+K_i] decode spans into ONE padded
    batched pass (prefill tokens then count toward the expert union — the
    paper's Fig. 2 cost driver now includes admission pressure). Callers
    roll each row back to its real chunk length, exactly like rejected
    drafts, and should pad T with `bucket_length` so jit traces are reused
    across prompt lengths.

    Returns (logits [B,T,V], new_cache, aux, staged); a row's last real
    position holds the next-token distribution once its prompt is done."""
    return decode_step(cfg, params, cache, tokens, rope_pos=rope_pos,
                       window=window, moe_exact=moe_exact,
                       token_mask=token_mask)
