"""Mixture-of-Experts layer: top-k router (+optional shared experts) and a
capacity-based scatter/gather expert dispatch.

Design notes (TPU adaptation, see DESIGN.md §3):
  * Dispatch uses integer scatter/gather (zero-FLOP data movement) plus a
    stacked-expert einsum whose FLOP count is E*C*d*F with
    C = ceil(T*k/E * capacity_factor)  ==>  ~active FLOPs * capacity_factor.
    This keeps the dry-run roofline honest about MoE sparsity (a one-hot
    dispatch einsum would add a T*E*C*d term that swamps everything).
  * The routed expert indices are also returned so (a) the serving engine can
    feed *unique activated expert counts* to Cascade's cost model — the
    paper's central quantity — and (b) the Pallas `moe_gmm` kernel path can
    consume the identical routing decision.
  * Verification steps (decode) use exact capacity C=T so no token is ever
    dropped (drops would corrupt rejection sampling); training uses the
    standard GShard capacity factor with drop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _dense_init, init_mlp, apply_mlp


def init_moe(cfg, key, dtype):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), dtype, scale=0.02),
        "w_gate": _dense_init(ks[1], (e, d, f), dtype),
        "w_up": _dense_init(ks[2], (e, d, f), dtype),
        "w_down": _dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d, f * cfg.num_shared_experts, dtype)
    return p


def route(cfg, p, x2d):
    """x2d: [T,d] -> (weights [T,k], idx [T,k], probs [T,E])."""
    logits = x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    if cfg.router_score == "sigmoid":        # DeepSeek-V3 / Kimi-K2 style
        scores = jax.nn.sigmoid(logits)
        top, idx = jax.lax.top_k(scores, cfg.experts_per_token)
        weights = top / (jnp.sum(top, -1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, -1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top, idx = jax.lax.top_k(probs, cfg.experts_per_token)
        weights = top / (jnp.sum(top, -1, keepdims=True) + 1e-20)
    return weights, idx, probs


def load_balance_loss(cfg, probs, idx):
    """Switch-Transformer auxiliary loss: E * sum_e f_e * P_e."""
    e = cfg.num_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)      # [T,k,E]
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # [E]
    frac_probs = jnp.mean(probs, axis=0)                     # [E]
    return e * jnp.sum(frac_tokens * frac_probs) / cfg.experts_per_token


def unique_expert_count(cfg, idx):
    """Number of distinct experts activated by this batch of tokens — the
    paper's data-movement driver (§2.4). idx: [T,k] -> scalar int."""
    hits = jnp.zeros((cfg.num_experts,), jnp.int32).at[idx.reshape(-1)].add(1)
    return jnp.sum(hits > 0)


def unique_expert_stats(cfg, idx_btk, token_mask=None):
    """Per-request AND batch-union distinct-expert counts — the two
    quantities batch-aware cost accounting needs (union drives the shared
    verification bytes; per-row counts drive the marginal split).

    idx_btk: [B,T,k] routed expert ids; token_mask: [B,T] bool marking the
    real (non-padding) tokens of the ragged [1+K_i] spans, or None for all
    valid. Returns (union scalar, per_row [B])."""
    b, t, k = idx_btk.shape
    e = cfg.num_experts
    if token_mask is not None:
        # padding tokens scatter into a sentinel bucket that is never counted
        idx_btk = jnp.where(token_mask[:, :, None], idx_btk, e)
    flat = idx_btk.reshape(b, t * k)
    rows = jnp.arange(b)[:, None]
    hits = jnp.zeros((b, e + 1), jnp.int32).at[rows, flat].add(1)
    per_row = jnp.sum(hits[:, :e] > 0, axis=-1)
    union = jnp.sum(jnp.sum(hits[:, :e], axis=0) > 0)
    return union, per_row


def shard_expert_stats(cfg, idx_btk, shard_of, token_mask=None,
                       n_shards=None):
    """Per-EP-shard distinct-expert counts: the batch union restricted to
    each shard's resident experts [S] and the per-row restriction [B,S] —
    the gating-shard quantities the sharded cost model prices (the pass
    completes only when the hottest shard has streamed its local activated
    experts; see core/cost_model.ExpertPlacement).

    idx_btk: [B,T,k] routed expert ids; shard_of: length-E int sequence
    mapping expert -> shard — either a static python sequence, or a traced
    array (the engine's online replica routing feeds one), in which case
    `n_shards` must be given since the shard count cannot be read off a
    tracer; token_mask: [B,T] bool marking real tokens (None = all valid).
    Because every expert lives on exactly one shard, the per-shard counts
    partition `unique_expert_stats`' union and the per-row counts
    partition its per_row."""
    b, t, k = idx_btk.shape
    e = cfg.num_experts
    s_n = int(n_shards) if n_shards is not None else int(max(shard_of)) + 1
    member = jax.nn.one_hot(jnp.asarray(shard_of, jnp.int32), s_n,
                            dtype=jnp.int32)                   # [E,S]
    if token_mask is not None:
        idx_btk = jnp.where(token_mask[:, :, None], idx_btk, e)
    flat = idx_btk.reshape(b, t * k)
    rows = jnp.arange(b)[:, None]
    hits = jnp.zeros((b, e + 1), jnp.int32).at[rows, flat].add(1)
    active = (hits[:, :e] > 0).astype(jnp.int32)               # [B,E]
    per_row_shard = active @ member                            # [B,S]
    union_active = (jnp.sum(hits[:, :e], axis=0) > 0).astype(jnp.int32)
    per_shard = union_active @ member                          # [S]
    return per_shard, per_row_shard


CAPACITY_FACTORS = {"train": 1.25, "serve": 2.0}


def _capacity(cfg, n_tokens: int, policy: str) -> int:
    """Tokens-per-expert buffer size.

    "exact":  C = T — no drop is possible (top-k experts are distinct per
              token); required for bit-exact speculative verification at
              single-request scale (the paper's single-batch setting).
    "train":  GShard capacity factor 1.25 (drops allowed, standard).
    "serve":  factor 2.0 — for batched decode/prefill, where C = T would
              make the dispatch buffer E x T x d (the §Perf kimi-decode
              finding); drop probability at 2x expected load is negligible
              and a dropped token only costs a skipped speculation."""
    if policy == "exact":
        return n_tokens
    cf = CAPACITY_FACTORS[policy]
    cap = int(n_tokens * cfg.experts_per_token * cf) // cfg.num_experts + 1
    # never below k (tiny batches) and never above T (pointless)
    return max(min(n_tokens, cap), min(n_tokens, cfg.experts_per_token))


def packed_expert_cap(cfg, n_tokens: int) -> int:
    """Static slot count U_pad of the packed verification layout.

    A T-token pass routes at most min(T*k, E) distinct experts, so the
    packed dispatch buffer needs at most that many expert slots.  The
    bound is pow-2 bucketed (reusing the span bucketing of
    `transformer.bucket_length`) so the jit trace is keyed on the same
    already-bucketed token counts the engine produces — U_pad changes only
    when the span bucket does, never per routing outcome."""
    from .transformer import bucket_length
    u = min(n_tokens * cfg.experts_per_token, cfg.num_experts)
    return min(bucket_length(u), cfg.num_experts)


def moe_pass_counters(cfg, n_tokens: int, *, capacity_policy: str = "exact",
                      packed: bool = False, weight_bytes: int = None,
                      precision=None) -> dict:
    """Dry-run counters for one MoE layer's FFN pass: the expert-weight
    bytes the dispatch path streams and the FLOPs its stacked matmuls
    execute.  These mirror the implementation exactly — the dense path
    einsums over all E experts; the packed path gathers and multiplies
    only the U_pad = `packed_expert_cap` slots — and back the scaling
    gates in `benchmarks/serving_micro.py --calibrate`.  Bytes price at
    the precision spec's expert class (`core.cost_model.Precision`;
    `weight_bytes` kept as a legacy uniform override) — quantized expert
    storage streams 1 byte/param."""
    if weight_bytes is None:
        from repro.core.cost_model import Precision
        weight_bytes = (precision.expert if precision is not None
                        else Precision.DEFAULT.expert)
    c = _capacity(cfg, n_tokens, capacity_policy)
    streamed = (packed_expert_cap(cfg, n_tokens) if packed
                else cfg.num_experts)
    mult = 3 if cfg.activation == "swiglu" else 2
    d, f = cfg.d_model, cfg.moe_d_ff
    return {
        "experts_streamed": streamed,
        "capacity": c,
        "expert_weight_bytes": streamed * mult * d * f * weight_bytes,
        "ffn_flops": 2.0 * streamed * c * d * f * mult,
    }


def quantize_transformer_experts(params, mode: str = "int8",
                                 quantile: float = 1.0) -> dict:
    """Quantize the routed-expert stacks of a FULL transformer params tree
    (the stacked-layer layout `transformer.init_params` builds:
    blocks/moe/w_* with a leading [L, E, ...] axis), returning a new tree.
    Scales are per-(layer, expert): `lax.scan` slices `w_up_q8` [L, E, d,
    F] -> [E, d, F] and `w_up_s` [L, E] -> [E] per layer, exactly the
    storage `apply_moe` detects. Router/shared/dense weights stay bf16 —
    the mixed-precision deployment `core.cost_model.Precision` prices.
    Modes as in `kernels.moe_gmm.quantize_moe_experts`."""
    from repro.kernels.moe_gmm.quant import (QUANT_SUFFIX, SCALE_SUFFIX,
                                             fake_quant_fp8, quantize_int8)
    if mode not in ("int8", "fp8"):
        raise ValueError(f"unknown quantization mode {mode!r}")
    moe = params.get("blocks", {}).get("moe")
    if not isinstance(moe, dict):
        raise ValueError("params tree has no stacked blocks/moe dict "
                         "(per-layer trees: quantize each layer's dict "
                         "with kernels.moe_gmm.quantize_moe_experts)")
    names = [k for k in ("w_gate", "w_up", "w_down") if k in moe]
    if not names:
        raise ValueError("blocks/moe holds no routed expert tensors")
    new = dict(moe)
    for k in names:
        w = moe[k]
        if mode == "fp8":
            new[k] = fake_quant_fp8(w)
            continue
        lyr, e = w.shape[:2]
        q, s = quantize_int8(w.reshape((lyr * e,) + w.shape[2:]),
                             quantile=quantile)
        new[k + QUANT_SUFFIX] = q.reshape(w.shape)
        new[k + SCALE_SUFFIX] = s.reshape(lyr, e)
        del new[k]
    out = dict(params)
    out["blocks"] = dict(params["blocks"])
    out["blocks"]["moe"] = new
    return out


_EP_CACHE = {}


def _ep_apply(cfg, mesh):
    from repro.distributed.expert_parallel import make_expert_parallel_moe
    key = (cfg.name, tuple(sorted(dict(mesh.shape).items())))
    if key not in _EP_CACHE:
        _EP_CACHE[key] = make_expert_parallel_moe(cfg, mesh)
    return _EP_CACHE[key]


def apply_moe(cfg, p, x2d, *, capacity_policy: str = "train",
              packed: bool = False, kernel_backend: str | None = None):
    """x2d: [T,d] -> (y [T,d], aux dict with routing telemetry).

    packed=True takes the union-packed verification path: the activated
    experts are compacted into the leading `packed_expert_cap(cfg, T)`
    slots, so weight gathers, the dispatch buffer and the FFN matmuls all
    scale with the (bucketed) union U rather than E.  With
    kernel_backend=None the packed FFN runs the same inline einsums as the
    dense path — identical contraction structure and dtype promotion, so
    the outputs are bit-identical and rejection sampling sees no numerics
    drift.  kernel_backend="pallas"/"interpret"/"ref" routes the packed
    FFN through `kernels.moe_gmm.moe_gmm_fused` instead (allclose, not
    bitwise).  The packed path is the single-host serving hot path; the
    GSPMD dispatch-shard constraints and the ep-a2a path stay dense.

    Quantized expert storage (docs/quantization.md): when `p` holds
    int8-packed experts (`w_up_q8` + `w_up_s` per-expert scales, from
    `kernels.moe_gmm.quantize_moe_experts` — router/shared/dense weights
    stay bf16), the packed union-gather gathers the QUANTIZED tensors and
    their scales, so only 1 byte/param of expert weights streams; with a
    kernel_backend the dequant fuses into `moe_gmm_fused_quant`'s tiles,
    inline the gathered [U_pad]-sized slice dequantizes in-register.  The
    dense/ep paths dequantize up front (correct, not byte-lean — serving
    uses the packed path)."""
    from repro.distributed.sharding import _CONTEXT_MESH, constrain, opt
    t, d = x2d.shape
    quant = "w_up_q8" in p
    if quant and not packed:
        # non-packed consumers (training-style dispatch, ep-a2a) see a
        # dequantized view; only the packed serving path earns the bytes
        from repro.kernels.moe_gmm import dequantize_int8
        p = dict(p)
        for name in ("w_gate", "w_up", "w_down"):
            if name + "_q8" in p:
                p[name] = dequantize_int8(p.pop(name + "_q8"),
                                          p.pop(name + "_s"))
    if opt("ep-a2a") and capacity_policy != "exact":
        # §Perf/beyond-paper: explicit all-to-all expert parallelism
        mesh = _CONTEXT_MESH[0]
        if mesh is not None:
            from repro.distributed.sharding import axis_size, data_axes
            n_data = axis_size(mesh, data_axes(mesh))
            if cfg.num_experts % n_data == 0 and t % n_data == 0:
                y, aux = _ep_apply(cfg, mesh)(
                    {k: p[k] for k in p}, x2d)
                # the gathered routing decision [T,k] feeds the same
                # union/per-row/per-shard accounting as the dense path —
                # summing the per-source-shard counts would double-count
                # experts shared across token shards, so the union is
                # recomputed from the global ids and the raw per-source
                # counts stay visible under their own key
                aux = dict(aux,
                           unique_experts=unique_expert_count(
                               cfg, aux["expert_idx"]),
                           unique_experts_src=aux["unique_experts"],
                           dropped=jnp.sum(aux["dropped"]))
                return y, aux
    k, e = cfg.experts_per_token, cfg.num_experts
    c = _capacity(cfg, t, capacity_policy)

    weights, idx, probs = route(cfg, p, x2d)

    # --- slot assignment: position of each (token, choice) inside its expert
    flat_e = idx.reshape(-1)                                  # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # [T*k,E]
    pos = jnp.cumsum(onehot, axis=0) * onehot                 # rank within expert
    flat_p = jnp.sum(pos, axis=-1) - 1                        # [T*k], 0-based
    keep = flat_p < c
    flat_p = jnp.where(keep, flat_p, c)  # overflow rows scatter to a spill slot

    x_rep = jnp.repeat(x2d, k, axis=0)                        # [T*k,d]
    if packed:
        # --- union compaction: map the activated experts onto the leading
        # U_pad packed slots (active experts first, ascending id — a
        # deterministic, trace-stable permutation).  Every routed expert
        # is active, so every (token, choice) lands in a slot < U_pad.
        u_cap = packed_expert_cap(cfg, t)
        hits = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)   # [E]
        active = (hits > 0).astype(jnp.int32)
        perm = jnp.argsort(1 - active, stable=True)           # [E]
        expert_ids = perm[:u_cap]                             # [U_pad]
        slot_of = (jnp.full((e,), u_cap, jnp.int32)
                   .at[expert_ids].set(jnp.arange(u_cap, dtype=jnp.int32)))
        flat_u = slot_of[flat_e]                              # [T*k] < U_pad

        # --- packed dispatch: [U_pad, C(+spill), d]
        disp = jnp.zeros((u_cap, c + 1, d), x2d.dtype)
        disp = disp.at[flat_u, flat_p].set(x_rep)[:, :c]

        # --- gather only the union's weights (the U-not-E byte stream);
        # quantized storage gathers int8 tensors + [U_pad] scales, so the
        # gather itself moves 1 byte/param
        if quant:
            wu_q = jnp.take(p["w_up_q8"], expert_ids, axis=0)
            wd_q = jnp.take(p["w_down_q8"], expert_ids, axis=0)
            su_g = jnp.take(p["w_up_s"], expert_ids, axis=0)
            sd_g = jnp.take(p["w_down_s"], expert_ids, axis=0)
            swiglu = "w_gate_q8" in p and cfg.activation == "swiglu"
            wg_q = (jnp.take(p["w_gate_q8"], expert_ids, axis=0)
                    if swiglu else None)
            sg_g = (jnp.take(p["w_gate_s"], expert_ids, axis=0)
                    if swiglu else None)
            if kernel_backend is not None:
                from repro.kernels.moe_gmm import moe_gmm_fused_quant
                counts = jnp.minimum(hits[expert_ids], c)
                out = moe_gmm_fused_quant(
                    disp, wg_q, wu_q, wd_q, sg_g, su_g, sd_g, counts,
                    activation="swiglu" if swiglu else "gelu",
                    backend=kernel_backend)
            else:
                # in-register dequant of the gathered [U_pad] slice, then
                # the same contractions as the bf16 packed path (matches
                # the kernel's oracle `moe_gmm_fused_quant_ref`)
                from repro.kernels.moe_gmm import dequantize_int8
                wu_g = dequantize_int8(wu_q, su_g)
                wd_g = dequantize_int8(wd_q, sd_g)
                if swiglu:
                    wg_g = dequantize_int8(wg_q, sg_g)
                    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, wg_g))
                    h = h * jnp.einsum("ecd,edf->ecf", disp, wu_g)
                else:
                    h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", disp, wu_g))
                out = jnp.einsum("ecf,efd->ecd", h, wd_g)     # [U_pad,C,d]
            pad = jnp.zeros((u_cap, 1, d), out.dtype)
            out = jnp.concatenate([out, pad], axis=1)
            y_rep = out[flat_u, jnp.where(keep, flat_p, c)]   # [T*k,d]
            w_flat = (weights.reshape(-1) * keep).astype(out.dtype)
            y = jnp.sum((y_rep * w_flat[:, None]).reshape(t, k, d), axis=1)
            if cfg.num_shared_experts:
                y = y + apply_mlp(cfg, p["shared"], x2d)
            aux = {
                "lb_loss": load_balance_loss(cfg, probs, idx),
                "expert_idx": idx,
                "unique_experts": unique_expert_count(cfg, idx),
                "dropped": jnp.sum(~keep),
            }
            return y, aux
        wu_g = jnp.take(p["w_up"], expert_ids, axis=0)        # [U_pad,d,F]
        wd_g = jnp.take(p["w_down"], expert_ids, axis=0)      # [U_pad,F,d]
        swiglu = "w_gate" in p and cfg.activation == "swiglu"
        wg_g = (jnp.take(p["w_gate"], expert_ids, axis=0) if swiglu
                else None)
        if kernel_backend is not None:
            from repro.kernels.moe_gmm import moe_gmm_fused
            counts = jnp.minimum(hits[expert_ids], c)
            out = moe_gmm_fused(disp, wg_g, wu_g, wd_g, counts,
                                activation="swiglu" if swiglu else "gelu",
                                backend=kernel_backend)
        else:
            # same contractions/dtypes as the dense branch -> bit-identical
            if swiglu:
                h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, wg_g))
                h = h * jnp.einsum("ecd,edf->ecf", disp, wu_g)
            else:
                h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", disp, wu_g))
            out = jnp.einsum("ecf,efd->ecd", h, wd_g)         # [U_pad,C,d]

        pad = jnp.zeros((u_cap, 1, d), out.dtype)
        out = jnp.concatenate([out, pad], axis=1)
        y_rep = out[flat_u, jnp.where(keep, flat_p, c)]       # [T*k,d]
    else:
        # --- dispatch: scatter tokens into [E, C(+spill), d]
        disp = jnp.zeros((e, c + 1, d), x2d.dtype)
        disp = disp.at[flat_e, flat_p].set(x_rep)
        disp = disp[:, :c]                                    # drop spill slot
        if opt("dispatch-shard"):
            # §Perf: pin the dispatch buffer (experts over 'data') so GSPMD
            # does not involuntarily replicate it through the scatter
            disp = constrain(disp, "data", None, None)

        # --- expert FFN (stacked einsum; FLOPs = E*C*d*F per matmul)
        if "w_gate" in p and cfg.activation == "swiglu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, p["w_gate"]))
            h = h * jnp.einsum("ecd,edf->ecf", disp, p["w_up"])
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", disp, p["w_up"]))
        if opt("dispatch-shard"):
            h = constrain(h, "data", None, "model")
        out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # [E,C,d]
        if opt("dispatch-shard"):
            out = constrain(out, "data", None, None)

        # --- combine: gather each slot's output back to its token
        pad = jnp.zeros((e, 1, d), out.dtype)
        out = jnp.concatenate([out, pad], axis=1)             # spill reads 0
        y_rep = out[flat_e, jnp.where(keep, flat_p, c)]       # [T*k,d]
    w_flat = (weights.reshape(-1) * keep).astype(out.dtype)
    y = jnp.sum((y_rep * w_flat[:, None]).reshape(t, k, d), axis=1)

    if cfg.num_shared_experts:
        y = y + apply_mlp(cfg, p["shared"], x2d)

    aux = {
        "lb_loss": load_balance_loss(cfg, probs, idx),
        "expert_idx": idx,
        "unique_experts": unique_expert_count(cfg, idx),
        "dropped": jnp.sum(~keep),
    }
    return y, aux
