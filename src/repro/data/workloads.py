"""Synthetic task workloads with controllable n-gram structure.

The paper evaluates code (HumanEval), math (GSM8K), and extraction
(MT-Bench) workloads, whose *draftability* differs: extraction outputs copy
long spans from the prompt (n-gram heaven), code repeats idioms, math
produces near-novel token streams (n-gram hostile). These generators build
token-level analogues over a small vocabulary with the same qualitative
structure, so a ~100M target model trained on them exhibits the paper's
task-dependent acceptance rates *for real* (DESIGN.md §4)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

TASKS = ("code", "math", "extract")
MIXES: Dict[str, Tuple[str, ...]] = {
    "code": ("code",),
    "math": ("math",),
    "extract": ("extract",),
    "code+math": ("code", "math"),
    "math+extract": ("math", "extract"),
    "code+extract": ("code", "extract"),
    "all-3": ("code", "math", "extract"),
}

# reserved token ids
PAD, BOS, SEP = 0, 1, 2
_BASE = 3


def _code_like(rng: np.random.Generator, vocab: int, length: int) -> List[int]:
    """Loop-ish structure: a handful of 'statement' templates repeated with
    small mutations — mid n-gram copy rate."""
    toks: List[int] = []
    n_templates = rng.integers(2, 5)
    templates = [list(rng.integers(_BASE, vocab, rng.integers(4, 9)))
                 for _ in range(n_templates)]
    while len(toks) < length:
        t = list(templates[rng.integers(0, n_templates)])
        if rng.random() < 0.4:  # mutate one token (variable rename)
            t[rng.integers(0, len(t))] = int(rng.integers(_BASE, vocab))
        toks.extend(t + [SEP])
    return toks[:length]


def _math_like(rng: np.random.Generator, vocab: int, length: int) -> List[int]:
    """Chain-of-arithmetic: mostly fresh 'digits' with rare operator
    repeats — low n-gram copy rate."""
    ops = list(rng.integers(_BASE, _BASE + 6, 4))
    toks: List[int] = []
    while len(toks) < length:
        expr = [int(rng.integers(_BASE + 6, vocab)) for _ in range(rng.integers(2, 5))]
        toks.extend([expr[0], int(rng.choice(ops))] + expr[1:] + [SEP])
    return toks[:length]


def _extract_like(rng: np.random.Generator, vocab: int, length: int,
                  source: List[int]) -> List[int]:
    """Extraction: copy contiguous spans from the prompt `source`, joined by
    separators — high n-gram copy rate (phases of near-1.0 acceptance)."""
    toks: List[int] = []
    while len(toks) < length:
        span_len = int(rng.integers(4, 12))
        start = int(rng.integers(0, max(1, len(source) - span_len)))
        toks.extend(source[start:start + span_len] + [SEP])
    return toks[:length]


@dataclass
class WorkloadSample:
    task: str
    prompt: List[int]
    continuation: List[int]  # ground-truth continuation (training target)


def make_sample(task: str, rng: np.random.Generator, *, vocab: int = 256,
                prompt_len: int = 64, cont_len: int = 128) -> WorkloadSample:
    if task == "code":
        body = _code_like(rng, vocab, prompt_len + cont_len)
    elif task == "math":
        body = _math_like(rng, vocab, prompt_len + cont_len)
    elif task == "extract":
        src = list(rng.integers(_BASE, vocab, prompt_len))
        cont = _extract_like(rng, vocab, cont_len, src)
        return WorkloadSample(task, [BOS] + src, cont)
    else:
        raise ValueError(task)
    return WorkloadSample(task, [BOS] + body[:prompt_len],
                          body[prompt_len:prompt_len + cont_len])


def request_stream(mix: str, n: int, seed: int = 0, **kw):
    """Round-robin stream over the tasks of a mixed workload (paper §3:
    'equal sharing of requests')."""
    rng = np.random.default_rng(seed)
    tasks = MIXES[mix]
    return [make_sample(tasks[i % len(tasks)], rng, **kw) for i in range(n)]


def sample_length(rng: np.random.Generator, dist: str = "lognormal", *,
                  median: float = 32.0, sigma: float = 0.6,
                  alpha: float = 1.5, lo: int = 4, hi: int = 256) -> int:
    """One long-tailed length draw for production-shaped traffic
    (docs/serving_load.md): real prompt/output length distributions are
    right-skewed — most requests short, a heavy tail of huge ones — and
    the tail is what fills cache rows and queues. "lognormal" draws
    exp(N(ln median, sigma²)) (median `median`, tail weight `sigma`);
    "pareto" draws lo·(1+Pareto(alpha)) (the heavier power-law tail,
    infinite variance at alpha <= 2). Clamped to [lo, hi] — hi mirrors
    the serving cap (`max_len` / `max_new`), where real traffic truncates
    too."""
    if dist == "lognormal":
        x = median * float(np.exp(sigma * rng.standard_normal()))
    elif dist == "pareto":
        x = lo * (1.0 + float(rng.pareto(alpha)))
    else:
        raise ValueError(f"unknown length distribution {dist!r} "
                         "(expected 'lognormal' or 'pareto')")
    return int(min(max(round(x), lo), hi))
