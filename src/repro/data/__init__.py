from .pipeline import batch_iterator, pack_batch
from .workloads import MIXES, TASKS, WorkloadSample, make_sample, request_stream
