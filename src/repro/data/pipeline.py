"""Token-stream data pipeline: packs workload samples into fixed-shape
training batches (next-token prediction with loss masked over prompts
optional). Deterministic, seedable, infinite."""

from __future__ import annotations

from typing import Dict, Iterator, Sequence

import numpy as np

from .workloads import MIXES, PAD, make_sample


def pack_batch(samples, seq_len: int, pad: int = PAD) -> Dict[str, np.ndarray]:
    """Concatenate prompt+continuation per sample, truncate/pad to seq_len.
    labels are inputs shifted left; mask excludes padding."""
    b = len(samples)
    tokens = np.full((b, seq_len), pad, np.int32)
    labels = np.full((b, seq_len), pad, np.int32)
    mask = np.zeros((b, seq_len), np.float32)
    for i, s in enumerate(samples):
        seq = (s.prompt + s.continuation)[:seq_len + 1]
        n = min(len(seq) - 1, seq_len)
        tokens[i, :n] = seq[:n]
        labels[i, :n] = seq[1:n + 1]
        mask[i, :n] = 1.0
    return {"tokens": tokens, "labels": labels, "mask": mask}


def batch_iterator(mix: str, batch_size: int, seq_len: int, *,
                   vocab: int = 256, seed: int = 0,
                   prompt_len: int = 64) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    tasks = MIXES[mix]
    i = 0
    while True:
        samples = [make_sample(tasks[(i + j) % len(tasks)], rng,
                               vocab=vocab, prompt_len=prompt_len,
                               cont_len=seq_len - prompt_len)
                   for j in range(batch_size)]
        i += batch_size
        yield pack_batch(samples, seq_len)
