"""Iteration-level speculation simulator for the paper-scale figures.

Runs the *real* Cascade controller (the identical code the serving engine
uses) against full-size MoE configs, with:
  * acceptance drawn from the per-task AR(1) process (tasks.py),
  * unique-expert activation from the routing simulator (affinity-damped
    bucket-and-balls, §2.4),
  * iteration time from the deterministic TPU-v5e data-movement cost model
    (core/cost_model.py).

This is the substrate for the Fig. 4/5/8/13/15/16/18 reproductions. The
end-to-end *real-model* path (examples/, tests) validates the same
controller with genuine routing + genuine n-gram acceptance at small scale;
the simulator extends it to the paper's model sizes (DESIGN.md §4)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import cost_model as cm
from repro.core.controller import CascadeController, StaticKController

from .tasks import (EAGLE_BOOST, MODEL_AFFINITY, TASK_PROCESSES,
                    AcceptanceProcess, RoutingSimulator,
                    effective_affinity)


@dataclass
class SimIteration:
    k: int
    tokens: int
    t_iter: float
    unique_experts: float
    utility: float
    phase: str


@dataclass
class SimRequest:
    task: str
    iterations: List[SimIteration] = field(default_factory=list)

    @property
    def output_tokens(self):
        return sum(i.tokens for i in self.iterations)

    @property
    def decode_time(self):
        return sum(i.t_iter for i in self.iterations)


class SpeculationSimulator:
    def __init__(self, cfg, *, hw: cm.Hardware = cm.TPU_V5E,
                 drafter: str = "ngram", context_len: int = 1024,
                 seed: int = 0):
        self.cfg = cfg
        self.hw = hw
        self.drafter = drafter
        self.context_len = context_len
        self.rng = np.random.default_rng(seed)
        self.affinity = MODEL_AFFINITY.get(cfg.name, 0.3)
        # EAGLE-style drafters fetch their own weights per drafted token
        self.drafter_params = (int(0.01 * cfg.active_param_count())
                               if drafter == "eagle" else 0)

    # ------------------------------------------------------------------ #

    def _baseline_iter_time(self, ctx: int) -> float:
        r = cm.iteration_time(self.cfg, self.hw, 1, ctx,
                              unique_experts=float(
                                  self.cfg.experts_per_token) or None,
                              window=self.cfg.window)
        return r["t_iter"]

    def run_request(self, task: str, n_iters: int = 256,
                    controller=None) -> SimRequest:
        cfg = self.cfg
        controller = controller or CascadeController()
        boost = EAGLE_BOOST.get(task, 0.15) if self.drafter == "eagle" else 0.0
        acc = AcceptanceProcess(TASK_PROCESSES[task], self.rng, boost=boost)
        aff = effective_affinity(cfg.name, task)
        routing = (RoutingSimulator(cfg.num_experts, cfg.experts_per_token,
                                    aff, self.rng)
                   if cfg.is_moe else None)
        req = SimRequest(task=task)
        ctx = self.context_len

        for _ in range(n_iters):
            k = controller.next_k()
            a = acc.step()
            # n-gram drafters sometimes find no match at all; GSM8K-style
            # text usually *matches* (numbers, templates) but continues
            # wrongly — hence the high find rate with low acceptance that
            # produces the paper's -54% math worst case.
            if self.drafter == "ngram" and self.rng.random() > min(
                    1.0, 0.5 + a * 1.2):
                k_eff = 0
            else:
                k_eff = k
            # sequential accept/reject over the k_eff drafts
            n_acc = 0
            for _ in range(k_eff):
                if self.rng.random() < a:
                    n_acc += 1
                else:
                    break
            tokens = n_acc + 1
            n_inflight = k_eff + 1

            uniq = (routing.unique_for(n_inflight) if routing else None)
            r = cm.iteration_time(cfg, self.hw, n_inflight, ctx,
                                  unique_experts=uniq, window=cfg.window)
            t_draft = cm.draft_time(self.hw, k_eff, self.drafter_params)
            t_sample = cm.sample_time(k_eff) if k_eff else 0.0
            t_iter = r["t_iter"] + t_draft + t_sample

            controller.observe(tokens, t_iter, t_draft=t_draft,
                               t_verify=r["t_iter"], t_sample=t_sample,
                               k=k_eff if k > 0 else 0)
            req.iterations.append(SimIteration(
                k=k_eff, tokens=tokens, t_iter=t_iter,
                unique_experts=float(uniq or 0),
                utility=controller.utility(),
                phase=getattr(controller, "phase", "")))
            ctx += tokens
        return req

    # ------------------------------------------------------------------ #

    def run_workload(self, tasks: List[str], *, n_requests: int = 8,
                     iters_per_request: int = 256,
                     controller_factory: Optional[Callable] = None
                     ) -> List[SimRequest]:
        """Round-robin mixed request stream (paper §3)."""
        controller_factory = controller_factory or (lambda: CascadeController())
        out = []
        for i in range(n_requests):
            task = tasks[i % len(tasks)]
            out.append(self.run_request(task, iters_per_request,
                                        controller_factory()))
        return out


def tpot_speedup(requests: List[SimRequest], baseline: List[SimRequest]):
    """Aggregate TPOT improvement vs a no-speculation run (y=1 line)."""
    t = sum(r.decode_time for r in requests)
    n = sum(r.output_tokens for r in requests)
    tb = sum(r.decode_time for r in baseline)
    nb = sum(r.output_tokens for r in baseline)
    return (tb / nb) / (t / n)


def run_point(cfg, task_mix: List[str], k: Optional[int], *,
              drafter="ngram", n_requests=8, iters=256, seed=0,
              cascade_cfg=None) -> Dict:
    """One (model, workload, policy) datapoint. k=None -> Cascade."""
    from repro.core.manager import CascadeConfig
    sim = SpeculationSimulator(cfg, drafter=drafter, seed=seed)
    if k is None:
        cc = cascade_cfg or CascadeConfig()
        factory = lambda: CascadeController(cc)   # noqa: E731
    else:
        factory = lambda: StaticKController(k)    # noqa: E731
    reqs = sim.run_workload(task_mix, n_requests=n_requests,
                            iters_per_request=iters,
                            controller_factory=factory)
    sim_b = SpeculationSimulator(cfg, drafter=drafter, seed=seed)
    base = sim_b.run_workload(task_mix, n_requests=n_requests,
                              iters_per_request=iters,
                              controller_factory=lambda: StaticKController(0))
    toks = sum(r.output_tokens for r in reqs)
    t = sum(r.decode_time for r in reqs)
    etr = toks / sum(len(r.iterations) for r in reqs)
    return {
        "speedup": tpot_speedup(reqs, base),
        "tpot": t / toks,
        "etr": etr,
        "requests": reqs,
        "baseline": base,
    }
