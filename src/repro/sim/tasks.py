"""Per-task draft-acceptance processes and per-model routing affinity.

The paper's workloads differ in *draftability* (how often n-gram drafts are
accepted) and models differ in *expert affinity* (how much consecutive
tokens reuse experts — §2.4/§7: OLMoE high, Mixtral low). The simulator
models acceptance as a per-request AR(1) latent acceptance rate (Fig. 6/7:
phases with temporal locality) and ETR then *emerges* from sequential
accept/reject draws — it is never assumed.

Acceptance means are anchored to the paper's reported ETRs (Fig. 4: at K=7,
n-gram ETR 1.6x-3.2x across tasks; code highest, math lowest; extraction has
high-copy phases). Affinities anchored to §7's Mixtral-low / OLMoE-high
observations."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TaskProcess:
    name: str
    accept_mean: float      # long-run mean acceptance prob per draft token
    accept_std: float       # dispersion of the AR(1) latent
    ar_rho: float           # temporal locality (Fig. 6: strong short-term)
    phase_flip_p: float     # prob/iter of a phase shift (Fig. 7 extraction)
    phase_gain: float       # acceptance boost in the high phase


# n-gram draftability per task (anchors: paper Fig. 4 ETR ranges: at K=7
# code ~3.2x, math ~1.6x, extraction ~2x with high-copy phases)
TASK_PROCESSES = {
    "code": TaskProcess("code", accept_mean=0.72, accept_std=0.08,
                        ar_rho=0.92, phase_flip_p=0.01, phase_gain=0.10),
    "math": TaskProcess("math", accept_mean=0.40, accept_std=0.08,
                        ar_rho=0.92, phase_flip_p=0.005, phase_gain=0.08),
    "extract": TaskProcess("extract", accept_mean=0.48, accept_std=0.12,
                           ar_rho=0.95, phase_flip_p=0.02, phase_gain=0.35),
}

# expert-affinity is also task-dependent: repetitive token streams (code,
# extraction spans) reuse experts far more than fresh math tokens — this is
# what reconciles Fig. 4's 2.3x (code) vs 3.0x (math) verification overheads
# at K=7 with the same model.
TASK_AFFINITY = {"code": 0.30, "math": 0.00, "extract": 0.25}

# EAGLE drafts are more accurate (paper §7.3: math ETR 1.7 vs 1.3 at K=1)
EAGLE_BOOST = {"code": 0.12, "math": 0.30, "extract": 0.18}

# base expert-token affinity per model (paper §7: OLMoE high, Mixtral low);
# effective affinity = clip(base + TASK_AFFINITY[task], 0, 0.9)
MODEL_AFFINITY = {
    "mixtral-8x7b": 0.12,
    "phi-3.5-moe": 0.25,
    "olmoe-1b-7b": 0.55,
    "deepseek-moe-16b": 0.35,
    "qwen15-moe-a2.7b": 0.35,
    # assigned-pool MoEs (no paper anchor; moderate affinity)
    "kimi-k2-1t-a32b": 0.30,
    "deepseek-v2-236b": 0.30,
}


def effective_affinity(model_name: str, task: str) -> float:
    base = MODEL_AFFINITY.get(model_name, 0.3)
    return float(min(0.9, max(0.0, base + TASK_AFFINITY.get(task, 0.1))))


class AcceptanceProcess:
    """Per-request latent acceptance-rate process."""

    def __init__(self, task: TaskProcess, rng: np.random.Generator,
                 boost: float = 0.0):
        self.task = task
        self.rng = rng
        self.boost = boost
        self.latent = float(np.clip(
            rng.normal(task.accept_mean, task.accept_std), 0.02, 0.95))
        self.high_phase = bool(rng.random() < 0.3)

    def step(self) -> float:
        t = self.task
        if self.rng.random() < t.phase_flip_p:
            self.high_phase = not self.high_phase
        target = t.accept_mean + (t.phase_gain if self.high_phase else 0.0)
        noise = self.rng.normal(0.0, t.accept_std * np.sqrt(1 - t.ar_rho**2))
        self.latent = t.ar_rho * self.latent + (1 - t.ar_rho) * target + noise
        return float(np.clip(self.latent + self.boost, 0.01, 0.98))


class RoutingSimulator:
    """Expert-activation simulator: per token, with prob `affinity` reuse
    the previous token's expert set, else draw k distinct experts uniformly.
    Returns the number of unique experts across the in-flight tokens."""

    def __init__(self, num_experts: int, top_k: int, affinity: float,
                 rng: np.random.Generator):
        self.e = num_experts
        self.k = top_k
        self.affinity = affinity
        self.rng = rng
        self.prev = self._fresh()

    def _fresh(self):
        return set(self.rng.choice(self.e, self.k, replace=False).tolist())

    def unique_for(self, n_tokens: int) -> int:
        uniq = set()
        for _ in range(n_tokens):
            if self.rng.random() < self.affinity and self.prev:
                sel = self.prev
            else:
                sel = self._fresh()
            self.prev = sel
            uniq |= sel
        return len(uniq)
