"""Serving substrate: engines (single-request + continuous batching),
drafters, rejection sampler, schedulers."""

from repro.core.slo import RequestSLO

from .drafter import Drafter, DraftModelDrafter, NGramDrafter
from .engine import BatchedEngine, GenerationResult, ServingEngine
from .sampler import greedy_verify, rejection_sample
from .scheduler import ContinuousBatchingScheduler, Request, Scheduler
from .telemetry import (EngineTelemetry, IterationTelemetry,
                        RequestTelemetry, StepTelemetry)
