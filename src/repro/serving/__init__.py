"""Serving substrate: engines (single-request + continuous batching),
drafters, rejection sampler, schedulers."""

from repro.core.slo import RequestSLO

from .drafter import Drafter, DraftModelDrafter, NGramDrafter
from .engine import BatchedEngine, GenerationResult, ServingEngine
from .load import (LoadSpec, build_trace, diurnal_arrivals,
                   poisson_arrivals, run_load, summarize)
from .sampler import greedy_verify, rejection_sample
from .scheduler import ContinuousBatchingScheduler, Request, Scheduler
from .telemetry import (EngineTelemetry, IterationTelemetry,
                        RequestTelemetry, StepTelemetry, percentile)
