"""Serving substrate: engine, drafters, rejection sampler, scheduler."""

from .drafter import Drafter, DraftModelDrafter, NGramDrafter
from .engine import GenerationResult, ServingEngine
from .sampler import greedy_verify, rejection_sample
from .scheduler import Request, Scheduler
from .telemetry import IterationTelemetry, RequestTelemetry
