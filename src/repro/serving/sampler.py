"""Token sampling + the speculative rejection sampler (Leviathan et al. '23).

The rejection sampler is the correctness-critical piece of speculative
decoding: accepted-token streams must be distributed exactly as if sampled
from the target model alone. Property tests in tests/test_serving.py verify
the output distribution on small vocabularies."""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def logits_to_probs(logits, temperature: float = 1.0):
    if temperature <= 0.0:  # greedy: delta at argmax
        v = logits.shape[-1]
        return jax.nn.one_hot(jnp.argmax(logits, -1), v, dtype=jnp.float32)
    return jax.nn.softmax(logits.astype(jnp.float32) / temperature, -1)


def sample_token(rng: np.random.Generator, probs: np.ndarray) -> int:
    probs = np.asarray(probs, np.float64)
    probs = np.maximum(probs, 0)
    s = probs.sum()
    if s <= 0:
        return int(np.argmax(probs))
    return int(rng.choice(len(probs), p=probs / s))


@dataclass
class RejectionResult:
    accepted: List[int]       # accepted draft tokens (prefix)
    next_token: int           # bonus token (all accepted) or resampled token
    n_accepted: int           # == len(accepted)


def rejection_sample(rng: np.random.Generator,
                     target_probs: np.ndarray,   # [K+1, V]
                     draft_tokens: List[int],    # K proposed tokens
                     draft_probs: Optional[np.ndarray] = None,  # [K, V]
                     ) -> RejectionResult:
    """Leviathan speculative sampling.

    target_probs[i] is the target distribution for the position of
    draft_tokens[i]; target_probs[K] is the bonus position. draft_probs=None
    means the drafter is deterministic (n-gram): q is a point mass at the
    proposed token, so acceptance probability reduces to p(d_i)."""
    k = len(draft_tokens)
    accepted: List[int] = []
    for i, d in enumerate(draft_tokens):
        p = np.asarray(target_probs[i], np.float64)
        if draft_probs is None:
            q_d = 1.0
        else:
            q_d = float(draft_probs[i][d])
        p_d = float(p[d])
        if q_d <= 0.0:
            ratio = 1.0 if p_d > 0 else 0.0
        else:
            ratio = min(1.0, p_d / q_d)
        if rng.random() < ratio:
            accepted.append(int(d))
            continue
        # rejected: resample from the residual max(p - q, 0)
        if draft_probs is None:
            resid = p.copy()
            resid[d] = 0.0
        else:
            resid = np.maximum(p - np.asarray(draft_probs[i], np.float64), 0.0)
        if resid.sum() <= 0:
            resid = p
        tok = sample_token(rng, resid)
        return RejectionResult(accepted, tok, len(accepted))
    # all K accepted: bonus token from the last target distribution
    tok = sample_token(rng, np.asarray(target_probs[k], np.float64))
    return RejectionResult(accepted, tok, len(accepted))


def greedy_verify(target_logits: np.ndarray, draft_tokens: List[int]
                  ) -> RejectionResult:
    """Deterministic verification: accept drafts while they match the target
    argmax; emit the first mismatching argmax (or the bonus argmax)."""
    argmaxes = np.argmax(np.asarray(target_logits, np.float32), axis=-1)
    accepted: List[int] = []
    for i, d in enumerate(draft_tokens):
        if int(argmaxes[i]) == int(d):
            accepted.append(int(d))
        else:
            return RejectionResult(accepted, int(argmaxes[i]), len(accepted))
    return RejectionResult(accepted, int(argmaxes[len(draft_tokens)]),
                           len(accepted))
