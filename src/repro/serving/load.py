"""Open-loop production-shaped load for the batched engine
(docs/serving_load.md).

The closed-loop sweeps submit every request at clock 0 and let the
scheduler pull work as fast as it drains — which can never show an
overload, a queue explosion, or a starved tier, because offered load
always equals service rate by construction. This module replays traffic
the way production sees it: arrivals land on the engine's *model clock*
whether the batch is ready or not (Poisson or diurnally-modulated
processes), prompt and output lengths are long-tailed (lognormal/Pareto,
`data.workloads.sample_length`), task types come from the paper's mixed
workloads, and a configurable fraction carries latency-tier SLOs. The
scheduler side (`ContinuousBatchingScheduler.run_trace`) holds each
request out of the queue until the clock reaches its arrival stamp, so
queue depth and TTFT measure the offered load, not the drain rate.

`summarize` turns one replay into the report every scale claim gets
measured on: p50/p95/p99 TTFT and experienced TPOT (nearest-rank,
`telemetry.percentile`), goodput under SLO, queue-depth/occupancy time
series, and overload behavior — shed and deferred counts as first-class
telemetry, not silent zeros."""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.slo import LATENCY, RequestSLO
from repro.data.workloads import MIXES, make_sample, sample_length

from .scheduler import ContinuousBatchingScheduler, Request
from .telemetry import percentile


# -- arrival processes --------------------------------------------------- #

def poisson_arrivals(rng: np.random.Generator, rate: float,
                     n: int) -> List[float]:
    """n arrival times of a homogeneous Poisson process at `rate` per
    model-clock second: i.i.d. exponential inter-arrival gaps — the
    memoryless baseline every queueing result assumes, and the default
    shape of aggregate production traffic between diurnal swings."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate!r}")
    gaps = rng.exponential(1.0 / rate, int(n))
    return [float(t) for t in np.cumsum(gaps)]


def diurnal_arrivals(rng: np.random.Generator, rate: float, n: int, *,
                     amplitude: float = 0.8,
                     period: float = 60.0) -> List[float]:
    """n arrival times of an inhomogeneous Poisson process whose rate
    swings sinusoidally around `rate` — lambda(t) = rate * (1 + amplitude
    * sin(2*pi*t / period)) — by Lewis-Shedler thinning of a homogeneous
    candidate process at the peak rate. The compressed analogue of a
    day/night traffic cycle: the same mean load as `poisson_arrivals`,
    but with sustained bursts that exercise overload behavior a flat
    process only hits by luck."""
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude!r}")
    if rate <= 0 or period <= 0:
        raise ValueError("rate and period must be positive")
    peak = rate * (1.0 + amplitude)
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        t += float(rng.exponential(1.0 / peak))
        lam = rate * (1.0 + amplitude * math.sin(2.0 * math.pi * t / period))
        if float(rng.random()) * peak <= lam:
            out.append(t)
    return out


# -- trace construction -------------------------------------------------- #

@dataclass(frozen=True)
class LoadSpec:
    """One production-shaped trace, fully determined by its seed.

    `rate` is offered load in requests per model-clock second — calibrate
    it against a measured service rate (benchmarks/serving_load.py does)
    to place a run below or above saturation. `latency_frac` of requests
    ride the latency tier with the given TTFT/TPOT bounds; the rest are
    unbounded throughput tier. Lengths are long-tailed draws clamped to
    [lo, hi] (`data.workloads.sample_length`)."""
    n_requests: int = 200
    arrival: str = "poisson"       # "poisson" | "diurnal"
    rate: float = 10.0             # offered requests / model-clock second
    amplitude: float = 0.8         # diurnal swing (ignored for poisson)
    period: float = 60.0           # diurnal period, model-clock seconds
    mix: str = "all-3"             # task mix (data.workloads.MIXES)
    # prompt length distribution
    prompt_dist: str = "lognormal"
    prompt_median: float = 24.0
    prompt_sigma: float = 0.7
    prompt_alpha: float = 1.5
    prompt_lo: int = 4
    prompt_hi: int = 96
    # output (max_new) length distribution
    out_dist: str = "lognormal"
    out_median: float = 10.0
    out_sigma: float = 0.7
    out_alpha: float = 1.5
    out_lo: int = 2
    out_hi: int = 32
    # SLO mix
    latency_frac: float = 0.5      # fraction carrying latency-tier SLOs
    latency_ttft: Optional[float] = None
    latency_tpot: Optional[float] = None
    vocab: int = 256
    seed: int = 0

    def scaled(self, rate: float) -> "LoadSpec":
        """The same trace shape at a different offered load."""
        return replace(self, rate=rate)


def build_trace(spec: LoadSpec) -> List[Tuple[float, Request]]:
    """Materialize a spec into `(arrival_time, Request)` pairs for
    `ContinuousBatchingScheduler.run_trace`. Deterministic in the spec:
    one rng drives arrivals, lengths, task content, and tier assignment,
    so two runs of the same spec replay byte-identical traffic."""
    rng = np.random.default_rng(spec.seed)
    if spec.arrival == "poisson":
        ats = poisson_arrivals(rng, spec.rate, spec.n_requests)
    elif spec.arrival == "diurnal":
        ats = diurnal_arrivals(rng, spec.rate, spec.n_requests,
                               amplitude=spec.amplitude,
                               period=spec.period)
    else:
        raise ValueError(f"unknown arrival process {spec.arrival!r} "
                         "(expected 'poisson' or 'diurnal')")
    tasks = MIXES[spec.mix]
    trace: List[Tuple[float, Request]] = []
    for i, at in enumerate(ats):
        task = tasks[i % len(tasks)]
        p_len = sample_length(rng, spec.prompt_dist,
                              median=spec.prompt_median,
                              sigma=spec.prompt_sigma,
                              alpha=spec.prompt_alpha,
                              lo=spec.prompt_lo, hi=spec.prompt_hi)
        o_len = sample_length(rng, spec.out_dist, median=spec.out_median,
                              sigma=spec.out_sigma, alpha=spec.out_alpha,
                              lo=spec.out_lo, hi=spec.out_hi)
        sample = make_sample(task, rng, vocab=spec.vocab,
                             prompt_len=p_len, cont_len=o_len)
        slo = None
        if float(rng.random()) < spec.latency_frac:
            slo = RequestSLO(tpot=spec.latency_tpot,
                             ttft=spec.latency_ttft, tier=LATENCY)
        trace.append((at, Request(request_id=f"load-{i}",
                                  prompt=sample.prompt, max_new=o_len,
                                  task=task, slo=slo)))
    return trace


# -- reporting ----------------------------------------------------------- #

def _downsample(timeline: Sequence[Tuple[float, int, int]],
                cap: int = 128) -> List[List[float]]:
    if len(timeline) <= cap:
        return [list(x) for x in timeline]
    stride = math.ceil(len(timeline) / cap)
    return [list(x) for x in timeline[::stride]]


def summarize(sched: ContinuousBatchingScheduler,
              trace: Optional[Sequence[Tuple[float, Request]]] = None
              ) -> dict:
    """The replay report (docs/serving_load.md): latency tails over
    *served* requests, goodput under SLO over the replay makespan, queue
    dynamics from the step timeline, and the overload ledger — shed and
    deferred counts plus drained-vs-censored throughput. Shed requests
    contribute violations (and their queue delay), never latency samples;
    a report whose `n_shed` is high and whose `p99_ttft` is low is
    describing an engine that kept its promises by refusing some — both
    numbers are the point."""
    served = sched.results
    shed = sched.shed_results
    ttfts = [r.telemetry.ttft for r in served]
    tpots = [r.telemetry.experienced_tpot for r in served
             if r.telemetry.output_tokens]
    qdel = [r.telemetry.t_queue for r in served + shed]
    tl = sched.timeline
    if trace:
        start = min(at for at, _ in trace)
    else:
        start = tl[0][0] if tl else 0.0
    end = tl[-1][0] if tl else start
    makespan = max(end - start, 0.0)
    tokens = sum(r.telemetry.output_tokens for r in served)
    good = sum(r.telemetry.output_tokens for r in served
               if not r.telemetry.slo_tpot_violated
               and not r.telemetry.slo_ttft_violated)
    depths = [d for _, d, _ in tl]
    occ = [o for _, _, o in tl]
    return {
        "n_offered": len(served) + len(shed) + len(sched.queue),
        "n_served": len(served),
        "n_shed": len(shed),
        "n_deferred": sched.deferred,
        "makespan": makespan,
        # latency tails (served requests; nearest-rank)
        "mean_ttft": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        "p50_ttft": percentile(ttfts, 0.50),
        "p95_ttft": percentile(ttfts, 0.95),
        "p99_ttft": percentile(ttfts, 0.99),
        "p50_tpot": percentile(tpots, 0.50),
        "p95_tpot": percentile(tpots, 0.95),
        "p99_tpot": percentile(tpots, 0.99),
        "p95_queue_delay": percentile(qdel, 0.95),
        "max_queue_delay": max(qdel, default=0.0),
        # goodput under SLO: tokens of requests that met every bound they
        # carried, over the replay makespan (unbounded requests always
        # count — an absent promise cannot be broken)
        "tokens": tokens,
        "goodput_tokens_per_s": good / makespan if makespan > 0 else 0.0,
        "goodput_frac": good / tokens if tokens else 0.0,
        "slo_violations": sched.slo_violations(),
        "tier_stats": sched.tier_stats(),
        # queue dynamics + overload ledger
        "queue_depth_max": max(depths, default=0),
        "queue_depth_mean": sum(depths) / len(depths) if depths else 0.0,
        "occupancy_mean": sum(occ) / len(occ) if occ else 0.0,
        "backpressure_steps": sum(1 for d in depths if d > 0),
        "throughput": sched.throughput_stats(),
        "timeline": _downsample(tl),
    }


def run_load(sched: ContinuousBatchingScheduler, spec: LoadSpec, *,
             max_steps: Optional[int] = None) -> dict:
    """Build the spec's trace, replay it open-loop, and summarize."""
    trace = build_trace(spec)
    sched.run_trace(trace, max_steps=max_steps)
    return summarize(sched, trace)
