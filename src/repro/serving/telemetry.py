"""Per-iteration serving telemetry: the measurement substrate Cascade's
utility analyzer feeds on (the paper's 'utility analysis telemetry', §6)."""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile: the smallest element covering a q-fraction
    of the sorted sample (q in (0, 1]; 0 of an empty sample). The ONE
    percentile rule shared by `ContinuousBatchingScheduler.tier_stats` and
    the load harness's p50/p95/p99 latency figures — two ad-hoc index
    formulas disagreeing at the tail is how p95 regressions hide."""
    if not values:
        return 0.0
    vs = sorted(values)
    rank = math.ceil(q * len(vs))
    return vs[min(max(rank, 1), len(vs)) - 1]


@dataclass
class IterationTelemetry:
    iteration: int
    k_requested: int           # controller's K
    k_drafted: int             # tokens the drafter actually proposed
    tokens_emitted: int        # accepted + 1
    t_iter: float              # total iteration seconds (virtual or wall);
                               # under batching, this request's attributed share
    t_draft: float
    t_verify: float
    t_sample: float
    unique_experts: float = 0.0   # mean per layer (MoE only); under batching,
                                  # this request's own tokens only
    context_len: int = 0
    phase: str = ""            # cascade phase when the iteration ran
    utility: float = 0.0       # analyzer's running utility after observe
    # -- continuous-batching fields (defaults = legacy single-request) ---- #
    batch_occupancy: int = 1   # requests sharing this verification pass
    union_experts: float = 0.0  # batch-union unique experts (mean per layer)
    padding_frac: float = 0.0  # padded fraction of the [B, T_max] step
    # -- batch-planner fields (k_granted == k_requested off-planner) ------ #
    k_granted: int = 0         # planner's joint allocation for this request
    plan_held: bool = False    # TEST trial postponed by phase staggering
    # -- SLO fields (docs/slo.md; defaults = unconstrained request) ------- #
    t_pass: float = 0.0        # the WHOLE shared pass's seconds (verify +
                               # slowest draft/sample) — the latency this
                               # request experienced waiting the pass out,
                               # as opposed to t_iter's attributed share
    slo_capped: bool = False   # a grant to this row was denied by an SLO


@dataclass
class StepTelemetry:
    """One continuous-batching engine step (the batch-level view the
    per-request records can't show: occupancy, expert-union inflation, and
    how much of the padded verification batch was wasted)."""
    step: int
    occupancy: int             # live requests in the pass
    tokens_in_flight: int      # sum of (1 + K_i) plus prefill-chunk tokens
    padded_tokens: int         # occupancy * T_max - tokens_in_flight
    union_experts: float = 0.0  # batch-union unique experts (mean per layer)
    t_step: float = 0.0        # shared verification seconds
    t_overhead: float = 0.0    # serial non-verify cost: max_i(draft+sample)
    joined: int = 0            # requests admitted before this step
    retired: int = 0           # requests finished by this step
    # -- chunked-prefill split (both 0 on a pure legacy decode step) ------ #
    prefill_tokens: int = 0    # prompt tokens co-scheduled into this pass
    decode_tokens: int = 0     # speculative span tokens in this pass
    # -- batch-planner decisions (requested == granted off-planner) ------- #
    k_requested: int = 0       # sum of controller asks across decode rows
    k_granted: int = 0         # sum of planner grants across decode rows
    preempted: int = 0         # decode rows granted 0 while asking > 0
    held_tests: int = 0        # TEST trials postponed by phase staggering
    t_step_predicted: float = 0.0  # planner's predicted pass seconds
    t_base_predicted: float = 0.0  # predicted no-speculation pass seconds
    tokens_predicted: float = 0.0  # planner's predicted decode emissions
    planned: bool = False      # the planner actually priced this pass —
                               # the calibration-sample filter (a predicted
                               # 0.0 is a sample, not an absence of one)
    slo_denied: int = 0        # rows whose grants an SLO constraint capped
    # -- EP-shard fields (defaults = unsharded deployment) ---------------- #
    shard_experts: tuple = ()  # per-shard activated experts (mean layers)
    max_shard_experts: float = 0.0  # the gating shard's activated experts
    hot_shard: int = -1        # id of the gating shard (-1 = unsharded)
    shard_imbalance: float = 1.0   # max-shard / mean-shard occupancy
    t_a2a: float = 0.0         # all-to-all seconds priced into t_step
    replica_moves: int = 0     # replicated experts re-routed to a cooler
                               # replica after this pass (0 = no replicas)
    packed_experts: int = 0    # U_pad of the union-packed verification
                               # path (0 = dense path)
    # -- residency/offload fields (defaults = all-hbm placement) ---------- #
    prefetch_hits: int = 0     # activated host-tier experts found resident
    prefetch_misses: int = 0   # activated host-tier experts demand-fetched
    evictions: int = 0         # host-tier residents evicted this step
    fetch_bytes: float = 0.0   # host->HBM bytes fetched (prefetch + demand)
    t_fetch: float = 0.0       # non-overlapped fetch seconds in t_step
    # -- layered-streaming fields (defaults = whole-expert granularity) --- #
    fetch_hide: float = 0.0    # the effective (staged-bytes-capped,
                               # first-layer) hide window this step's
                               # fetch pricing overlapped against
    t_fetch_by_layer: tuple = ()       # per-MoE-layer link seconds for the
                                       # gating shard's fetched slices
    prefetch_hits_by_layer: tuple = ()    # per-layer resident activations
    prefetch_misses_by_layer: tuple = ()  # per-layer demand-fetched slices
    # -- precision fields (defaults = bf16 everywhere) -------------------- #
    precision: str = ""        # cost-model Precision label ("" = legacy)
    expert_bytes_saved: float = 0.0  # expert-read bytes this pass avoided
                               # moving vs bf16 storage (0.0 unquantized)

    @property
    def t_total(self) -> float:
        """Wall time of the step: shared verify + the slowest request's
        draft/sample work (drafting runs per-request, concurrently)."""
        return self.t_step + self.t_overhead

    @property
    def padding_frac(self) -> float:
        tot = self.tokens_in_flight + self.padded_tokens
        return self.padded_tokens / tot if tot else 0.0


@dataclass
class RequestTelemetry:
    request_id: str = ""
    task: str = ""
    prompt_len: int = 0
    iterations: List[IterationTelemetry] = field(default_factory=list)
    t_prefill: float = 0.0     # prefill seconds on the engine's clock
                               # (cm.prefill_time under clock="model" — never
                               # wall-clock mixed into the virtual clock)
    t_queue: float = 0.0       # admission wait: submit -> first prefill work
    ttft: float = 0.0          # submit -> first output token, engine clock
    prefill_chunks: int = 0    # chunks the prompt was admitted in (0 =
                               # legacy single-shot blocking prefill)
    # -- SLO identity (docs/slo.md; defaults = unconstrained request) ----- #
    tier: str = "throughput"   # scheduling tier ("latency" | "throughput")
    slo_tpot: Optional[float] = None   # TPOT bound of the request, if any
    slo_ttft: Optional[float] = None   # TTFT bound of the request, if any
    # -- overload outcome (docs/serving_load.md) -------------------------- #
    shed: bool = False         # admission shed the request before it ever
                               # reached a slot; t_queue holds the wait it
                               # accrued, ttft stays 0 (and a TTFT bound on
                               # a shed request counts as violated)

    # ------------------------------------------------------------------ #

    @property
    def output_tokens(self) -> int:
        return sum(it.tokens_emitted for it in self.iterations)

    @property
    def decode_time(self) -> float:
        return sum(it.t_iter for it in self.iterations)

    @property
    def tpot(self) -> float:
        """Time per output token (paper's figure of merit): attributed
        cost share per token — what this request's decoding cost the
        cluster."""
        n = self.output_tokens
        return self.decode_time / n if n else float("inf")

    @property
    def experienced_tpot(self) -> float:
        """Time per output token the *user* experienced: under continuous
        batching a request waits out the whole shared pass between its
        token batches, so its inter-token latency is the pass time — not
        its attributed cost share, which deliberately charges expert bytes
        to whoever dragged them in. This is the quantity `RequestSLO.tpot`
        bounds and the planner's SLO constraint predicts (docs/slo.md).
        Falls back to the attributed `tpot` for records without a pass
        time (the single-request engine, where the two coincide)."""
        n = self.output_tokens
        if not n:
            return float("inf")
        t = sum(it.t_pass for it in self.iterations)
        return t / n if t > 0 else self.tpot

    @property
    def slo_tpot_violated(self) -> bool:
        """True when this request's experienced TPOT exceeded its bound
        (False without a bound — the shared no-bound-passes rule)."""
        from repro.core.slo import tpot_within
        return not tpot_within(self.slo_tpot, self.experienced_tpot)

    @property
    def slo_ttft_violated(self) -> bool:
        """True when this request's TTFT blew its bound — including the
        never-served case (shed, or still queued at a replay horizon):
        a bounded request with no first token IS a violation, not an
        unknown (`slo.ttft_violated`'s rule; mapping ttft == 0 to "no
        violation" silently zeroed the violation counters under
        overload)."""
        from repro.core.slo import ttft_violated
        return ttft_violated(self.slo_ttft, self.ttft)

    @property
    def etr(self) -> float:
        its = self.iterations
        return self.output_tokens / len(its) if its else 0.0

    def breakdown(self):
        its = self.iterations
        if not its:
            return {}
        return {
            "draft": sum(i.t_draft for i in its),
            "verify": sum(i.t_verify for i in its),
            "sample": sum(i.t_sample for i in its),
            "total": self.decode_time,
        }


@dataclass
class EngineTelemetry:
    """Per-step telemetry of a continuous-batching engine run."""
    steps: List[StepTelemetry] = field(default_factory=list)

    @property
    def mean_occupancy(self) -> float:
        s = self.steps
        return sum(t.occupancy for t in s) / len(s) if s else 0.0

    @property
    def mean_union_experts(self) -> float:
        s = self.steps
        return sum(t.union_experts for t in s) / len(s) if s else 0.0

    @property
    def mean_padding_frac(self) -> float:
        s = self.steps
        return sum(t.padding_frac for t in s) / len(s) if s else 0.0

    @property
    def total_time(self) -> float:
        return sum(t.t_total for t in self.steps)

    @property
    def prefill_token_frac(self) -> float:
        """Fraction of scheduled (unpadded) tokens that were prefill — how
        much of the serving capacity admission pressure consumed."""
        pre = sum(t.prefill_tokens for t in self.steps)
        tot = sum(t.tokens_in_flight for t in self.steps)
        return pre / tot if tot else 0.0

    # -- batch-planner aggregates ---------------------------------------- #

    @property
    def grant_ratio(self) -> float:
        """Granted / requested draft tokens across the run — how much of
        the controllers' asks the joint planner actually admitted (1.0
        under policy="independent" by construction)."""
        return planner_aggregates(self.steps)["grant_ratio"]

    @property
    def preemptions(self) -> int:
        """Decode iterations whose speculation the planner denied outright."""
        return planner_aggregates(self.steps)["preemptions"]

    @property
    def held_tests(self) -> int:
        """Cascade TEST trials postponed by phase staggering."""
        return planner_aggregates(self.steps)["held_tests"]

    @property
    def plan_time_error(self) -> float:
        """Mean relative |predicted - measured| step time — the planner's
        calibration against the measured pass (analytic union + acceptance
        prior vs the model's actual routing)."""
        return planner_aggregates(self.steps)["plan_time_error"]

    @property
    def slo_denied(self) -> int:
        """Row-steps whose grants an SLO constraint capped (victim
        protection engaging; 0 without bounded requests)."""
        return planner_aggregates(self.steps)["slo_denied"]

    @property
    def replica_moves(self) -> int:
        """Replicated-expert route flips across the run (the engine's
        online cheapest-replica routing engaging; 0 without replicas)."""
        return planner_aggregates(self.steps)["replica_moves"]

    @property
    def mean_shard_imbalance(self) -> float:
        """Mean max-shard/mean-shard activated-expert ratio over sharded
        steps (1.0 = perfectly balanced, or no EP placement)."""
        return planner_aggregates(self.steps)["mean_shard_imbalance"]

    @property
    def hot_shard_frac(self) -> float:
        """How persistently one shard gates: the modal hot shard's share
        of sharded steps (0.0 when the deployment is unsharded)."""
        return planner_aggregates(self.steps)["hot_shard_frac"]

    @property
    def prefetch_hit_rate(self) -> float:
        """Activated host-tier experts found HBM-resident at pass time /
        all activated host-tier experts (1.0 = every fetch was hidden by
        the prefetcher, or no host tier; docs/offload.md)."""
        return planner_aggregates(self.steps)["prefetch_hit_rate"]

    @property
    def fetch_bytes(self) -> float:
        """Total host->HBM bytes fetched across the run (0 without a
        host tier)."""
        return planner_aggregates(self.steps)["fetch_bytes"]

    @property
    def evictions(self) -> int:
        """Host-tier cache evictions across the run."""
        return planner_aggregates(self.steps)["evictions"]

    @property
    def expert_bytes_saved(self) -> float:
        """Expert-read bytes the run avoided moving vs bf16 storage
        (docs/quantization.md; 0.0 on unquantized runs)."""
        return planner_aggregates(self.steps)["expert_bytes_saved"]


def planner_aggregates(steps) -> dict:
    """Batch-planner decision aggregates over a step-telemetry list — the
    one implementation behind `EngineTelemetry`'s planner properties and
    `ContinuousBatchingScheduler.planner_stats` (which slices the steps to
    its own run before aggregating)."""
    req = sum(s.k_requested for s in steps)
    gr = sum(s.k_granted for s in steps)
    hits = sum(s.prefetch_hits for s in steps)
    misses = sum(s.prefetch_misses for s in steps)
    # filter on "a plan priced this pass", not on the prediction's
    # truthiness — a predicted 0.0 is a (terrible) calibration sample the
    # error must count, not a missing one
    errs = [abs(s.t_step_predicted - s.t_step) / s.t_step
            for s in steps if s.t_step > 0 and s.planned]
    sharded = [s for s in steps if s.hot_shard >= 0]
    hot_frac = 0.0
    if sharded:
        counts: dict = {}
        for s in sharded:
            counts[s.hot_shard] = counts.get(s.hot_shard, 0) + 1
        hot_frac = max(counts.values()) / len(sharded)
    return {
        "grant_ratio": gr / req if req else 1.0,
        "preemptions": sum(s.preempted for s in steps),
        "held_tests": sum(s.held_tests for s in steps),
        "plan_time_error": sum(errs) / len(errs) if errs else 0.0,
        "mean_shard_imbalance": (sum(s.shard_imbalance for s in sharded)
                                 / len(sharded) if sharded else 1.0),
        "hot_shard_frac": hot_frac,
        "slo_denied": sum(s.slo_denied for s in steps),
        "replica_moves": sum(s.replica_moves for s in steps),
        "prefetch_hit_rate": (hits / (hits + misses)
                              if (hits + misses) else 1.0),
        "fetch_bytes": sum(s.fetch_bytes for s in steps),
        "evictions": sum(s.evictions for s in steps),
        "expert_bytes_saved": sum(s.expert_bytes_saved for s in steps),
    }
