"""Per-iteration serving telemetry: the measurement substrate Cascade's
utility analyzer feeds on (the paper's 'utility analysis telemetry', §6)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class IterationTelemetry:
    iteration: int
    k_requested: int           # controller's K
    k_drafted: int             # tokens the drafter actually proposed
    tokens_emitted: int        # accepted + 1
    t_iter: float              # total iteration seconds (virtual or wall)
    t_draft: float
    t_verify: float
    t_sample: float
    unique_experts: float = 0.0   # mean per layer (MoE only)
    context_len: int = 0
    phase: str = ""            # cascade phase when the iteration ran
    utility: float = 0.0       # analyzer's running utility after observe


@dataclass
class RequestTelemetry:
    request_id: str = ""
    task: str = ""
    prompt_len: int = 0
    iterations: List[IterationTelemetry] = field(default_factory=list)
    t_prefill: float = 0.0

    # ------------------------------------------------------------------ #

    @property
    def output_tokens(self) -> int:
        return sum(it.tokens_emitted for it in self.iterations)

    @property
    def decode_time(self) -> float:
        return sum(it.t_iter for it in self.iterations)

    @property
    def tpot(self) -> float:
        """Time per output token (paper's figure of merit)."""
        n = self.output_tokens
        return self.decode_time / n if n else float("inf")

    @property
    def etr(self) -> float:
        its = self.iterations
        return self.output_tokens / len(its) if its else 0.0

    def breakdown(self):
        its = self.iterations
        if not its:
            return {}
        return {
            "draft": sum(i.t_draft for i in its),
            "verify": sum(i.t_verify for i in its),
            "sample": sum(i.t_sample for i in its),
            "total": self.decode_time,
        }
