"""Drafters: n-gram prompt-lookup (the paper's main technique, model-free)
and a learned draft-model drafter (EAGLE stand-in — the paper's EAGLE case
study uses a feature-level drafter available only for Mixtral; we implement
the general draft-model form with the same engine interface).

A drafter proposes up to K tokens given the token history. It may return
fewer than K (n-gram returns none when no match exists) — the engine treats
the actual proposal length as this iteration's effective K."""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


class Drafter:
    """Interface."""
    #: active params fetched per drafted token (cost-model input); 0 => free
    active_params: int = 0
    #: hard cap on proposal length, if the drafter has one (None = only the
    #: engine's K bounds it); the engine's KV-ring guard falls back to this
    #: when the controller exposes no k_max
    max_propose: Optional[int] = None

    def reset(self) -> None:
        pass

    def propose(self, history: List[int], k: int, rng=None
                ) -> Tuple[List[int], Optional[np.ndarray]]:
        """Return (draft_tokens, draft_probs or None). Stochastic drafters
        sample from `rng` (np.random.Generator); deterministic drafters
        return draft_probs=None (point-mass q)."""
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Prompt-lookup decoding (Saxena '23 [38]): find the longest recent
    n-gram suffix that occurred earlier in the history and propose the
    tokens that followed it. Deterministic — draft_probs is None.

    The scan is bounded to the last `max_scan` tokens of the history
    (0 = unbounded). The unbounded form rebuilt a sliding-window view of the
    *entire* history every iteration — O(len(history)) per proposal, so a
    long generation paid quadratic total drafting cost. On histories no
    longer than `max_scan` the bounded scan is exact (identical proposals);
    on longer ones it keeps the most recent occurrences, which is also where
    prompt-lookup hits live."""

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 max_scan: int = 512):
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_scan = max_scan

    def propose(self, history: List[int], k: int, rng=None):
        if k <= 0 or len(history) < self.min_ngram + 1:
            return [], None
        n_hist = len(history)
        base = max(0, n_hist - self.max_scan) if self.max_scan else 0
        h = np.asarray(history[base:])
        n_win = len(h)
        if n_win < self.min_ngram + 1:
            return [], None
        for n in range(min(self.max_ngram, n_win - 1), self.min_ngram - 1, -1):
            suffix = h[-n:]
            # vectorized rolling-window match: windows[i] == h[i:i+n]
            windows = np.lib.stride_tricks.sliding_window_view(
                h[:-1], n)                       # exclude the suffix itself
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            # latest earlier occurrence with a non-empty continuation
            hits = hits[hits + n < n_win]
            if hits.size:
                start = int(hits[-1])
                cont = h[start + n:start + n + k]
                if cont.size:
                    return [int(c) for c in cont], None
        return [], None


class DraftModelDrafter(Drafter):
    """A small autoregressive target-family-agnostic draft model with its own
    KV cache, kept in sync with the request's token history. Drafted tokens
    are rolled back after each proposal (only externally-committed tokens
    stay in the drafter's cache)."""

    def __init__(self, cfg, params, max_len: int = 4096,
                 temperature: float = 1.0):
        from repro.models import transformer as T
        self._T = T
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.active_params = cfg.active_param_count()
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t)[:2])
        self.reset()

    def reset(self):
        self.cache = None
        self.synced = 0  # tokens of history already in the drafter cache
        self._last_logits = None

    def _ensure_cache(self, batch: int = 1):
        if self.cache is None:
            self.cache = self._T.init_cache(self.cfg, batch, self.max_len)

    def _feed(self, tokens: List[int]):
        """Advance the drafter cache over committed tokens."""
        if not tokens:
            return
        self._ensure_cache()
        arr = jnp.asarray(tokens, jnp.int32)[None, :]
        logits, self.cache = self._decode(self.params, self.cache, arr)
        self._last_logits = np.asarray(logits[0, -1])
        self.synced += len(tokens)

    def propose(self, history: List[int], k: int, rng=None):
        self._feed(history[self.synced:])
        if k <= 0 or self._last_logits is None:
            return [], None
        greedy = self.temperature <= 0 or rng is None
        drafts: List[int] = []
        probs: List[np.ndarray] = []
        logits = self._last_logits
        cache = self.cache
        for _ in range(k):
            if greedy:
                tok = int(np.argmax(logits))
            else:
                x = np.asarray(logits, np.float64) / self.temperature
                x -= x.max()
                p = np.exp(x)
                p /= p.sum()
                tok = int(rng.choice(len(p), p=p))
                probs.append(p.astype(np.float32))
            drafts.append(tok)
            lo, cache = self._decode(self.params,
                                     cache, jnp.asarray([[tok]], jnp.int32))
            logits = np.asarray(lo[0, -1])
        # roll back: drafted tokens are speculative; keep only synced prefix
        # (attention cache rollback is metadata-only)
        self.cache = self._T.rollback_cache(self.cfg, cache, None, 0,
                                            self.synced)
        return drafts, (np.stack(probs) if probs else None)
