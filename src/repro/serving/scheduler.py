"""Request schedulers.

`Scheduler` — the original FIFO queue serving requests one at a time (the
paper's single-batch, latency-critical setting). It only needs an object
with `.generate(...)`, so handing it a `BatchedEngine` makes it a thin
wrapper over continuous batching at occupancy 1.

`ContinuousBatchingScheduler` — the production path: an admission queue in
front of a `BatchedEngine` slot table. Every engine step, finished requests
retire and queued requests join the freed rows, so the verification batch
stays as full as the workload allows. Mixed workloads (code+math etc.) are
interleaved streams of task-tagged requests, matching the paper's §3
'mixed' workloads — now sharing one verification pass whose cost is driven
by the *union* of the experts their drafts activate (see docs/batching.md).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, List, Optional, Tuple

from repro.core.planner import DEFER, SHED, AdmissionConstraint
from repro.core.slo import LATENCY, RequestSLO

from .engine import BatchedEngine, GenerationResult, ServingEngine
from .telemetry import RequestTelemetry, percentile, planner_aggregates


@dataclass
class Request:
    request_id: str
    prompt: list
    max_new: int = 128
    task: str = ""
    enc_out: object = None
    stop_token: Optional[int] = None
    #: latency objective (docs/slo.md): a TPOT/TTFT bound plus tier.
    #: Latency-tier requests are admitted ahead of FIFO when a slot frees,
    #: and their TPOT bound constrains the planner's joint allocation.
    slo: Optional[RequestSLO] = None


@dataclass
class Scheduler:
    engine: ServingEngine
    controller_factory: Optional[Callable] = None
    share_controller_across_requests: bool = False

    _shared_controller: object = None
    results: List[GenerationResult] = field(default_factory=list)

    def run(self, requests: Iterable[Request]) -> List[GenerationResult]:
        for req in requests:
            ctl = None
            if self.controller_factory is not None:
                if self.share_controller_across_requests:
                    if self._shared_controller is None:
                        self._shared_controller = self.controller_factory()
                    ctl = self._shared_controller
                else:
                    ctl = self.controller_factory()
            res = self.engine.generate(req.prompt, req.max_new,
                                       controller=ctl,
                                       request_id=req.request_id,
                                       task=req.task, enc_out=req.enc_out,
                                       stop_token=req.stop_token)
            self.results.append(res)
        return self.results

    # -- aggregate figures of merit (paper §3) -------------------------- #

    def tokens_per_second(self) -> float:
        toks = sum(r.telemetry.output_tokens for r in self.results)
        t = sum(r.telemetry.decode_time for r in self.results)
        return toks / t if t else 0.0

    def mean_tpot(self) -> float:
        tps = self.tokens_per_second()
        return 1.0 / tps if tps else float("inf")


@dataclass
class ContinuousBatchingScheduler:
    """Admission queue + slot table over a `BatchedEngine`.

    `run(requests)` admits requests FIFO into free engine slots, steps the
    engine until everything drains, and retires finished requests as their
    rows free up — the continuous part: a long request never blocks the
    batch, short requests flow through around it."""

    engine: BatchedEngine
    controller_factory: Optional[Callable] = None
    #: join-side admission pipeline (docs/serving_load.md): vets each
    #: queued request about to join — ADMIT / DEFER (backpressure) /
    #: SHED (load shedding). None admits everything, bit-identically.
    admission: Optional[AdmissionConstraint] = None
    #: starvation guard (bounded queue-jumps): a waiting non-latency
    #: request may be jumped by latency-tier admissions at most this many
    #: times before it is served next regardless of tier. None disables
    #: the guard (the pre-guard unconditional-jump scheduler, under which
    #: sustained latency traffic starves the throughput tier forever).
    #: Plain FIFO stays byte-identical either way when no latency-tier
    #: request waits.
    max_queue_jumps: Optional[int] = 8

    queue: Deque[Request] = field(default_factory=deque)
    results: List[GenerationResult] = field(default_factory=list)
    #: requests the admission pipeline dropped (empty token streams,
    #: telemetry carrying tier/bounds/queue-wait) — kept OUT of `results`
    #: so served-request figures stay served-request figures, counted by
    #: `tier_stats`/`slo_violations`/the load harness as violations
    shed_results: List[GenerationResult] = field(default_factory=list)
    #: (engine-clock t, queue_depth, occupancy) samples, one per
    #: `run_trace` step — the queue-dynamics time series
    timeline: List[Tuple[float, int, int]] = field(default_factory=list)
    deferred: int = 0          # DEFER verdicts issued (backpressure events)
    _order: List[str] = field(default_factory=list)
    _by_id: Dict[str, GenerationResult] = field(default_factory=dict)
    _slot_req: Dict[int, str] = field(default_factory=dict)
    _submit_time: Dict[str, float] = field(default_factory=dict)
    _jumps: Dict[str, int] = field(default_factory=dict)
    _deferrals: Dict[str, int] = field(default_factory=dict)
    _steps_start: int = 0

    def __post_init__(self):
        # engine may be reused across schedulers: only count steps (and
        # their time) taken after this scheduler attached
        self._steps_start = len(self.engine.telemetry.steps)

    # -- admission / draining ------------------------------------------- #

    def submit(self, req: Request, at: Optional[float] = None) -> None:
        """Enqueue a request. `at` (engine-clock seconds) stamps its
        arrival time for queue-delay/TTFT telemetry — `run_trace` passes
        the trace's arrival stamps so a request that waited out a long
        step before release is charged from when it *arrived*, not from
        when the loop got around to the submit call. Default: arrived
        now (the closed-loop behavior, byte-identical to before)."""
        self.queue.append(req)
        self._order.append(req.request_id)
        self._submit_time[req.request_id] = (
            getattr(self.engine, "now", 0.0) if at is None else float(at))

    def _pop_next(self) -> Request:
        """Tier-aware admission with a starvation guard: the first
        latency-tier request jumps the queue (FIFO within each tier) —
        but only until the waiting queue head has been jumped
        `max_queue_jumps` times, after which the head is served
        regardless of tier, so a sustained latency stream can no longer
        starve throughput-tier requests indefinitely (each one's
        admission is delayed by at most its queue position plus the jump
        bound). With no latency-tier request waiting this is plain FIFO
        — byte-identical to the pre-SLO scheduler."""
        for n, r in enumerate(self.queue):
            if r.slo is not None and r.slo.tier == LATENCY:
                if n > 0 and self.max_queue_jumps is not None:
                    head = self.queue[0]
                    if (self._jumps.get(head.request_id, 0)
                            >= self.max_queue_jumps):
                        return self.queue.popleft()
                    for jumped in list(self.queue)[:n]:
                        rid = jumped.request_id
                        self._jumps[rid] = self._jumps.get(rid, 0) + 1
                del self.queue[n]
                return r
        return self.queue.popleft()

    def _shed(self, req: Request, queue_delay: float) -> None:
        """Record an admission drop as first-class telemetry: an empty
        token stream whose RequestTelemetry carries the tier, the bounds
        (a TTFT bound on a never-served request counts as violated), and
        the queue delay it accrued before the verdict."""
        tel = RequestTelemetry(request_id=req.request_id, task=req.task,
                               prompt_len=len(req.prompt), shed=True)
        tel.t_queue = queue_delay
        if req.slo is not None:
            tel.tier = req.slo.tier
            tel.slo_tpot = req.slo.tpot
            tel.slo_ttft = req.slo.ttft
        self.shed_results.append(GenerationResult(tokens=[], telemetry=tel))

    def _admit(self) -> None:
        while self.queue and self.engine.free_slots:
            req = self._pop_next()
            rid = req.request_id
            self._jumps.pop(rid, None)
            if self.admission is not None:
                delay = max(getattr(self.engine, "now", 0.0)
                            - self._submit_time.get(rid, 0.0), 0.0)
                svc = self.engine.predicted_service_time(len(req.prompt))
                dec = self.admission.decide(
                    req.slo, queue_delay=delay, service_time=svc,
                    deferrals=self._deferrals.get(rid, 0))
                # a DEFER against an idle engine would never resolve (the
                # clock only advances with the batch) — serve it instead
                if dec.action == DEFER and self.engine.active_slots:
                    self._deferrals[rid] = self._deferrals.get(rid, 0) + 1
                    self.deferred += 1
                    self.queue.appendleft(req)   # backpressure: hold the
                    break                        # queue until re-decided
                if dec.action == SHED:
                    self._deferrals.pop(rid, None)
                    self._shed(req, delay)
                    continue
            self._deferrals.pop(rid, None)
            ctl = (self.controller_factory() if self.controller_factory
                   else None)
            idx = self.engine.join(req.prompt, req.max_new, controller=ctl,
                                   request_id=req.request_id, task=req.task,
                                   stop_token=req.stop_token,
                                   enc_out=req.enc_out,
                                   submit_time=self._submit_time.get(
                                       req.request_id),
                                   slo=req.slo)
            self._slot_req[idx] = req.request_id

    def _retire_finished(self) -> None:
        for idx, slot in enumerate(self.engine.slots):
            if slot is not None and slot.done:
                res = self.engine.retire(idx)
                self._by_id[self._slot_req.pop(idx)] = res

    def step(self) -> bool:
        """Admit, run one engine step, retire. False when fully drained."""
        self._admit()
        if not self.engine.active_slots and not self.queue:
            return False
        if not self.engine.active_slots:
            # the whole queue was shed this round — drained, no pass to run
            return bool(self.queue)
        self.engine.step()
        self._retire_finished()
        return bool(self.queue or self.engine.active_slots)

    def run(self, requests: Iterable[Request]) -> List[GenerationResult]:
        """Serve `requests` to completion; results in submission order."""
        for req in requests:
            self.submit(req)
        while self.step():
            pass
        self.results = [self._by_id[rid] for rid in self._order
                        if rid in self._by_id]
        return self.results

    def run_trace(self, trace: Iterable,
                  max_steps: Optional[int] = None
                  ) -> List[GenerationResult]:
        """Open-loop replay (docs/serving_load.md): serve `(arrival_time,
        Request)` pairs, holding each request out of the queue until the
        engine clock reaches its arrival — unlike `run`, the scheduler
        cannot pull work forward, so queue depth and TTFT reflect the
        offered load, not the drain rate. An idle engine fast-forwards
        the clock to the next arrival (virtual seconds are free). Samples
        (t, queue_depth, occupancy) into `self.timeline` after every
        step. `max_steps` cuts the replay at a horizon, leaving requests
        in flight — the censored regime `throughput_stats` reports
        honestly. Returns finished results in arrival order."""
        pending = deque(sorted(((float(at), req) for at, req in trace),
                               key=lambda p: p[0]))
        steps = 0
        while pending or self.queue or self.engine.active_slots:
            now = getattr(self.engine, "now", 0.0)
            while pending and pending[0][0] <= now:
                at, req = pending.popleft()
                self.submit(req, at=at)
            if not self.queue and not self.engine.active_slots:
                # idle: nothing live — jump to the next arrival
                self.engine.now = max(now, pending[0][0])
                continue
            if not self.step() and not pending:
                break
            steps += 1
            self.timeline.append((self.engine.now, len(self.queue),
                                  len(self.engine.active_slots)))
            if max_steps is not None and steps >= max_steps:
                break
        self.results = [self._by_id[rid] for rid in self._order
                        if rid in self._by_id]
        return self.results

    # -- aggregate figures of merit ------------------------------------- #

    def _inflight_telemetry(self) -> List[RequestTelemetry]:
        """Telemetry of this scheduler's requests still occupying slots —
        non-empty only when measuring before the run drained (a replay
        horizon), the censored regime `tokens_per_second` must account."""
        return [self.engine.slots[i].tel
                for i, _ in self._slot_req.items()
                if self.engine.slots[i] is not None]

    def tokens_per_second(self) -> float:
        """Decode throughput: emitted tokens over *shared* step wall time
        (not the sum of per-request attributed times — that would count the
        shared verification pass B times). Blocking (chunk=0) prefill runs
        inside join() and never enters the steps, so the chunked prefill
        work co-scheduled *into* steps is subtracted via its attributed
        share — both admission modes then measure the same decode-only
        quantity. Measured at a replay horizon with requests still in
        flight, their emissions (and their prefill share) count too —
        counting all steps' time but only finished requests' tokens would
        censor the figure downward exactly when the batch is fullest. On
        a drained run the in-flight terms are empty and the figure is
        byte-identical to the finished-only accounting."""
        rs = self.results
        toks = sum(r.telemetry.output_tokens for r in rs)
        t = sum(s.t_total
                for s in self.engine.telemetry.steps[self._steps_start:])
        t -= sum(r.telemetry.t_prefill for r in rs
                 if r.telemetry.prefill_chunks)
        inflight = self._inflight_telemetry()
        if inflight:
            toks += sum(tel.output_tokens for tel in inflight)
            t -= sum(tel.t_prefill for tel in inflight
                     if tel.prefill_chunks)
        return toks / t if t > 0 else 0.0

    def throughput_stats(self) -> dict:
        """Drained vs censored decode throughput, explicitly: the drained
        figure counts finished requests only (the pre-horizon quantity —
        correct once the run drained, censored before), the corrected
        figure adds in-flight emissions and their prefill share
        (`tokens_per_second`'s accounting). `censored` says whether the
        two can differ right now."""
        rs = self.results
        fin_toks = sum(r.telemetry.output_tokens for r in rs)
        t = sum(s.t_total
                for s in self.engine.telemetry.steps[self._steps_start:])
        t_fin = t - sum(r.telemetry.t_prefill for r in rs
                        if r.telemetry.prefill_chunks)
        inflight = self._inflight_telemetry()
        in_toks = sum(tel.output_tokens for tel in inflight)
        t_all = t_fin - sum(tel.t_prefill for tel in inflight
                            if tel.prefill_chunks)
        return {
            "finished_tokens": fin_toks,
            "inflight_tokens": in_toks,
            "censored": bool(inflight or self.queue),
            "drained_tokens_per_s": fin_toks / t_fin if t_fin > 0 else 0.0,
            "tokens_per_s": ((fin_toks + in_toks) / t_all
                             if t_all > 0 else 0.0),
        }

    def mean_tpot(self) -> float:
        tps = self.tokens_per_second()
        return 1.0 / tps if tps else float("inf")

    def mean_request_utility(self) -> float:
        rs = self.results
        if not rs:
            return 0.0
        finals = [r.telemetry.iterations[-1].utility
                  for r in rs if r.telemetry.iterations]
        return sum(finals) / len(finals) if finals else 0.0

    def mean_ttft(self) -> float:
        """Mean submit -> first-token latency on the engine clock — the
        admission-side figure of merit chunked prefill exists to improve."""
        rs = self.results
        return sum(r.telemetry.ttft for r in rs) / len(rs) if rs else 0.0

    def mean_queue_delay(self) -> float:
        rs = self.results
        return sum(r.telemetry.t_queue for r in rs) / len(rs) if rs else 0.0

    def planner_stats(self) -> dict:
        """Batch-planner figures over this scheduler's steps (sliced from
        `_steps_start` so a reused engine's earlier runs don't leak in):
        grant ratio (granted/requested drafts — 1.0 under
        policy="independent" by construction), outright preemptions, TEST
        trials postponed by phase staggering, the planner's
        predicted-vs-measured step-time calibration error, row-steps whose
        grants an SLO constraint capped (`slo_denied`, docs/slo.md), and —
        under an EP placement (docs/expert_parallel.md) — the mean
        max/mean-shard activation imbalance plus how persistently one
        shard gated the pass (`hot_shard_frac`)."""
        return planner_aggregates(
            self.engine.telemetry.steps[self._steps_start:])

    # -- SLO figures of merit (docs/slo.md) ----------------------------- #

    def tier_stats(self) -> Dict[str, dict]:
        """Per-tier latency/throughput figures: request count and emitted
        tokens over finished requests, mean/p95 *experienced* TPOT (the
        pass time a request waits out between token batches — the quantity
        `RequestSLO.tpot` bounds, nearest-rank p95 via the shared
        `telemetry.percentile`), mean TTFT, and how many requests violated
        their own TPOT/TTFT bound. Shed requests count toward their tier's
        `shed` and — when TTFT-bounded — `ttft_violations` (a bounded
        request that never got a first token is a violation, not a
        no-op); they contribute no latency samples (there is nothing to
        sample)."""
        tiers: Dict[str, list] = {}
        for r in self.results:
            tiers.setdefault(r.telemetry.tier, []).append(r.telemetry)
        shed_tiers: Dict[str, list] = {}
        for r in self.shed_results:
            shed_tiers.setdefault(r.telemetry.tier, []).append(r.telemetry)
        out = {}
        for tier in {**tiers, **shed_tiers}:
            tels = tiers.get(tier, [])
            shed = shed_tiers.get(tier, [])
            tpots = sorted(t.experienced_tpot for t in tels
                           if t.output_tokens)
            out[tier] = {
                "n": len(tels),
                "shed": len(shed),
                "tokens": sum(t.output_tokens for t in tels),
                "mean_tpot": sum(tpots) / len(tpots) if tpots else 0.0,
                "p95_tpot": percentile(tpots, 0.95),
                "max_tpot": tpots[-1] if tpots else 0.0,
                "mean_ttft": (sum(t.ttft for t in tels) / len(tels)
                              if tels else 0.0),
                "tpot_violations": sum(t.slo_tpot_violated for t in tels),
                "ttft_violations": sum(t.slo_ttft_violated
                                       for t in tels + shed),
            }
        return out

    def slo_violations(self) -> int:
        """Requests whose experienced TPOT or TTFT exceeded their own
        bound (0 without bounded requests). Shed requests count their
        TTFT bound as violated — never serving a bounded request is the
        one way to miss its deadline with certainty."""
        return (sum(r.telemetry.slo_tpot_violated
                    + r.telemetry.slo_ttft_violated for r in self.results)
                + sum(r.telemetry.slo_ttft_violated
                      for r in self.shed_results))
