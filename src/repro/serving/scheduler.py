"""Request scheduler: a FIFO queue of heterogeneous requests served
sequentially — the paper's single-batch, latency-critical serving setting.
Mixed workloads (code+math etc.) are interleaved streams of task-tagged
requests, matching the paper's §3 'mixed' workloads."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional

from .engine import GenerationResult, ServingEngine


@dataclass
class Request:
    request_id: str
    prompt: list
    max_new: int = 128
    task: str = ""
    enc_out: object = None


@dataclass
class Scheduler:
    engine: ServingEngine
    controller_factory: Optional[Callable] = None
    share_controller_across_requests: bool = False

    _shared_controller: object = None
    results: List[GenerationResult] = field(default_factory=list)

    def run(self, requests: Iterable[Request]) -> List[GenerationResult]:
        for req in requests:
            ctl = None
            if self.controller_factory is not None:
                if self.share_controller_across_requests:
                    if self._shared_controller is None:
                        self._shared_controller = self.controller_factory()
                    ctl = self._shared_controller
                else:
                    ctl = self.controller_factory()
            res = self.engine.generate(req.prompt, req.max_new,
                                       controller=ctl,
                                       request_id=req.request_id,
                                       task=req.task, enc_out=req.enc_out)
            self.results.append(res)
        return self.results

    # -- aggregate figures of merit (paper §3) -------------------------- #

    def tokens_per_second(self) -> float:
        toks = sum(r.telemetry.output_tokens for r in self.results)
        t = sum(r.telemetry.decode_time for r in self.results)
        return toks / t if t else 0.0

    def mean_tpot(self) -> float:
        tps = self.tokens_per_second()
        return 1.0 / tps if tps else float("inf")
