"""Request schedulers.

`Scheduler` — the original FIFO queue serving requests one at a time (the
paper's single-batch, latency-critical setting). It only needs an object
with `.generate(...)`, so handing it a `BatchedEngine` makes it a thin
wrapper over continuous batching at occupancy 1.

`ContinuousBatchingScheduler` — the production path: an admission queue in
front of a `BatchedEngine` slot table. Every engine step, finished requests
retire and queued requests join the freed rows, so the verification batch
stays as full as the workload allows. Mixed workloads (code+math etc.) are
interleaved streams of task-tagged requests, matching the paper's §3
'mixed' workloads — now sharing one verification pass whose cost is driven
by the *union* of the experts their drafts activate (see docs/batching.md).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, List, Optional

from repro.core.slo import LATENCY, RequestSLO

from .engine import BatchedEngine, GenerationResult, ServingEngine
from .telemetry import planner_aggregates


@dataclass
class Request:
    request_id: str
    prompt: list
    max_new: int = 128
    task: str = ""
    enc_out: object = None
    stop_token: Optional[int] = None
    #: latency objective (docs/slo.md): a TPOT/TTFT bound plus tier.
    #: Latency-tier requests are admitted ahead of FIFO when a slot frees,
    #: and their TPOT bound constrains the planner's joint allocation.
    slo: Optional[RequestSLO] = None


@dataclass
class Scheduler:
    engine: ServingEngine
    controller_factory: Optional[Callable] = None
    share_controller_across_requests: bool = False

    _shared_controller: object = None
    results: List[GenerationResult] = field(default_factory=list)

    def run(self, requests: Iterable[Request]) -> List[GenerationResult]:
        for req in requests:
            ctl = None
            if self.controller_factory is not None:
                if self.share_controller_across_requests:
                    if self._shared_controller is None:
                        self._shared_controller = self.controller_factory()
                    ctl = self._shared_controller
                else:
                    ctl = self.controller_factory()
            res = self.engine.generate(req.prompt, req.max_new,
                                       controller=ctl,
                                       request_id=req.request_id,
                                       task=req.task, enc_out=req.enc_out,
                                       stop_token=req.stop_token)
            self.results.append(res)
        return self.results

    # -- aggregate figures of merit (paper §3) -------------------------- #

    def tokens_per_second(self) -> float:
        toks = sum(r.telemetry.output_tokens for r in self.results)
        t = sum(r.telemetry.decode_time for r in self.results)
        return toks / t if t else 0.0

    def mean_tpot(self) -> float:
        tps = self.tokens_per_second()
        return 1.0 / tps if tps else float("inf")


@dataclass
class ContinuousBatchingScheduler:
    """Admission queue + slot table over a `BatchedEngine`.

    `run(requests)` admits requests FIFO into free engine slots, steps the
    engine until everything drains, and retires finished requests as their
    rows free up — the continuous part: a long request never blocks the
    batch, short requests flow through around it."""

    engine: BatchedEngine
    controller_factory: Optional[Callable] = None

    queue: Deque[Request] = field(default_factory=deque)
    results: List[GenerationResult] = field(default_factory=list)
    _order: List[str] = field(default_factory=list)
    _by_id: Dict[str, GenerationResult] = field(default_factory=dict)
    _slot_req: Dict[int, str] = field(default_factory=dict)
    _submit_time: Dict[str, float] = field(default_factory=dict)
    _steps_start: int = 0

    def __post_init__(self):
        # engine may be reused across schedulers: only count steps (and
        # their time) taken after this scheduler attached
        self._steps_start = len(self.engine.telemetry.steps)

    # -- admission / draining ------------------------------------------- #

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self._order.append(req.request_id)
        # stamp the engine clock at enqueue so queue-delay/TTFT telemetry
        # covers the scheduler's own queue, not just the slot table
        self._submit_time[req.request_id] = getattr(self.engine, "now", 0.0)

    def _pop_next(self) -> Request:
        """Tier-aware admission: the first latency-tier request jumps the
        queue (FIFO within each tier); with no latency-tier requests
        waiting, this is plain FIFO — byte-identical to the pre-SLO
        scheduler."""
        for n, r in enumerate(self.queue):
            if r.slo is not None and r.slo.tier == LATENCY:
                del self.queue[n]
                return r
        return self.queue.popleft()

    def _admit(self) -> None:
        while self.queue and self.engine.free_slots:
            req = self._pop_next()
            ctl = (self.controller_factory() if self.controller_factory
                   else None)
            idx = self.engine.join(req.prompt, req.max_new, controller=ctl,
                                   request_id=req.request_id, task=req.task,
                                   stop_token=req.stop_token,
                                   enc_out=req.enc_out,
                                   submit_time=self._submit_time.get(
                                       req.request_id),
                                   slo=req.slo)
            self._slot_req[idx] = req.request_id

    def _retire_finished(self) -> None:
        for idx, slot in enumerate(self.engine.slots):
            if slot is not None and slot.done:
                res = self.engine.retire(idx)
                self._by_id[self._slot_req.pop(idx)] = res

    def step(self) -> bool:
        """Admit, run one engine step, retire. False when fully drained."""
        self._admit()
        if not self.engine.active_slots and not self.queue:
            return False
        self.engine.step()
        self._retire_finished()
        return bool(self.queue or self.engine.active_slots)

    def run(self, requests: Iterable[Request]) -> List[GenerationResult]:
        """Serve `requests` to completion; results in submission order."""
        for req in requests:
            self.submit(req)
        while self.step():
            pass
        self.results = [self._by_id[rid] for rid in self._order
                        if rid in self._by_id]
        return self.results

    # -- aggregate figures of merit ------------------------------------- #

    def tokens_per_second(self) -> float:
        """Decode throughput: emitted tokens over *shared* step wall time
        (not the sum of per-request attributed times — that would count the
        shared verification pass B times). Blocking (chunk=0) prefill runs
        inside join() and never enters the steps, so the chunked prefill
        work co-scheduled *into* steps is subtracted via its attributed
        share — both admission modes then measure the same decode-only
        quantity."""
        rs = self.results
        toks = sum(r.telemetry.output_tokens for r in rs)
        t = sum(s.t_total
                for s in self.engine.telemetry.steps[self._steps_start:])
        t -= sum(r.telemetry.t_prefill for r in rs
                 if r.telemetry.prefill_chunks)
        return toks / t if t > 0 else 0.0

    def mean_tpot(self) -> float:
        tps = self.tokens_per_second()
        return 1.0 / tps if tps else float("inf")

    def mean_request_utility(self) -> float:
        rs = self.results
        if not rs:
            return 0.0
        finals = [r.telemetry.iterations[-1].utility
                  for r in rs if r.telemetry.iterations]
        return sum(finals) / len(finals) if finals else 0.0

    def mean_ttft(self) -> float:
        """Mean submit -> first-token latency on the engine clock — the
        admission-side figure of merit chunked prefill exists to improve."""
        rs = self.results
        return sum(r.telemetry.ttft for r in rs) / len(rs) if rs else 0.0

    def mean_queue_delay(self) -> float:
        rs = self.results
        return sum(r.telemetry.t_queue for r in rs) / len(rs) if rs else 0.0

    def planner_stats(self) -> dict:
        """Batch-planner figures over this scheduler's steps (sliced from
        `_steps_start` so a reused engine's earlier runs don't leak in):
        grant ratio (granted/requested drafts — 1.0 under
        policy="independent" by construction), outright preemptions, TEST
        trials postponed by phase staggering, the planner's
        predicted-vs-measured step-time calibration error, row-steps whose
        grants an SLO constraint capped (`slo_denied`, docs/slo.md), and —
        under an EP placement (docs/expert_parallel.md) — the mean
        max/mean-shard activation imbalance plus how persistently one
        shard gated the pass (`hot_shard_frac`)."""
        return planner_aggregates(
            self.engine.telemetry.steps[self._steps_start:])

    # -- SLO figures of merit (docs/slo.md) ----------------------------- #

    def tier_stats(self) -> Dict[str, dict]:
        """Per-tier latency/throughput figures over finished requests:
        request count, emitted tokens, mean/p95 *experienced* TPOT (the
        pass time a request waits out between token batches — the quantity
        `RequestSLO.tpot` bounds), mean TTFT, and how many requests
        violated their own TPOT/TTFT bound."""
        tiers: Dict[str, list] = {}
        for r in self.results:
            tiers.setdefault(r.telemetry.tier, []).append(r.telemetry)
        out = {}
        for tier, tels in tiers.items():
            tpots = sorted(t.experienced_tpot for t in tels
                           if t.output_tokens)
            p95 = (tpots[min(int(0.95 * (len(tpots) - 1) + 0.999999),
                             len(tpots) - 1)] if tpots else 0.0)
            out[tier] = {
                "n": len(tels),
                "tokens": sum(t.output_tokens for t in tels),
                "mean_tpot": sum(tpots) / len(tpots) if tpots else 0.0,
                "p95_tpot": p95,
                "max_tpot": tpots[-1] if tpots else 0.0,
                "mean_ttft": sum(t.ttft for t in tels) / len(tels),
                "tpot_violations": sum(t.slo_tpot_violated for t in tels),
                "ttft_violations": sum(t.slo_ttft_violated for t in tels),
            }
        return out

    def slo_violations(self) -> int:
        """Finished requests whose experienced TPOT or TTFT exceeded their
        own bound (0 without bounded requests)."""
        return sum(r.telemetry.slo_tpot_violated
                   + r.telemetry.slo_ttft_violated for r in self.results)
