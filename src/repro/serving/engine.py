"""Serving engines: the vLLM-analogue decode loop with speculative decoding
and Cascade in the loop.

Two engines share the verification math:

`ServingEngine` — single-request-at-a-time (the paper's single-batch,
latency-bound setting). Per iteration (paper Fig. 14's spec-decode worker):
    1. controller.next_k() -> K            (Cascade / static policy)
    2. drafter.propose(history, K)         (n-gram or draft model)
    3. decode_step over [last_token, d_0..d_{K-1}]   (verification)
    4. rejection sample -> accepted prefix + next token
    5. rollback cache to the accepted length
    6. controller.observe(tokens, t_iter, breakdown)

`BatchedEngine` — continuous batching: a slot table of up to `max_batch`
in-flight requests, each with its own Cascade controller, drafter, and
cache row. One `step()` drafts per-request K_i, packs the ragged [1+K_i]
spans into a single padded verification pass, rejection-samples per row,
rolls every row back to its own accepted length, and attributes the shared
verification cost back to requests through the cost model's marginal-bytes
split (`cost_model.batch_iteration_time`). The batch-level cost driver is
the *union* of experts the B spans activate — the paper's Fig. 2 effect
compounding across requests.

Timing source is pluggable: 'wall' uses the host clock (meaningful on real
accelerators); 'model' uses the deterministic TPU-v5e data-movement cost
model driven by the *measured* unique-expert activations of this iteration
(DESIGN.md §4 — the honest CPU-container strategy)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core.controller import CascadeController, StaticKController
from repro.models import transformer as T

from .drafter import Drafter, NGramDrafter
from .sampler import greedy_verify, logits_to_probs, rejection_sample, sample_token
from .telemetry import (EngineTelemetry, IterationTelemetry,
                        RequestTelemetry, StepTelemetry)


@dataclass
class GenerationResult:
    tokens: List[int]
    telemetry: RequestTelemetry


def _sample_logits(rng: np.random.Generator, logits: np.ndarray,
                   temperature: float) -> int:
    """Temperature-gated sampling shared by both engines: argmax at
    temperature <= 0, softmax sample otherwise."""
    if temperature <= 0:
        return int(np.argmax(logits))
    probs = np.asarray(logits_to_probs(jnp.asarray(logits), temperature))
    return sample_token(rng, probs)


class ServingEngine:
    """Single-request-at-a-time serving (the paper's single-batch,
    latency-bound setting)."""

    def __init__(self, cfg, params, drafter: Drafter, *,
                 controller_factory: Callable = None,
                 clock: str = "model",
                 hw: cm.Hardware = cm.TPU_V5E,
                 affinity: float = 0.0,
                 window: int = 0,
                 max_len: int = 2048,
                 temperature: float = 1.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.drafter = drafter
        self.controller_factory = controller_factory or (
            lambda: CascadeController())
        self.clock = clock
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.max_len = max_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)

        self._prefill = jax.jit(
            lambda p, t, c, e: T.prefill(cfg, p, t, c, window=window,
                                         enc_out=e))
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t, window=window))

    # ------------------------------------------------------------------ #

    def _iter_time(self, n_tokens: int, context_len: int,
                   unique_experts: Optional[float], wall: float) -> float:
        """Virtual (cost-model) or wall-clock verification time."""
        if self.clock == "wall":
            return wall
        r = cm.iteration_time(self.cfg, self.hw, n_tokens, context_len,
                              unique_experts=unique_experts,
                              affinity=self.affinity, window=self.window)
        return r["t_iter"]

    def _draft_time(self, k: int) -> float:
        return cm.draft_time(self.hw, k, self.drafter.active_params)

    # ------------------------------------------------------------------ #

    def generate(self, prompt: List[int], max_new: int = 128, *,
                 controller=None, request_id: str = "", task: str = "",
                 stop_token: Optional[int] = None,
                 enc_out=None) -> GenerationResult:
        cfg = self.cfg
        controller = controller or self.controller_factory()
        self.drafter.reset()
        tel = RequestTelemetry(request_id=request_id, task=task,
                               prompt_len=len(prompt))

        cache = T.init_cache(cfg, 1, self.max_len, window=self.window)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        t0 = time.perf_counter()
        logits, cache, _ = self._prefill(self.params, toks, cache, enc_out)
        logits = np.asarray(logits[0, -1], np.float32)
        tel.t_prefill = time.perf_counter() - t0

        history = list(prompt)
        # first output token comes from the prefill logits
        last_tok = self._sample(logits)
        out: List[int] = [last_tok]
        history.append(last_tok)

        it = 0
        while len(out) < max_new:
            k_req = controller.next_k()
            t0 = time.perf_counter()
            drafts, draft_probs = self.drafter.propose(history, k_req,
                                                       rng=self.rng)
            wall_draft = time.perf_counter() - t0
            k_eff = len(drafts)

            step_toks = jnp.asarray([ [last_tok] + drafts ], jnp.int32)
            len_before = int(cache["length"])
            t1 = time.perf_counter()
            lo, new_cache, aux, staged = self._decode(self.params, cache,
                                                      step_toks)
            lo = np.asarray(lo[0], np.float32)           # [K+1, V]
            wall_verify = time.perf_counter() - t1

            t2 = time.perf_counter()
            if self.temperature <= 0:
                res = greedy_verify(lo, drafts)
            else:
                probs = np.asarray(
                    logits_to_probs(jnp.asarray(lo), self.temperature))
                res = rejection_sample(self.rng, probs, drafts, draft_probs)
            wall_sample = time.perf_counter() - t2

            n_keep = 1 + res.n_accepted           # last_tok + accepted drafts
            cache = T.rollback_cache(cfg, new_cache, staged, n_keep,
                                     len_before)
            emitted = res.accepted + [res.next_token]
            out.extend(emitted)
            history.extend(emitted)
            last_tok = res.next_token

            uniq = None
            if "unique_experts" in aux and cfg.is_moe:
                uniq = float(np.mean(np.asarray(aux["unique_experts"])))
            t_verify = self._iter_time(k_eff + 1, len_before, uniq,
                                       wall_verify)
            t_draft = (wall_draft if self.clock == "wall"
                       else self._draft_time(k_eff))
            t_sample = (wall_sample if self.clock == "wall"
                        else cm.sample_time(k_eff))
            t_iter = t_draft + t_verify + t_sample

            controller.observe(len(emitted), t_iter, t_draft=t_draft,
                               t_verify=t_verify, t_sample=t_sample,
                               k=k_eff if k_req > 0 else 0)
            tel.iterations.append(IterationTelemetry(
                iteration=it, k_requested=k_req, k_drafted=k_eff,
                tokens_emitted=len(emitted), t_iter=t_iter, t_draft=t_draft,
                t_verify=t_verify, t_sample=t_sample,
                unique_experts=uniq or 0.0, context_len=len_before,
                phase=getattr(controller, "phase", ""),
                utility=controller.utility()))
            it += 1
            if stop_token is not None and res.next_token == stop_token:
                break
            if len(history) + 16 >= self.max_len:
                break
        return GenerationResult(out[:max_new], tel)

    # ------------------------------------------------------------------ #

    def _sample(self, logits: np.ndarray) -> int:
        return _sample_logits(self.rng, logits, self.temperature)


# ===================================================================== #
# Continuous batching
# ===================================================================== #

@dataclass
class _Slot:
    """One in-flight request: its own controller, drafter, rng stream,
    telemetry, and token state. The model-side state is row `index` of the
    engine's per-row batched cache."""
    index: int
    request_id: str
    task: str
    max_new: int
    stop_token: Optional[int]
    controller: object
    drafter: Drafter
    rng: np.random.Generator
    tel: RequestTelemetry
    history: List[int]
    out: List[int]
    last_tok: int
    done: bool = False
    iteration: int = 0


class BatchedEngine:
    """Continuous-batching serving engine.

    API:
        join(prompt, ...) -> slot    admit + prefill a request into a free
                                     cache row (raises when full)
        step() -> {slot: emitted}    one shared draft/verify/rollback pass
                                     over every live request
        retire(slot) -> result       collect a finished request, free the row
        generate(prompt, ...)        batch=1 compatibility wrapper: at
                                     max_batch=1 this reproduces the legacy
                                     `ServingEngine` token stream bit-exactly
                                     on the same seed (greedy and sampled).

    Each request keeps its own Cascade controller; the shared verification
    cost is attributed back per request via the cost model's marginal-bytes
    split, so per-request utility stays meaningful under batching."""

    def __init__(self, cfg, params, drafter_factory: Callable = None, *,
                 max_batch: int = 8,
                 controller_factory: Callable = None,
                 clock: str = "model",
                 hw: cm.Hardware = cm.TPU_V5E,
                 affinity: float = 0.0,
                 window: int = 0,
                 max_len: int = 2048,
                 temperature: float = 1.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.drafter_factory = drafter_factory or (lambda: NGramDrafter())
        self.controller_factory = controller_factory or (
            lambda: CascadeController())
        self.max_batch = max_batch
        self.clock = clock
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.max_len = max_len
        self.temperature = temperature
        self.seed = seed

        self.slots: List[Optional[_Slot]] = [None] * max_batch
        self.cache = T.init_cache(cfg, max_batch, max_len, window=window,
                                  per_row=True)
        self.telemetry = EngineTelemetry()
        self._prefill = jax.jit(
            lambda p, t, c, e: T.prefill(cfg, p, t, c, window=window,
                                         enc_out=e))
        self._decode = jax.jit(
            lambda p, c, t, m: T.decode_step(cfg, p, c, t, window=window,
                                             token_mask=m))
        self._step_idx = 0
        self._req_counter = 0
        self._joined_since_step = 0

    # -- admission ------------------------------------------------------ #

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]

    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def join(self, prompt: List[int], max_new: int = 128, *,
             controller=None, request_id: str = "", task: str = "",
             stop_token: Optional[int] = None, enc_out=None) -> int:
        """Prefill `prompt` into a free cache row; returns the slot index."""
        free = self.free_slots
        if not free:
            raise RuntimeError("no free slot — retire a request first")
        idx = free[0]
        controller = controller or self.controller_factory()
        drafter = self.drafter_factory()
        drafter.reset()
        # the first request consumes exactly the legacy engine's rng stream
        # (bit-identical batch=1 behaviour); later requests get their own
        n = self._req_counter
        rng = (np.random.default_rng(self.seed) if n == 0
               else np.random.default_rng([self.seed, n]))
        self._req_counter += 1

        tel = RequestTelemetry(request_id=request_id, task=task,
                               prompt_len=len(prompt))
        row = T.init_cache(self.cfg, 1, self.max_len, window=self.window)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        t0 = time.perf_counter()
        logits, row, _ = self._prefill(self.params, toks, row, enc_out)
        logits = np.asarray(logits[0, -1], np.float32)
        tel.t_prefill = time.perf_counter() - t0
        self.cache = T.write_cache_row(self.cache, idx, row)

        first = _sample_logits(rng, logits, self.temperature)
        self.slots[idx] = _Slot(
            index=idx, request_id=request_id, task=task, max_new=max_new,
            stop_token=stop_token, controller=controller, drafter=drafter,
            rng=rng, tel=tel, history=list(prompt) + [first], out=[first],
            last_tok=first)
        self._joined_since_step += 1
        return idx

    def retire(self, idx: int) -> GenerationResult:
        """Free the slot and return the finished request's result."""
        s = self.slots[idx] if 0 <= idx < self.max_batch else None
        if s is None:
            raise KeyError(f"slot {idx} is empty (table size "
                           f"{self.max_batch})")
        self.cache = T.clear_cache_row(self.cache, idx)
        self.slots[idx] = None
        return GenerationResult(s.out[:s.max_new], s.tel)

    # -- the shared iteration ------------------------------------------- #

    def step(self) -> dict:
        """One continuous-batching iteration over every live request:
        per-request drafting, one padded shared verification pass, per-row
        rejection sampling and rollback, marginal cost attribution.
        Returns {slot: emitted tokens}; empty when nothing is live."""
        active = self.active_slots
        if not active:
            return {}
        b = self.max_batch
        lengths_before = np.asarray(self.cache["lengths"])

        # 1. per-request drafting (each request's own controller decides K_i)
        k_req, drafts, draft_probs, wall_draft = {}, {}, {}, {}
        for i in active:
            s = self.slots[i]
            k_req[i] = s.controller.next_k()
            t0 = time.perf_counter()
            drafts[i], draft_probs[i] = s.drafter.propose(
                s.history, k_req[i], rng=s.rng)
            wall_draft[i] = time.perf_counter() - t0

        # 2. pack ragged [1 + K_i] spans into one padded batch
        t_max = max(1 + len(drafts[i]) for i in active)
        toks = np.zeros((b, t_max), np.int32)
        mask = np.zeros((b, t_max), bool)
        for i in active:
            s = self.slots[i]
            span = [s.last_tok] + drafts[i]
            toks[i, :len(span)] = span
            mask[i, :len(span)] = True

        # 3. shared verification pass
        t1 = time.perf_counter()
        lo, new_cache, aux, staged = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(mask))
        lo = np.asarray(lo, np.float32)            # [B, T_max, V]
        wall_verify = time.perf_counter() - t1

        # 4. per-row rejection sampling
        results, wall_sample = {}, {}
        for i in active:
            s = self.slots[i]
            n_i = 1 + len(drafts[i])
            t2 = time.perf_counter()
            if self.temperature <= 0:
                results[i] = greedy_verify(lo[i, :n_i], drafts[i])
            else:
                probs = np.asarray(logits_to_probs(
                    jnp.asarray(lo[i, :n_i]), self.temperature))
                results[i] = rejection_sample(s.rng, probs, drafts[i],
                                              draft_probs[i])
            wall_sample[i] = time.perf_counter() - t2

        # 5. vectorized per-row rollback (idle rows keep length unchanged)
        n_keep = np.zeros((b,), np.int32)
        for i in active:
            n_keep[i] = 1 + results[i].n_accepted
        self.cache = T.rollback_cache(self.cfg, new_cache, staged,
                                      jnp.asarray(n_keep),
                                      jnp.asarray(lengths_before))

        # 6. batch-aware cost accounting + marginal attribution
        union = per_row = None
        if self.cfg.is_moe and "unique_experts" in aux:
            union = float(np.mean(np.asarray(aux["unique_experts"])))
        if self.cfg.is_moe and "unique_experts_row" in aux:
            per_row = np.mean(np.asarray(aux["unique_experts_row"],
                                         np.float64), axis=0)   # [B]
        tokens_per_row = [int(mask[i].sum()) for i in range(b)]
        cost = cm.batch_iteration_time(
            self.cfg, self.hw, tokens_per_row, list(lengths_before),
            unique_experts=union,
            per_request_unique=(None if per_row is None else
                                [per_row[i] if i in active else 0.0
                                 for i in range(b)]),
            affinity=self.affinity, window=self.window)
        t_verify_shared = (wall_verify if self.clock == "wall"
                           else cost["t_iter"])

        # 7. feed back per request; advance token state
        emitted_by_slot = {}
        occupancy = len(active)
        n_tokens = sum(tokens_per_row)
        padded = occupancy * t_max - n_tokens
        t_overhead = 0.0
        for i in active:
            s = self.slots[i]
            res = results[i]
            k_eff = len(drafts[i])
            emitted = res.accepted + [res.next_token]
            s.out.extend(emitted)
            s.history.extend(emitted)
            s.last_tok = res.next_token

            attr = cost["per_request"][i]
            frac = (attr["bytes_attr"] / cost["bytes"]
                    if cost["bytes"] else 1.0 / occupancy)
            t_verify = (wall_verify * frac if self.clock == "wall"
                        else attr["t_attr"])
            t_draft = (wall_draft[i] if self.clock == "wall"
                       else cm.draft_time(self.hw, k_eff,
                                          s.drafter.active_params))
            t_sample = (wall_sample[i] if self.clock == "wall"
                        else cm.sample_time(k_eff))
            t_iter = t_draft + t_verify + t_sample
            t_overhead = max(t_overhead, t_draft + t_sample)

            s.controller.observe(len(emitted), t_iter, t_draft=t_draft,
                                 t_verify=t_verify, t_sample=t_sample,
                                 k=k_eff if k_req[i] > 0 else 0,
                                 batch=occupancy)
            s.tel.iterations.append(IterationTelemetry(
                iteration=s.iteration, k_requested=k_req[i],
                k_drafted=k_eff, tokens_emitted=len(emitted),
                t_iter=t_iter, t_draft=t_draft, t_verify=t_verify,
                t_sample=t_sample,
                unique_experts=(float(per_row[i]) if per_row is not None
                                else 0.0),
                context_len=int(lengths_before[i]),
                phase=getattr(s.controller, "phase", ""),
                utility=s.controller.utility(),
                batch_occupancy=occupancy,
                union_experts=union or 0.0,
                padding_frac=padded / (n_tokens + padded) if n_tokens else 0.0))
            s.iteration += 1
            emitted_by_slot[i] = emitted

            if len(s.out) >= s.max_new:
                s.done = True
            if s.stop_token is not None and res.next_token == s.stop_token:
                s.done = True
            if len(s.history) + 16 >= self.max_len:
                s.done = True

        self.telemetry.steps.append(StepTelemetry(
            step=self._step_idx, occupancy=occupancy,
            tokens_in_flight=n_tokens, padded_tokens=padded,
            union_experts=union or 0.0,
            t_step=t_verify_shared, t_overhead=t_overhead,
            joined=self._joined_since_step,
            retired=sum(1 for i in active if self.slots[i].done)))
        self._joined_since_step = 0
        self._step_idx += 1
        return emitted_by_slot

    # -- batch=1 compatibility ------------------------------------------ #

    def generate(self, prompt: List[int], max_new: int = 128, *,
                 controller=None, request_id: str = "", task: str = "",
                 stop_token: Optional[int] = None,
                 enc_out=None) -> GenerationResult:
        """Drive a single request to completion (other live slots advance
        alongside it). At max_batch=1 this is the legacy `ServingEngine`
        loop, token for token."""
        idx = self.join(prompt, max_new, controller=controller,
                        request_id=request_id, task=task,
                        stop_token=stop_token, enc_out=enc_out)
        while not self.slots[idx].done:
            self.step()
        return self.retire(idx)
