"""Serving engine: the vLLM-analogue decode loop with speculative decoding
and Cascade in the loop.

Per iteration (paper Fig. 14's spec-decode worker):
    1. controller.next_k() -> K            (Cascade / static policy)
    2. drafter.propose(history, K)         (n-gram or draft model)
    3. decode_step over [last_token, d_0..d_{K-1}]   (verification)
    4. rejection sample -> accepted prefix + next token
    5. rollback cache to the accepted length
    6. controller.observe(tokens, t_iter, breakdown)

Timing source is pluggable: 'wall' uses the host clock (meaningful on real
accelerators); 'model' uses the deterministic TPU-v5e data-movement cost
model driven by the *measured* unique-expert activations of this iteration
(DESIGN.md §4 — the honest CPU-container strategy)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core.controller import CascadeController, StaticKController
from repro.models import transformer as T

from .drafter import Drafter
from .sampler import greedy_verify, logits_to_probs, rejection_sample, sample_token
from .telemetry import IterationTelemetry, RequestTelemetry


@dataclass
class GenerationResult:
    tokens: List[int]
    telemetry: RequestTelemetry


class ServingEngine:
    """Single-request-at-a-time serving (the paper's single-batch,
    latency-bound setting)."""

    def __init__(self, cfg, params, drafter: Drafter, *,
                 controller_factory: Callable = None,
                 clock: str = "model",
                 hw: cm.Hardware = cm.TPU_V5E,
                 affinity: float = 0.0,
                 window: int = 0,
                 max_len: int = 2048,
                 temperature: float = 1.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.drafter = drafter
        self.controller_factory = controller_factory or (
            lambda: CascadeController())
        self.clock = clock
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.max_len = max_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)

        self._prefill = jax.jit(
            lambda p, t, c, e: T.prefill(cfg, p, t, c, window=window,
                                         enc_out=e))
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t, window=window))

    # ------------------------------------------------------------------ #

    def _iter_time(self, n_tokens: int, context_len: int,
                   unique_experts: Optional[float], wall: float) -> float:
        """Virtual (cost-model) or wall-clock verification time."""
        if self.clock == "wall":
            return wall
        r = cm.iteration_time(self.cfg, self.hw, n_tokens, context_len,
                              unique_experts=unique_experts,
                              affinity=self.affinity, window=self.window)
        return r["t_iter"]

    def _draft_time(self, k: int) -> float:
        return cm.draft_time(self.hw, k, self.drafter.active_params)

    # ------------------------------------------------------------------ #

    def generate(self, prompt: List[int], max_new: int = 128, *,
                 controller=None, request_id: str = "", task: str = "",
                 stop_token: Optional[int] = None,
                 enc_out=None) -> GenerationResult:
        cfg = self.cfg
        controller = controller or self.controller_factory()
        self.drafter.reset()
        tel = RequestTelemetry(request_id=request_id, task=task,
                               prompt_len=len(prompt))

        cache = T.init_cache(cfg, 1, self.max_len, window=self.window)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        t0 = time.perf_counter()
        logits, cache, _ = self._prefill(self.params, toks, cache, enc_out)
        logits = np.asarray(logits[0, -1], np.float32)
        tel.t_prefill = time.perf_counter() - t0

        history = list(prompt)
        # first output token comes from the prefill logits
        last_tok = self._sample(logits)
        out: List[int] = [last_tok]
        history.append(last_tok)

        it = 0
        while len(out) < max_new:
            k_req = controller.next_k()
            t0 = time.perf_counter()
            drafts, draft_probs = self.drafter.propose(history, k_req,
                                                       rng=self.rng)
            wall_draft = time.perf_counter() - t0
            k_eff = len(drafts)

            step_toks = jnp.asarray([ [last_tok] + drafts ], jnp.int32)
            len_before = int(cache["length"])
            t1 = time.perf_counter()
            lo, new_cache, aux, staged = self._decode(self.params, cache,
                                                      step_toks)
            lo = np.asarray(lo[0], np.float32)           # [K+1, V]
            wall_verify = time.perf_counter() - t1

            t2 = time.perf_counter()
            if self.temperature <= 0:
                res = greedy_verify(lo, drafts)
            else:
                probs = np.asarray(
                    logits_to_probs(jnp.asarray(lo), self.temperature))
                res = rejection_sample(self.rng, probs, drafts, draft_probs)
            wall_sample = time.perf_counter() - t2

            n_keep = 1 + res.n_accepted           # last_tok + accepted drafts
            cache = T.rollback_cache(cfg, new_cache, staged, n_keep,
                                     len_before)
            emitted = res.accepted + [res.next_token]
            out.extend(emitted)
            history.extend(emitted)
            last_tok = res.next_token

            uniq = None
            if "unique_experts" in aux and cfg.is_moe:
                uniq = float(np.mean(np.asarray(aux["unique_experts"])))
            t_verify = self._iter_time(k_eff + 1, len_before, uniq,
                                       wall_verify)
            t_draft = (wall_draft if self.clock == "wall"
                       else self._draft_time(k_eff))
            t_sample = (wall_sample if self.clock == "wall"
                        else cm.sample_time(k_eff))
            t_iter = t_draft + t_verify + t_sample

            controller.observe(len(emitted), t_iter, t_draft=t_draft,
                               t_verify=t_verify, t_sample=t_sample,
                               k=k_eff if k_req > 0 else 0)
            tel.iterations.append(IterationTelemetry(
                iteration=it, k_requested=k_req, k_drafted=k_eff,
                tokens_emitted=len(emitted), t_iter=t_iter, t_draft=t_draft,
                t_verify=t_verify, t_sample=t_sample,
                unique_experts=uniq or 0.0, context_len=len_before,
                phase=getattr(controller, "phase", ""),
                utility=controller.utility()))
            it += 1
            if stop_token is not None and res.next_token == stop_token:
                break
            if len(history) + 16 >= self.max_len:
                break
        return GenerationResult(out[:max_new], tel)

    # ------------------------------------------------------------------ #

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits))
        probs = np.asarray(logits_to_probs(jnp.asarray(logits),
                                           self.temperature))
        return sample_token(self.rng, probs)
