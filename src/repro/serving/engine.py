"""Serving engines: the vLLM-analogue decode loop with speculative decoding
and Cascade in the loop.

Two engines share the verification math:

`ServingEngine` — single-request-at-a-time (the paper's single-batch,
latency-bound setting). Per iteration (paper Fig. 14's spec-decode worker):
    1. controller.next_k() -> K            (Cascade / static policy)
    2. drafter.propose(history, K)         (n-gram or draft model)
    3. decode_step over [last_token, d_0..d_{K-1}]   (verification)
    4. rejection sample -> accepted prefix + next token
    5. rollback cache to the accepted length
    6. controller.observe(tokens, t_iter, breakdown)

`BatchedEngine` — continuous batching: a slot table of up to `max_batch`
in-flight requests, each with its own Cascade controller, drafter, and
cache row. One `step()` drafts per-request K_i, packs the ragged [1+K_i]
spans into a single padded verification pass, rejection-samples per row,
rolls every row back to its own accepted length, and attributes the shared
verification cost back to requests through the cost model's marginal-bytes
split (`cost_model.batch_iteration_time`). The batch-level cost driver is
the *union* of experts the B spans activate — the paper's Fig. 2 effect
compounding across requests.

Timing source is pluggable: 'wall' uses the host clock (meaningful on real
accelerators); 'model' uses the deterministic TPU-v5e data-movement cost
model driven by the *measured* unique-expert activations of this iteration
(DESIGN.md §4 — the honest CPU-container strategy)."""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core.controller import CascadeController, StaticKController
from repro.core.planner import BatchSpecPlanner, PlannerConfig
from repro.core.slo import RequestSLO
from repro.models import transformer as T
from repro.models.moe import packed_expert_cap

from .drafter import Drafter, NGramDrafter
from .sampler import greedy_verify, logits_to_probs, rejection_sample, sample_token
from .telemetry import (EngineTelemetry, IterationTelemetry,
                        RequestTelemetry, StepTelemetry)


@dataclass
class GenerationResult:
    tokens: List[int]
    telemetry: RequestTelemetry


def _sample_logits(rng: np.random.Generator, logits: np.ndarray,
                   temperature: float) -> int:
    """Temperature-gated sampling shared by both engines: argmax at
    temperature <= 0, softmax sample otherwise."""
    if temperature <= 0:
        return int(np.argmax(logits))
    probs = np.asarray(logits_to_probs(jnp.asarray(logits), temperature))
    return sample_token(rng, probs)


def _spec_room(controller, drafter=None) -> int:
    """Worst-case tokens one speculative iteration may append: 1 (the
    committed token) + the controller's K ceiling. This is the KV-ring
    guard's safety margin — it used to be a hardcoded 16, which overflows
    the cache for any controller with k_max > 15. Fallback chain:
    controller config k_max -> static controller k -> drafter proposal cap
    -> the legacy 15."""
    cfg = getattr(controller, "config", None)
    k_cap = getattr(cfg, "k_max", None) if cfg is not None else None
    if k_cap is None:
        k_cap = getattr(controller, "k", None)
    if k_cap is None:
        k_cap = getattr(drafter, "max_propose", None)
    if k_cap is None:
        k_cap = 15
    return 1 + int(k_cap)


def _truncate_at_stop(emitted: List[int], stop_token: Optional[int]
                      ) -> tuple:
    """Cut an iteration's emitted tokens at the first stop token
    (inclusive). A stop token accepted mid-draft must terminate the request
    — the old engines only tested the final `next_token`, silently emitting
    tokens past a stop accepted from the drafts."""
    if stop_token is None or stop_token not in emitted:
        return emitted, False
    return emitted[:emitted.index(stop_token) + 1], True


def _stacked_routers(params):
    """[L_moe, d, E] router weights, whichever way the blocks are stored
    (vmap-stacked `blocks` or per-layer `blocks_list`)."""
    if "blocks" in params:
        return params["blocks"]["moe"]["router"]
    return jnp.stack([bl["moe"]["router"] for bl in params["blocks_list"]
                      if "moe" in bl])


def _layer_hist(cfg, idx, mask):
    """[L,B,T,k] routed indices -> per-layer activation counts [L,E];
    padding routes to the sentinel bucket e and is dropped."""
    e = cfg.num_experts
    idx = jnp.where(mask[None, :, :, None], idx, e)
    hits = jax.vmap(
        lambda ix: jnp.zeros((e + 1,), jnp.int32).at[ix].add(1))(
            idx.reshape(idx.shape[0], -1))
    return hits[:, :e]


def _router_probe(cfg, params, toks, mask):
    """Predicted per-layer expert-activation counts [L,E] of a span batch
    (routed (token, layer) slots per expert — the prefetcher's nomination
    signal and confidence ordering): embed the tokens and run every MoE
    layer's router over the raw embeddings —
    the speculation-guided prefetch predictor (docs/offload.md). An
    approximation by construction (the real pass routes each layer's
    hidden state, not the embedding); prediction errors surface as demand
    misses, never as wrong tokens. Whole-expert callers sum over the
    layer axis — the same integers PR 7's flat [E] histogram counted."""
    routers = _stacked_routers(params)                    # [L, d, E]
    x = params["embed"]["embedding"][toks].astype(jnp.float32)   # [B,T,d]
    logits = jnp.einsum("btd,lde->lbte", x, routers.astype(jnp.float32))
    _, idx = jax.lax.top_k(logits, cfg.experts_per_token)  # [L,B,T,k]
    return _layer_hist(cfg, idx, mask)


def _hidden_router_probe(cfg, params, moe_h, mask):
    """Per-layer activation counts [L,E] from the PREVIOUS pass's
    per-layer MoE inputs (`decode_step(want_moe_h=True)`'s aux["moe_h"],
    [L,B,T,d]): route layer l's router over layer l's actual hidden
    states. Deeper layers' hidden states drift slowly across adjacent
    decode steps, so last pass's layer-l routing inputs predict THIS
    pass's layer-l routing far better than raw embeddings do — the
    layered prefetcher's deep-layer nomination signal, closing the
    "router probe only sees the embedding" residual (docs/offload.md)."""
    routers = _stacked_routers(params)                    # [L, d, E]
    x = moe_h.astype(jnp.float32)                         # [L,B,T,d]
    logits = jnp.einsum("lbtd,lde->lbte", x, routers.astype(jnp.float32))
    _, idx = jax.lax.top_k(logits, cfg.experts_per_token)  # [L,B,T,k]
    return _layer_hist(cfg, idx, mask)


def _prefill_clock(cfg, hw, clock: str, n_tokens: int, wall: float, *,
                   affinity: float, window: int, precision=None) -> float:
    """Prefill seconds on the engine's clock: wall seconds under
    clock="wall", cm.prefill_time under the virtual model clock (wall time
    of a jitted CPU trace must never mix into the virtual clock)."""
    if clock == "wall":
        return wall
    return cm.prefill_time(cfg, hw, n_tokens, affinity=affinity,
                           window=window, precision=precision)["t_iter"]


class ServingEngine:
    """Single-request-at-a-time serving (the paper's single-batch,
    latency-bound setting)."""

    def __init__(self, cfg, params, drafter: Drafter, *,
                 controller_factory: Callable = None,
                 clock: str = "model",
                 hw: cm.Hardware = cm.TPU_V5E,
                 affinity: float = 0.0,
                 window: int = 0,
                 max_len: int = 2048,
                 temperature: float = 1.0,
                 seed: int = 0,
                 drafter_precision: Optional[cm.Precision] = None):
        self.cfg = cfg
        self.params = params
        self.drafter = drafter
        #: bytes-per-param pricing for the drafter's weight reads (an int8
        #: drafter halves its window); None prices at bf16, bit for bit
        self.drafter_precision = drafter_precision
        self.controller_factory = controller_factory or (
            lambda: CascadeController())
        self.clock = clock
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.max_len = max_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)

        self._prefill = jax.jit(
            lambda p, t, c, e: T.prefill(cfg, p, t, c, window=window,
                                         enc_out=e))
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t, window=window))

    # ------------------------------------------------------------------ #

    def _iter_time(self, n_tokens: int, context_len: int,
                   unique_experts: Optional[float], wall: float) -> float:
        """Virtual (cost-model) or wall-clock verification time."""
        if self.clock == "wall":
            return wall
        r = cm.iteration_time(self.cfg, self.hw, n_tokens, context_len,
                              unique_experts=unique_experts,
                              affinity=self.affinity, window=self.window)
        return r["t_iter"]

    def _draft_time(self, k: int) -> float:
        return cm.draft_time(self.hw, k, self.drafter.active_params,
                             precision=self.drafter_precision)

    # ------------------------------------------------------------------ #

    def generate(self, prompt: List[int], max_new: int = 128, *,
                 controller=None, request_id: str = "", task: str = "",
                 stop_token: Optional[int] = None,
                 enc_out=None) -> GenerationResult:
        cfg = self.cfg
        if not prompt:
            raise ValueError("empty prompt — nothing to prefill")
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens cannot fit a "
                             f"max_len={self.max_len} cache")
        controller = controller or self.controller_factory()
        self.drafter.reset()
        tel = RequestTelemetry(request_id=request_id, task=task,
                               prompt_len=len(prompt))

        cache = T.init_cache(cfg, 1, self.max_len, window=self.window)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        t0 = time.perf_counter()
        logits, cache, _ = self._prefill(self.params, toks, cache, enc_out)
        logits = np.asarray(logits[0, -1], np.float32)
        wall_prefill = time.perf_counter() - t0
        tel.t_prefill = _prefill_clock(cfg, self.hw, self.clock,
                                       len(prompt), wall_prefill,
                                       affinity=self.affinity,
                                       window=self.window)
        tel.ttft = tel.t_prefill  # serial engine: no admission queue

        history = list(prompt)
        # first output token comes from the prefill logits
        last_tok = self._sample(logits)
        out: List[int] = [last_tok]
        history.append(last_tok)
        if stop_token is not None and last_tok == stop_token:
            return GenerationResult(out[:max_new], tel)

        margin = _spec_room(controller, self.drafter)
        it = 0
        while len(out) < max_new:
            if len(history) + margin > self.max_len:
                break  # next span of up to 1+k_max tokens would overflow
            k_req = controller.next_k()
            t0 = time.perf_counter()
            drafts, draft_probs = self.drafter.propose(history, k_req,
                                                       rng=self.rng)
            wall_draft = time.perf_counter() - t0
            # belt-and-braces: never let a span write past the cache even if
            # a drafter over-proposes beyond the controller's cap; windowed
            # ring caches additionally bound spans to their SPEC_PAD spill
            # slots so speculative writes cannot clobber the live window
            room = self.max_len - len(history)
            if self.window:
                room = min(room, T.SPEC_PAD - 1)
            if len(drafts) > room:
                drafts = drafts[:max(room, 0)]
                if draft_probs is not None:
                    draft_probs = draft_probs[:len(drafts)]
            k_eff = len(drafts)

            step_toks = jnp.asarray([ [last_tok] + drafts ], jnp.int32)
            len_before = int(cache["length"])
            t1 = time.perf_counter()
            lo, new_cache, aux, staged = self._decode(self.params, cache,
                                                      step_toks)
            lo = np.asarray(lo[0], np.float32)           # [K+1, V]
            wall_verify = time.perf_counter() - t1

            t2 = time.perf_counter()
            if self.temperature <= 0:
                res = greedy_verify(lo, drafts)
            else:
                probs = np.asarray(
                    logits_to_probs(jnp.asarray(lo), self.temperature))
                res = rejection_sample(self.rng, probs, drafts, draft_probs)
            wall_sample = time.perf_counter() - t2

            n_keep = 1 + res.n_accepted           # last_tok + accepted drafts
            cache = T.rollback_cache(cfg, new_cache, staged, n_keep,
                                     len_before)
            emitted, stopped = _truncate_at_stop(
                res.accepted + [res.next_token], stop_token)
            out.extend(emitted)
            history.extend(emitted)
            last_tok = emitted[-1]

            uniq = None
            if "unique_experts" in aux and cfg.is_moe:
                uniq = float(np.mean(np.asarray(aux["unique_experts"])))
            t_verify = self._iter_time(k_eff + 1, len_before, uniq,
                                       wall_verify)
            t_draft = (wall_draft if self.clock == "wall"
                       else self._draft_time(k_eff))
            t_sample = (wall_sample if self.clock == "wall"
                        else cm.sample_time(k_eff))
            t_iter = t_draft + t_verify + t_sample

            controller.observe(len(emitted), t_iter, t_draft=t_draft,
                               t_verify=t_verify, t_sample=t_sample,
                               k=k_eff if k_req > 0 else 0)
            tel.iterations.append(IterationTelemetry(
                iteration=it, k_requested=k_req, k_drafted=k_eff,
                tokens_emitted=len(emitted), t_iter=t_iter, t_draft=t_draft,
                t_verify=t_verify, t_sample=t_sample,
                unique_experts=uniq or 0.0, context_len=len_before,
                phase=getattr(controller, "phase", ""),
                utility=controller.utility(),
                t_pass=t_iter))  # single-request: the pass IS the request's
            it += 1
            if stopped:
                break
        return GenerationResult(out[:max_new], tel)

    # ------------------------------------------------------------------ #

    def _sample(self, logits: np.ndarray) -> int:
        return _sample_logits(self.rng, logits, self.temperature)


# ===================================================================== #
# Continuous batching
# ===================================================================== #

@dataclass
class _Slot:
    """One in-flight request: its own controller, drafter, rng stream,
    telemetry, and token state. The model-side state is row `index` of the
    engine's per-row batched cache. A chunk-admitted slot starts in
    phase="prefill" with its prompt pending; step() feeds it chunk by chunk
    until the prompt is consumed, samples the first output token, and flips
    it to phase="decode"."""
    index: int
    request_id: str
    task: str
    max_new: int
    stop_token: Optional[int]
    controller: object
    drafter: Drafter
    rng: np.random.Generator
    tel: RequestTelemetry
    history: List[int]
    out: List[int]
    last_tok: int
    done: bool = False
    iteration: int = 0
    phase: str = "decode"            # "prefill" -> "decode"
    prompt: Optional[List[int]] = None   # pending prompt (chunked admission)
    prefill_pos: int = 0             # prompt tokens already in the cache
    t_submit: float = 0.0            # engine-clock time of submission
    queue_seen: bool = False         # t_queue recorded yet?
    seq: int = 0                     # admission order (FIFO prefill packing)
    slo: Optional[RequestSLO] = None  # latency objective (docs/slo.md)


class BatchedEngine:
    """Continuous-batching serving engine.

    API:
        join(prompt, ...) -> slot    admit a request into a free cache row
                                     (raises when full). chunk=0: blocking
                                     prefill here; chunk>0: non-blocking —
                                     prefill runs chunked inside step()
        step() -> {slot: emitted}    one shared pass packing speculative
                                     decode spans AND pending prefill chunks
                                     (budgeted by max_prefill_tokens_per_step)
        retire(slot) -> result       collect a finished request, free the row
        generate(prompt, ...)        batch=1 compatibility wrapper: at
                                     max_batch=1, chunk=0 this reproduces the
                                     legacy `ServingEngine` token stream
                                     bit-exactly on the same seed (greedy and
                                     sampled).

    Each request keeps its own Cascade controller; the shared verification
    cost is attributed back per request via the cost model's marginal-bytes
    split, so per-request utility stays meaningful under batching. The
    engine clock `now` (virtual under clock="model") prices admission too:
    queue delay, chunked/blocking prefill, and TTFT are all on one clock
    (see docs/prefill.md).

    `policy` selects how the per-request controller asks become per-step
    draft allocations: "joint" (default) runs the `BatchSpecPlanner`'s
    marginal-utility water-filling over the shared pass (docs/planner.md);
    "independent" is the escape hatch where every grant equals its ask —
    the pre-planner engine. At B=1 the two are bit-identical.

    `placement` (an `ExpertPlacement`, docs/expert_parallel.md) models an
    EP-sharded deployment: the verification pass is priced max-over-shards
    (the hottest shard's local activated experts gate it, plus the
    all-to-all collective), the decode pass emits measured per-shard and
    per-row-per-shard activation telemetry, and the planner steers grants
    away from requests concentrating load on the gating shard via an EMA
    of each row's shard profile. `placement=None` (default) and
    n_shards=1 are the unsharded engine, bit for bit.

    `residency` (a `core.residency.ResidencyState` over a host-tiered
    placement, docs/offload.md) models an offload tier: after drafting,
    the engine routes the packed span tokens through the stacked routers
    (`prefetch=True`, the SP-MoE speculation-guided prefetch) to predict
    the verification union and fetches predicted-missing host-tier experts
    during the draft+sample window; activated host experts still missing
    at pass time are demand-fetched, the coldest residents are evicted
    LRU-by-EMA-load, and the pass is priced with the measured per-shard
    fetch counts (`per_shard_miss`) under the window's `fetch_hide`
    overlap. Under `granularity="layer"` residency units the prefetch
    stage becomes a layer pipeline (docs/offload.md, layered streaming):
    per-(layer, expert) slices stage layer by layer, deep layers nominate
    from the previous pass's per-layer hidden states, and layer l's
    fetches hide behind the draft window plus the compute of layers < l
    (double-buffered against the previous pass's tail unless
    `double_buffer=False`). An all-hbm residency (or `residency=None`)
    is the flat engine, bit for bit — token streams and per-step
    telemetry."""

    def __init__(self, cfg, params, drafter_factory: Callable = None, *,
                 max_batch: int = 8,
                 controller_factory: Callable = None,
                 clock: str = "model",
                 hw: cm.Hardware = cm.TPU_V5E,
                 affinity: float = 0.0,
                 window: int = 0,
                 max_len: int = 2048,
                 temperature: float = 1.0,
                 seed: int = 0,
                 chunk: int = 0,
                 max_prefill_tokens_per_step: Optional[int] = None,
                 policy: Optional[str] = None,
                 planner: Optional[BatchSpecPlanner] = None,
                 placement: Optional[cm.ExpertPlacement] = None,
                 packed: bool = False,
                 residency=None,
                 prefetch: bool = True,
                 precision: Optional[cm.Precision] = None,
                 drafter_precision: Optional[cm.Precision] = None,
                 double_buffer: bool = True):
        self.cfg = cfg
        self.params = params
        self.drafter_factory = drafter_factory or (lambda: NGramDrafter())
        self.controller_factory = controller_factory or (
            lambda: CascadeController())
        self.max_batch = max_batch
        self.clock = clock
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.max_len = max_len
        self.temperature = temperature
        self.seed = seed
        # chunk=0: legacy blocking prefill inside join() (bit-exact with the
        # single-request engine at max_batch=1). chunk>0: join() only
        # enqueues; step() co-schedules up to `chunk` prompt tokens per
        # request into the shared verification pass, bounded by the
        # admission budget below.
        self.chunk = int(chunk)
        if max_prefill_tokens_per_step is None:
            max_prefill_tokens_per_step = self.chunk * max_batch
        self.max_prefill_tokens_per_step = int(max_prefill_tokens_per_step)
        # a supplied planner's own config is the source of truth for the
        # policy; an explicit `policy` argument must agree with it (a
        # silently-ignored escape hatch would be worse than an error)
        if planner is not None:
            if policy is not None and policy != planner.config.policy:
                raise ValueError(
                    f"policy={policy!r} contradicts the supplied planner's "
                    f"policy={planner.config.policy!r}")
            policy = planner.config.policy
        policy = policy or "joint"
        if policy not in ("joint", "independent"):
            raise ValueError(f"unknown planner policy {policy!r} "
                             "(expected 'joint' or 'independent')")
        self.policy = policy
        if residency is not None:
            if placement is None:
                placement = residency.placement
            elif (residency.placement.shard_of != placement.shard_of
                  or residency.placement.tiers != placement.tiers):
                raise ValueError(
                    "residency tracks a different placement than the "
                    "engine serves — homes and tiers must agree")
        if placement is not None:
            if not cfg.is_moe:
                raise ValueError(
                    f"ExpertPlacement supplied for the dense (non-MoE) "
                    f"config {cfg.name!r} — there are no experts to shard, "
                    "so the run would silently measure an unsharded "
                    "deployment")
            placement.validate_experts(cfg.num_experts)
        self.placement = placement
        # like the policy check above, a supplied planner must agree with
        # the engine on the deployment it prices: the engine measures the
        # max-over-shards pass under `placement`, and a planner pricing a
        # different (or no) sharding would silently re-introduce exactly
        # the mispricing the placement exists to eliminate. The sanctioned
        # naive comparator is PlannerConfig(shard_aware=False), which
        # keeps the placement but spreads the union evenly.
        # same contract for pricing precision: a supplied planner fit to
        # bf16 bytes would mispredict every quantized step (and vice
        # versa), so the two must agree explicitly.
        if planner is not None:
            theirs = getattr(planner, "precision", None)
            if (precision or cm.Precision.DEFAULT) != \
                    (theirs or cm.Precision.DEFAULT):
                raise ValueError(
                    f"precision={precision!r} contradicts the supplied "
                    f"planner's precision={theirs!r}")
        #: bytes-per-param pricing the cost oracle and planner share;
        #: None prices identically to Precision.DEFAULT (bf16)
        self.precision = precision
        # the drafter's weight pricing must agree the same way: the draft
        # window is the fetch scheduler's hide budget, and a planner
        # pricing a bf16 drafter against an int8-drafted engine would
        # mispredict every fetch deadline
        if planner is not None:
            theirs = getattr(planner, "drafter_precision", None)
            if (drafter_precision or cm.Precision.DEFAULT) != \
                    (theirs or cm.Precision.DEFAULT):
                raise ValueError(
                    f"drafter_precision={drafter_precision!r} contradicts "
                    f"the supplied planner's "
                    f"drafter_precision={theirs!r}")
        #: bytes-per-param pricing for drafter weight reads (an int8
        #: drafter halves the draft window fetches hide behind); None
        #: prices at bf16, bit for bit
        self.drafter_precision = drafter_precision
        if planner is not None and cfg.is_moe:
            pp = getattr(planner, "placement", None)
            ours = self.placement.shard_of if self.placement else None
            theirs = pp.shard_of if pp is not None else None
            if ours != theirs:
                raise ValueError(
                    f"engine placement {ours} contradicts the supplied "
                    f"planner's placement {theirs}")
            if getattr(planner, "residency", None) is not None \
                    and planner.residency is not residency:
                raise ValueError(
                    "the supplied planner tracks a different residency "
                    "state than the engine mutates — they must share one "
                    "ResidencyState object")
        #: measured shard accounting is live only when >1 shard exists —
        #: a 1-shard placement must be indistinguishable from None
        self._ep = (self.placement is not None
                    and self.placement.n_shards > 1)
        #: per-row EMA of measured per-shard activation profiles, the
        #: planner's steering signal (slot -> [S] weights)
        self._shard_profiles: dict = {}
        self.planner = planner or BatchSpecPlanner(
            cfg, hw, affinity=affinity, window=window,
            config=PlannerConfig(policy=policy), placement=self.placement,
            residency=residency, precision=precision,
            drafter_precision=drafter_precision)
        #: offload tier: live only when the placement actually has
        #: host-tier experts — an all-hbm residency must be invisible
        self.residency = residency
        self.prefetch = bool(prefetch)
        #: minimum predicted (token, layer) routing slots before an
        #: expert is staged. Staging means a misprediction costs only
        #: its (hidden) link bytes — never the cache trajectory — so the
        #: default keeps every nomination; raise it on workloads where
        #: the probe's single-slot predictions are noise, trading
        #: hit-rate for link traffic.
        self.prefetch_min_count = 1
        self._offload = residency is not None and residency.has_host_tier
        #: layered streaming (docs/offload.md): per-(layer, expert)
        #: residency units turn the prefetch stage into a layer pipeline —
        #: layer l's staged fetches hide behind the draft window PLUS the
        #:  compute of layers < l in the current pass
        self._layered = (self._offload
                         and residency.granularity == "layer")
        #: double-buffer the layered pipeline against the previous pass:
        #: fetches issued at step start also overlap the tail of the
        #: previous pass that runs after its LAST MoE layer consumed
        #: weights (False pins the window to this step's own work — the
        #: whole-expert engine's contract, which the degradation tests
        #: compare against)
        self.double_buffer = bool(double_buffer)
        #: engine clock: virtual seconds under clock="model" (cost-model
        #: priced steps + blocking prefills), wall seconds under "wall".
        #: Queue-delay and TTFT telemetry are measured on this clock.
        self.now = 0.0

        self.slots: List[Optional[_Slot]] = [None] * max_batch
        self.cache = T.init_cache(cfg, max_batch, max_len, window=window,
                                  per_row=True)
        self.telemetry = EngineTelemetry()
        self._prefill = jax.jit(
            lambda p, t, c, e: T.prefill(cfg, p, t, c, window=window,
                                         enc_out=e))
        #: union-packed verification path (models/moe.apply_moe(packed=
        #: True)): bit-identical outputs, union-scaled weight traffic
        self.packed = bool(packed)
        #: online replica routing: with replicated experts the engine
        #: re-routes each replicated expert to its currently-cheapest
        #: replica (the serving-side realisation of the min-over-replicas
        #: relief `cost_model._rebalance_replicas` already prices), so the
        #: shard map becomes a traced argument instead of a static closure
        #: constant — re-routing must not retrace the decode step.
        self._replica_routes = None
        self._shard_load = None   # EMA of measured per-shard activation
        self.replica_moves = 0    # route flips across the run
        # the layered prefetcher probes NEXT pass's deep-layer routing
        # from THIS pass's per-layer MoE inputs, so the decode step must
        # return them (want_moe_h; a flat engine pays nothing for it)
        want_h = self._layered and self.prefetch
        if self._ep and self.placement.has_replication:
            self._replica_routes = np.asarray(
                self.placement.primary_shard_of, np.int32)
            n_sh = self.placement.n_shards
            self._decode = jax.jit(
                lambda p, c, t, m, sid: T.decode_step(
                    cfg, p, c, t, window=window, token_mask=m,
                    ep_shard_ids=sid, ep_n_shards=n_sh,
                    moe_packed=self.packed, want_moe_h=want_h))
        else:
            # unreplicated routing uses the static primary homes
            sid = (tuple(self.placement.primary_shard_of)
                   if self._ep else None)
            self._decode = jax.jit(
                lambda p, c, t, m: T.decode_step(cfg, p, c, t, window=window,
                                                 token_mask=m,
                                                 ep_shard_ids=sid,
                                                 moe_packed=self.packed,
                                                 want_moe_h=want_h))
        #: speculation-guided prefetch probe (docs/offload.md): embed the
        #: packed span tokens and apply every MoE layer's router to them —
        #: a one-einsum approximation of the verification pass's routing
        #: (SP-MoE style: the drafted lookahead IS the prediction window).
        #: Top-k indices are what the cache needs; they are invariant to
        #: the router's sigmoid/softmax squashing, so raw logits suffice.
        self._probe = None
        self._hprobe = None
        if self._offload and self.prefetch:
            self._probe = jax.jit(
                lambda p, t, m: _router_probe(cfg, p, t, m))
            if self._layered:
                self._hprobe = jax.jit(
                    lambda p, h, m: _hidden_router_probe(cfg, p, h, m))
        #: the previous pass's per-layer MoE inputs + token mask — the
        #: layered prefetcher's deep-layer probe basis (None before the
        #: first decode pass: the embedding probe covers every layer)
        self._last_moe_h = None
        self._last_mask = None
        #: per-MoE-layer hide-window fractions (cost_model.moe_hide_fracs;
        #: fracs[0] is PR 7's pre-MoE fraction): the fraction of a pass
        #: that runs before MoE layer l consumes expert weights — prefetch
        #: DMA issued at step start overlaps embed + leading dense layers
        #: + layer l's own attention block (the +0.5: expert weights are
        #: read by the FFN sub-layer, roughly half a layer after its
        #: attention starts) in addition to the draft/sample window.
        #: Demand misses, discovered at routing time inside the pass, get
        #: neither credit.
        self._hide_fracs = cm.moe_hide_fracs(cfg)
        self._pre_moe_frac = (self._hide_fracs[0]
                              if self._hide_fracs else 0.0)
        self._last_t_iter = 0.0
        self._step_idx = 0
        self._req_counter = 0
        self._joined_since_step = 0

    # -- admission ------------------------------------------------------ #

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.done]

    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def predicted_service_time(self, prompt_len: int) -> float:
        """Predicted seconds from joining NOW to this prompt's first
        output token, on the model clock — the admission-side counterpart
        of the planner's pass predictions, and what
        `PredictiveTTFTAdmission` adds to a queued request's accrued delay
        to decide whether its TTFT bound is already doomed
        (docs/serving_load.md). Blocking admission (chunk=0) is one full
        prefill pass. Chunked admission prices one decode-shaped shared
        pass carrying a `chunk`-token prefill row alongside the CURRENT
        batch state (1 committed token per live decode row — the
        conservative no-speculation floor) via `BatchCostOracle`, then
        charges one such pass per chunk of this prompt — or more, when
        the prefill backlog already queued ahead of it exceeds the
        admission budget. A pure prediction: reads engine state, mutates
        nothing."""
        n = max(int(prompt_len), 1)
        if self.chunk <= 0:
            return cm.prefill_time(self.cfg, self.hw, n,
                                   affinity=self.affinity,
                                   window=self.window,
                                   precision=self.precision)["t_iter"]
        lens = [int(x) for x in np.asarray(self.cache["lengths"])]
        chunk = min(self.chunk, n)
        oracle = cm.BatchCostOracle(
            self.cfg, self.hw, lens + [0], affinity=self.affinity,
            window=self.window,
            prefill_tokens=[0] * len(lens) + [chunk],
            placement=self.placement,
            calibration=getattr(self.planner, "calibration", None),
            residency=self.residency, precision=self.precision)
        ns = [0] * (len(lens) + 1)
        backlog = 0
        for i in self.active_slots:
            s = self.slots[i]
            if s.phase == "prefill":
                backlog += max(len(s.prompt) - s.prefill_pos, 0)
            else:
                ns[i] = 1
        ns[-1] = chunk
        t_pass = oracle.t_batch(ns)
        budget = max(self.max_prefill_tokens_per_step, chunk)
        n_passes = max(-(-n // chunk), -(-(backlog + n) // budget))
        return n_passes * t_pass

    def join(self, prompt: List[int], max_new: int = 128, *,
             controller=None, request_id: str = "", task: str = "",
             stop_token: Optional[int] = None, enc_out=None,
             submit_time: Optional[float] = None,
             slo: Optional[RequestSLO] = None) -> int:
        """Admit `prompt` into a free cache row; returns the slot index.

        chunk=0: blocking — runs the full prefill here, stalling every
        in-flight decode for its duration (the legacy path).
        chunk>0: non-blocking — only enqueues the prompt; step() feeds it
        into the shared pass chunk by chunk under the admission budget.
        Encoder-decoder requests (enc_out) fall back to the blocking path:
        their cross-attention KV is only populated by a prefill-mode pass,
        which the chunked decode-shaped pass cannot do.
        `submit_time` (engine-clock seconds, e.g. recorded by a scheduler at
        enqueue) anchors the request's queue-delay/TTFT telemetry; default
        is "submitted now".
        `slo` (a `core.RequestSLO`, docs/slo.md) rides on the slot into the
        planner: its TPOT bound constrains the joint allocation (grants to
        ANY co-scheduled row that would push this request past its bound
        are denied) and is handed to the request's own Cascade config so
        the per-request trial gate enforces the same bound."""
        if not prompt:
            raise ValueError("empty prompt — nothing to prefill")
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens cannot fit a "
                             f"max_len={self.max_len} cache row")
        free = self.free_slots
        if not free:
            raise RuntimeError("no free slot — retire a request first")
        idx = free[0]
        self._shard_profiles.pop(idx, None)  # fresh row, fresh profile
        controller = controller or self.controller_factory()
        if slo is not None and slo.tpot is not None:
            # the per-request FSM shares the bound: its measured trial
            # gate (manager._slo_allows) and the planner's predicted grant
            # constraint then enforce the SAME objective at both levels.
            # An explicit CascadeConfig.slo_tpot wins over the request's,
            # and the caller's config object is never mutated (a factory
            # may hand the same tuned config to every controller —
            # install the bound on a per-request replacement instead).
            ccfg = getattr(controller, "config", None)
            if (dataclasses.is_dataclass(ccfg)
                    and getattr(ccfg, "slo_tpot", 0) is None):
                bound_cfg = dataclasses.replace(ccfg, slo_tpot=slo.tpot)
                controller.config = bound_cfg
                mgr = getattr(controller, "manager", None)
                if mgr is not None and getattr(mgr, "cfg", None) is ccfg:
                    mgr.cfg = bound_cfg
        drafter = self.drafter_factory()
        drafter.reset()
        # the first request consumes exactly the legacy engine's rng stream
        # (bit-identical batch=1 behaviour); later requests get their own
        n = self._req_counter
        rng = (np.random.default_rng(self.seed) if n == 0
               else np.random.default_rng([self.seed, n]))
        self._req_counter += 1

        t_submit = self.now if submit_time is None else float(submit_time)
        tel = RequestTelemetry(request_id=request_id, task=task,
                               prompt_len=len(prompt))
        if slo is not None:
            tel.tier = slo.tier
            tel.slo_tpot = slo.tpot
            tel.slo_ttft = slo.ttft

        if self.chunk > 0 and enc_out is None:
            # non-blocking admission: no forward pass here; the row's cache
            # is empty (lengths[idx] == 0) and fills chunk by chunk
            self.slots[idx] = _Slot(
                index=idx, request_id=request_id, task=task,
                max_new=max_new, stop_token=stop_token,
                controller=controller, drafter=drafter, rng=rng, tel=tel,
                history=list(prompt), out=[], last_tok=-1,
                phase="prefill", prompt=list(prompt),
                t_submit=t_submit, seq=n, slo=slo)
            self._joined_since_step += 1
            return idx

        row = T.init_cache(self.cfg, 1, self.max_len, window=self.window)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        t0 = time.perf_counter()
        logits, row, _ = self._prefill(self.params, toks, row, enc_out)
        logits = np.asarray(logits[0, -1], np.float32)
        wall_prefill = time.perf_counter() - t0
        tel.t_prefill = _prefill_clock(self.cfg, self.hw, self.clock,
                                       len(prompt), wall_prefill,
                                       affinity=self.affinity,
                                       window=self.window,
                                       precision=self.precision)
        tel.t_queue = max(self.now - t_submit, 0.0)
        tel.ttft = tel.t_queue + tel.t_prefill
        self.now += tel.t_prefill  # blocking: everyone waits out the prefill
        self.cache = T.write_cache_row(self.cache, idx, row)

        first = _sample_logits(rng, logits, self.temperature)
        slot = _Slot(
            index=idx, request_id=request_id, task=task, max_new=max_new,
            stop_token=stop_token, controller=controller, drafter=drafter,
            rng=rng, tel=tel, history=list(prompt) + [first], out=[first],
            last_tok=first, t_submit=t_submit, seq=n, slo=slo)
        self._maybe_finish(slot,
                           stopped=stop_token is not None
                           and first == stop_token)
        self.slots[idx] = slot
        self._joined_since_step += 1
        return idx

    def _attr_share(self, cost: dict, i: int, wall_verify: float,
                    occupancy: int) -> float:
        """Request i's attributed share of the shared pass, on the engine's
        clock: marginal-bytes fraction of the wall time under clock="wall",
        the cost model's t_attr under the virtual clock. One rule for both
        the decode feedback and the chunked-prefill TTFT clock."""
        attr = cost["per_request"][i]
        if self.clock != "wall":
            return attr["t_attr"]
        frac = (attr["bytes_attr"] / cost["bytes"]
                if cost["bytes"] else 1.0 / occupancy)
        return wall_verify * frac

    def _update_replica_routes(self, shard_load) -> int:
        """Fold a pass's measured per-shard activation [S] into the EMA and
        point every replicated expert at its currently-coolest replica
        (ties break toward the lower shard id, so routing is deterministic
        and a balanced load keeps the primary homes). Returns the number of
        experts whose route flipped — the next pass runs on the new map."""
        old = self._shard_load
        self._shard_load = (np.asarray(shard_load, np.float64) if old is None
                            else 0.5 * old + 0.5 * shard_load)
        moves = 0
        for e, reps in enumerate(self.placement.shard_of):
            if not isinstance(reps, tuple):
                continue
            best = min(reps, key=lambda s: (self._shard_load[s], s))
            if best != self._replica_routes[e]:
                self._replica_routes[e] = best
                moves += 1
        self.replica_moves += moves
        return moves

    def _maybe_finish(self, s: _Slot, *, stopped: bool = False) -> None:
        """The one termination rule, shared by every path that advances a
        request (blocking join, decode feedback, chunked-prefill finish):
        output budget reached, stop token emitted, or no worst-case
        speculative span left before the cache end."""
        if len(s.out) >= s.max_new:
            s.done = True
        if stopped:
            s.done = True
        if len(s.history) + _spec_room(s.controller, s.drafter) \
                > self.max_len:
            s.done = True

    def retire(self, idx: int) -> GenerationResult:
        """Free the slot and return the finished request's result."""
        s = self.slots[idx] if 0 <= idx < self.max_batch else None
        if s is None:
            raise KeyError(f"slot {idx} is empty (table size "
                           f"{self.max_batch})")
        self.cache = T.clear_cache_row(self.cache, idx)
        self.slots[idx] = None
        self._shard_profiles.pop(idx, None)
        return GenerationResult(s.out[:s.max_new], s.tel)

    # -- the shared iteration ------------------------------------------- #

    def step(self) -> dict:
        """One continuous-batching iteration over every live request:
        per-request drafting, one padded shared pass over speculative decode
        spans AND co-scheduled prefill chunks, per-row rejection sampling
        and rollback, marginal cost attribution. Prefill tokens count toward
        the expert union, so admission pressure raises verification cost for
        every request sharing the pass — the paper's Fig. 2 effect now
        includes admission. Returns {slot: emitted tokens}; empty when
        nothing is live."""
        active = self.active_slots
        if not active:
            return {}
        b = self.max_batch
        slots = self.slots
        lengths_before = np.asarray(self.cache["lengths"])
        decode_rows = [i for i in active if slots[i].phase == "decode"]
        prefill_rows = sorted(
            (i for i in active if slots[i].phase == "prefill"),
            key=lambda i: slots[i].seq)

        # EVERY non-done row of the padded pass gets T_max ring-slot writes
        # starting at its own length (padding writes are rolled back, but
        # they land first) — including rows whose prefill was NOT admitted
        # this step. Cap this step's span lengths so no such row's padded
        # writes can wrap past its cache end, and so a windowed ring's
        # contiguous write stays inside its SPEC_PAD spill slots. Under
        # chunked admission the cap is floored to a power of two, keeping
        # the bucketed [B, T] trace shapes a small fixed set even when a
        # long-running row squeezes the room step by step.
        room_min = min(self.max_len - int(lengths_before[i])
                       for i in active)
        if self.window:
            room_min = min(room_min, T.SPEC_PAD)
        if self.chunk > 0 and room_min > 0:
            room_min = 1 << (room_min.bit_length() - 1)

        # 0. admission policy: pack pending prefill chunks FIFO under the
        # per-step token budget. The head-of-queue chunk always runs (no
        # starvation under a tiny budget); later chunks wait their turn.
        # The capacity cap applies before the budget debit, so a capped
        # head chunk does not eat budget it cannot use.
        chunk_plan: dict = {}
        budget = self.max_prefill_tokens_per_step
        for i in prefill_rows:
            s = slots[i]
            n = min(self.chunk, len(s.prompt) - s.prefill_pos, room_min)
            if n <= 0:
                continue
            if chunk_plan and n > budget:
                break
            chunk_plan[i] = n
            budget -= n
            if not s.queue_seen:
                s.tel.t_queue = max(self.now - s.t_submit, 0.0)
                s.queue_seen = True
        if not decode_rows and not chunk_plan:
            return {}

        # 1. joint speculation planning + per-request drafting: each
        # request's controller asks (the Cascade FSM still explores and
        # disables per request), the planner grants {K_i} jointly — greedy
        # marginal-utility water-filling over the shared pass, with TEST
        # phases staggered to one trial per step (docs/planner.md). Under
        # policy="independent", and always at B=1, grants == asks exactly.
        plan = self.planner.plan(
            {i: slots[i].controller for i in decode_rows},
            [int(n) for n in lengths_before],
            prefill_tokens=chunk_plan,
            shard_weights=({i: self._shard_profiles[i] for i in decode_rows
                            if i in self._shard_profiles}
                           if self._ep else None),
            slos={i: slots[i].slo for i in decode_rows
                  if slots[i].slo is not None})
        k_req, drafts, draft_probs, wall_draft = {}, {}, {}, {}
        for i in decode_rows:
            s = slots[i]
            k_req[i] = plan.decisions[i].requested
            t0 = time.perf_counter()
            drafts[i], draft_probs[i] = s.drafter.propose(
                s.history, plan.decisions[i].granted, rng=s.rng)
            wall_draft[i] = time.perf_counter() - t0
            if len(drafts[i]) > room_min - 1:  # span = 1 + drafts
                drafts[i] = drafts[i][:max(room_min - 1, 0)]
                if draft_probs[i] is not None:
                    draft_probs[i] = draft_probs[i][:len(drafts[i])]

        # 2. pack ragged [1 + K_i] decode spans and prefill chunks into one
        # padded batch; bucket T to a power of two under chunked admission
        # so jit traces are reused across prompt/chunk lengths
        spans = {i: [slots[i].last_tok] + drafts[i] for i in decode_rows}
        for i, n in chunk_plan.items():
            s = slots[i]
            spans[i] = s.prompt[s.prefill_pos:s.prefill_pos + n]
        t_max = max(len(sp) for sp in spans.values())
        if self.chunk > 0:
            t_max = min(T.bucket_length(t_max), room_min)
        toks = np.zeros((b, t_max), np.int32)
        mask = np.zeros((b, t_max), bool)
        for i, span in spans.items():
            toks[i, :len(span)] = span
            mask[i, :len(span)] = True

        # 2b. speculation-guided prefetch (docs/offload.md): this step's
        # spans are a window into the verification union — route them
        # through the routers NOW and stream predicted host-tier experts
        # into the residency staging buffer while drafting/sampling and
        # the pre-MoE dense compute run, so the fetch hides behind work
        # the pass performs anyway (`fetch_hide` prices exactly that
        # window). Every span row nominates — the spans ARE this pass's
        # routing inputs, so any predicted-but-absent expert is a demand
        # miss about to happen — and staging (vs installing) keeps
        # mispredictions out of the eviction path: an unused staged
        # expert is discarded at pass end, so the cache trajectory
        # matches the prefetch-off run except for the conversions
        # (residency.fetch(stage=True) docstring)
        prefetch_counts = None        # [S] whole-expert staged counts
        staged_counts = None          # [S][L] per-layer staged counts
        fetch_hide = 0.0              # scalar window, or [L] schedule
        if self._offload:
            base_hide = 0.0
            if self.prefetch:
                # the model-clock draft+sample window of this step — what
                # a prefetched byte can hide behind (same expressions as
                # stage 7's t_overhead, known here because K_i are fixed)
                base_hide = max(
                    (cm.draft_time(self.hw, len(drafts[i]),
                                   slots[i].drafter.active_params,
                                   precision=self.drafter_precision)
                     + cm.sample_time(len(drafts[i]))
                     for i in decode_rows), default=0.0)
            if self._layered:
                # layered streaming: layer l's staged fetches additionally
                # hide behind the compute of layers < l in THIS pass (the
                # planner's predicted base pass is the compute estimate —
                # priced for the current batch composition, so membership
                # churn reprices the window the same step it happens)...
                if self.prefetch and self.double_buffer:
                    # ...and, double-buffered, behind the tail of the
                    # PREVIOUS pass that ran after its last MoE layer
                    # consumed weights — the link was idle there
                    base_hide += (1.0 - self._hide_fracs[-1]) \
                        * self._last_t_iter
                fetch_hide = cm.fetch_hide_schedule(self.cfg, base_hide,
                                                    plan.t_base)
                n_l = self.residency.n_unit_layers
                staged_counts = [[0] * n_l
                                 for _ in range(self.residency.n_shards)]
                if self._probe is not None:
                    pred = np.asarray(self._probe(self.params,
                                                  jnp.asarray(toks),
                                                  jnp.asarray(mask)))
                    if self._last_moe_h is not None:
                        # deep layers nominate from the PREVIOUS pass's
                        # per-layer hidden states — layer l's router over
                        # layer l's actual inputs, not the embedding
                        # (layer 0 keeps the current spans' embed probe:
                        # its routing input IS close to the embedding)
                        hp = np.asarray(self._hprobe(self.params,
                                                     self._last_moe_h,
                                                     self._last_mask))
                        pred = np.concatenate([pred[:1], hp[1:]], axis=0)
                    # nominate layer-by-layer in pipeline order —
                    # most-confident first within a layer, exactly the
                    # order the link drains and the cumulative staged
                    # cap credits (fetch_time_layered)
                    for lyr in range(n_l):
                        row = pred[lyr]
                        nominated = sorted(
                            ((lyr, int(e)) for e in np.nonzero(row)[0]
                             if row[e] >= self.prefetch_min_count),
                            key=lambda u: (-int(row[u[1]]), u[1]))
                        pf = self.residency.fetch(nominated,
                                                  self._step_idx,
                                                  stage=True)
                        for s_i, c in enumerate(pf["per_shard"]):
                            staged_counts[s_i][lyr] = c
            else:
                fetch_hide = base_hide
                if self.prefetch:
                    # ... plus the dense compute ahead of the first MoE
                    # layer: the DMA issued now keeps streaming while
                    # embed + leading layers run, and the weights are
                    # only needed when that layer routes (the planner's
                    # predicted base pass for THIS batch composition is
                    # the compute estimate — the previous pass's t_iter
                    # overstates the window right after rows retire)
                    fetch_hide += self._pre_moe_frac * plan.t_base
                if self._probe is not None:
                    pred = np.asarray(self._probe(self.params,
                                                  jnp.asarray(toks),
                                                  jnp.asarray(mask))
                                      ).sum(axis=0)        # [L,E] -> [E]
                    # most-confident first: experts routed by more
                    # predicted (token, layer) slots stage before marginal
                    # ones (the ordering the min-count filter and hide
                    # window reward)
                    nominated = sorted(
                        (int(e) for e in np.nonzero(pred)[0]
                         if pred[e] >= self.prefetch_min_count),
                        key=lambda e: (-int(pred[e]), e))
                    pf = self.residency.fetch(nominated, self._step_idx,
                                              stage=True)
                    prefetch_counts = pf["per_shard"]
                    # honest hide: the draft+sample window only hides
                    # bytes that were actually prefetched during it —
                    # demand misses are discovered at pass time and can
                    # never hide, so cap the credit at the prefetched
                    # fetch time (the layered path applies the same cap
                    # per layer inside fetch_time_layered, from
                    # staged_counts)
                    fetch_hide = min(
                        fetch_hide,
                        max(prefetch_counts) * self.residency.expert_bytes
                        / self.hw.host_bw)

        # 3. shared verification pass
        t1 = time.perf_counter()
        if self._replica_routes is not None:
            lo, new_cache, aux, staged = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(mask), jnp.asarray(self._replica_routes))
        else:
            lo, new_cache, aux, staged = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(mask))
        lo = np.asarray(lo, np.float32)            # [B, T_max, V]
        wall_verify = time.perf_counter() - t1
        if self._hprobe is not None and "moe_h" in aux:
            # keep this pass's per-layer MoE inputs (+ their mask) as the
            # NEXT step's deep-layer nomination basis
            self._last_moe_h = aux["moe_h"]        # [L, B, T, d] (device)
            self._last_mask = jnp.asarray(mask)

        # 4. per-row rejection sampling (decode rows only — prefill chunks
        # commit all their real tokens, nothing to verify)
        results, wall_sample = {}, {}
        for i in decode_rows:
            s = slots[i]
            n_i = 1 + len(drafts[i])
            t2 = time.perf_counter()
            if self.temperature <= 0:
                results[i] = greedy_verify(lo[i, :n_i], drafts[i])
            else:
                probs = np.asarray(logits_to_probs(
                    jnp.asarray(lo[i, :n_i]), self.temperature))
                results[i] = rejection_sample(s.rng, probs, drafts[i],
                                              draft_probs[i])
            wall_sample[i] = time.perf_counter() - t2

        # 5. vectorized per-row rollback (idle rows keep length unchanged;
        # prefill rows keep their whole real chunk, dropping the padding)
        n_keep = np.zeros((b,), np.int32)
        for i in decode_rows:
            n_keep[i] = 1 + results[i].n_accepted
        for i, n in chunk_plan.items():
            n_keep[i] = n
        self.cache = T.rollback_cache(self.cfg, new_cache, staged,
                                      jnp.asarray(n_keep),
                                      jnp.asarray(lengths_before))

        # 6. batch-aware cost accounting + marginal attribution
        union = per_row = shard_mean = row_shard = None
        if self.cfg.is_moe and "unique_experts" in aux:
            # mean over *layers* of the masked per-layer union [L]. (The EP
            # apply path used to land its per-source-shard counts on this
            # key, and a bare np.mean folded them into a scalar that was
            # neither the union nor the gating shard; the union is now
            # recomputed from the gathered expert ids upstream, and the
            # per-shard view arrives separately below.)
            union = float(np.mean(np.asarray(aux["unique_experts"])))
        if self.cfg.is_moe and "unique_experts_row" in aux:
            per_row = np.mean(np.asarray(aux["unique_experts_row"],
                                         np.float64), axis=0)   # [B]
        if self._ep and "unique_experts_shard" in aux:
            shard_mean = np.mean(np.asarray(aux["unique_experts_shard"],
                                            np.float64), axis=0)   # [S]
            row_shard = np.mean(np.asarray(aux["unique_experts_row_shard"],
                                           np.float64), axis=0)    # [B,S]
        # residency bookkeeping: classify the pass's ACTUAL activated
        # host-tier experts into prefetch hits and demand misses, fetch
        # the misses (discovered too late to hide), evict-and-admit, and
        # price the pass with the measured per-shard fetch counts
        per_shard_miss = None
        n_hits = n_miss = step_evictions = 0
        step_fetch_bytes = 0.0
        hit_by_layer = miss_by_layer = ()
        if self._offload:
            ev0 = self.residency.evictions
            if self._layered:
                # per-(layer, expert) units: each MoE layer's activated
                # slices classify and demand-fetch independently, in
                # pipeline (layer) order — the measured [S][L] counts the
                # layered pricing consumes
                n_l = self.residency.n_unit_layers
                units = []
                if "experts_active" in aux:
                    act = np.asarray(aux["experts_active"])  # [L, E]
                    units = [(int(l), int(e))
                             for l, e in zip(*np.nonzero(act))]
                hit, missing = self.residency.access(units, self._step_idx)
                sc = staged_counts or [[0] * n_l
                                       for _ in range(
                                           self.residency.n_shards)]
                per_shard_miss = [list(r) for r in sc]
                for lyr in range(n_l):
                    df = self.residency.fetch(
                        [u for u in missing if u[0] == lyr],
                        self._step_idx)
                    for s_i, c in enumerate(df["per_shard"]):
                        per_shard_miss[s_i][lyr] += c
                self.residency.note_step(units, self._step_idx)
                n_hits, n_miss = len(hit), len(missing)
                hit_by_layer = tuple(
                    sum(1 for u in hit if u[0] == lyr)
                    for lyr in range(n_l))
                miss_by_layer = tuple(
                    sum(1 for u in missing if u[0] == lyr)
                    for lyr in range(n_l))
                step_fetch_bytes = sum(
                    sum(r) for r in per_shard_miss) * \
                    self.residency.expert_bytes
            else:
                active_ids = []
                if "experts_active" in aux:
                    act = np.asarray(aux["experts_active"])      # [L, E]
                    active_ids = np.nonzero(act.any(axis=0))[0]
                hit, missing = self.residency.access(active_ids,
                                                     self._step_idx)
                df = self.residency.fetch(missing, self._step_idx)
                pc = prefetch_counts or [0] * self.residency.n_shards
                per_shard_miss = [p + d
                                  for p, d in zip(pc, df["per_shard"])]
                self.residency.note_step(active_ids, self._step_idx)
                n_hits, n_miss = len(hit), len(missing)
                step_fetch_bytes = sum(per_shard_miss) * \
                    self.residency.expert_bytes
            step_evictions = self.residency.evictions - ev0
        tokens_per_row = [int(mask[i].sum()) for i in range(b)]
        cost = cm.batch_iteration_time(
            self.cfg, self.hw, tokens_per_row, list(lengths_before),
            unique_experts=union,
            per_request_unique=(None if per_row is None else
                                [per_row[i] if i in spans else 0.0
                                 for i in range(b)]),
            affinity=self.affinity, window=self.window,
            prefill_tokens=[chunk_plan.get(i, 0) for i in range(b)],
            placement=self.placement,
            per_shard_unique=(None if shard_mean is None
                              else list(shard_mean)),
            residency=self.residency, per_shard_miss=per_shard_miss,
            fetch_hide=fetch_hide, staged_per_shard=staged_counts,
            precision=self.precision)
        self._last_t_iter = float(cost["t_iter"])
        t_verify_shared = (wall_verify if self.clock == "wall"
                           else cost["t_iter"])

        # EP steering signal: fold this pass's measured per-row shard
        # profile into the EMA the next plan() steers with
        if row_shard is not None:
            for i in spans:
                prof = row_shard[i]
                tot = float(prof.sum())
                if tot <= 0:
                    continue
                prof = prof / tot
                old = self._shard_profiles.get(i)
                self._shard_profiles[i] = (prof if old is None
                                           else 0.5 * old + 0.5 * prof)
        # online replica routing: fold this pass's measured per-shard
        # activation into an EMA and re-point each replicated expert at its
        # currently-coolest replica for the NEXT pass (the serving-side
        # half of the min-over-replicas relief the oracle prices)
        step_moves = 0
        if self._replica_routes is not None and shard_mean is not None:
            step_moves = self._update_replica_routes(np.asarray(shard_mean))

        # 7. feed back per request; advance token state
        emitted_by_slot = {}
        step_iter_tel = {}   # this step's records, for the t_pass backfill
        occupancy = len(spans)
        n_tokens = sum(tokens_per_row)
        padded = occupancy * t_max - n_tokens
        t_overhead = 0.0
        for i in decode_rows:
            s = slots[i]
            res = results[i]
            k_eff = len(drafts[i])
            emitted, stopped = _truncate_at_stop(
                res.accepted + [res.next_token], s.stop_token)
            s.out.extend(emitted)
            s.history.extend(emitted)
            s.last_tok = emitted[-1]

            t_verify = self._attr_share(cost, i, wall_verify, occupancy)
            t_draft = (wall_draft[i] if self.clock == "wall"
                       else cm.draft_time(self.hw, k_eff,
                                          s.drafter.active_params,
                                          precision=self.drafter_precision))
            t_sample = (wall_sample[i] if self.clock == "wall"
                        else cm.sample_time(k_eff))
            t_iter = t_draft + t_verify + t_sample
            t_overhead = max(t_overhead, t_draft + t_sample)

            s.controller.observe(len(emitted), t_iter, t_draft=t_draft,
                                 t_verify=t_verify, t_sample=t_sample,
                                 k=k_eff if k_req[i] > 0 else 0,
                                 batch=occupancy)
            step_iter_tel[i] = IterationTelemetry(
                iteration=s.iteration, k_requested=k_req[i],
                k_drafted=k_eff, tokens_emitted=len(emitted),
                t_iter=t_iter, t_draft=t_draft, t_verify=t_verify,
                t_sample=t_sample,
                unique_experts=(float(per_row[i]) if per_row is not None
                                else 0.0),
                context_len=int(lengths_before[i]),
                phase=getattr(s.controller, "phase", ""),
                utility=s.controller.utility(),
                batch_occupancy=occupancy,
                union_experts=union or 0.0,
                padding_frac=padded / (n_tokens + padded) if n_tokens else 0.0,
                k_granted=plan.decisions[i].granted,
                plan_held=plan.decisions[i].held,
                slo_capped=plan.decisions[i].slo_capped)
            s.tel.iterations.append(step_iter_tel[i])
            s.iteration += 1
            emitted_by_slot[i] = emitted
            self._maybe_finish(s, stopped=stopped)

        # 8. prefill bookkeeping: attribute this chunk's share of the pass
        # to the request's TTFT clock; on the final chunk, sample the first
        # output token and flip the slot to decode
        finished_prefill = []
        for i, n in chunk_plan.items():
            s = slots[i]
            s.tel.t_prefill += self._attr_share(cost, i, wall_verify,
                                                occupancy)
            s.tel.prefill_chunks += 1
            s.prefill_pos += n
            if s.prefill_pos >= len(s.prompt):
                first = _sample_logits(s.rng, lo[i, n - 1],
                                       self.temperature)
                s.history.append(first)
                s.out = [first]
                s.last_tok = first
                s.phase = "decode"
                finished_prefill.append(i)
                emitted_by_slot[i] = [first]
                self._maybe_finish(s,
                                   stopped=s.stop_token is not None
                                   and first == s.stop_token)

        step_tel = StepTelemetry(
            step=self._step_idx, occupancy=occupancy,
            tokens_in_flight=n_tokens, padded_tokens=padded,
            union_experts=union or 0.0,
            t_step=t_verify_shared, t_overhead=t_overhead,
            joined=self._joined_since_step,
            retired=sum(1 for i in spans if slots[i].done),
            prefill_tokens=sum(chunk_plan.values()),
            decode_tokens=sum(len(spans[i]) for i in decode_rows),
            k_requested=plan.requested_total,
            k_granted=plan.granted_total,
            preempted=plan.preempted,
            held_tests=plan.held,
            t_step_predicted=plan.t_predicted,
            t_base_predicted=plan.t_base,
            tokens_predicted=plan.tokens_predicted,
            planned=plan.priced,
            slo_denied=plan.slo_denied,
            shard_experts=tuple(cost.get("shard_unique", ())),
            max_shard_experts=cost.get("max_shard_experts", 0.0),
            hot_shard=cost.get("hot_shard", -1),
            shard_imbalance=cost.get("imbalance", 1.0),
            t_a2a=cost.get("t_a2a", 0.0),
            replica_moves=step_moves,
            packed_experts=(packed_expert_cap(self.cfg, b * t_max)
                            if self.packed else 0),
            prefetch_hits=n_hits,
            prefetch_misses=n_miss,
            evictions=step_evictions,
            fetch_bytes=step_fetch_bytes,
            t_fetch=cost.get("t_fetch_unhidden", 0.0),
            fetch_hide=(min(float(fetch_hide[0]),
                            max(r[0] for r in staged_counts)
                            * self.residency.expert_bytes
                            / self.hw.host_bw)
                        if isinstance(fetch_hide, list)
                        else float(fetch_hide)),
            t_fetch_by_layer=tuple(cost.get("t_fetch_by_layer", ())),
            prefetch_hits_by_layer=hit_by_layer,
            prefetch_misses_by_layer=miss_by_layer,
            precision=cost.get("precision", ""),
            expert_bytes_saved=cost.get("expert_bytes_saved", 0.0))
        self.telemetry.steps.append(step_tel)
        # every decode row experienced the WHOLE pass between its tokens —
        # the latency quantity SLOs bound (vs t_iter's attributed share)
        for it_tel in step_iter_tel.values():
            it_tel.t_pass = step_tel.t_total
        self.now += step_tel.t_total
        for i in finished_prefill:  # first token exists as of end-of-step
            s = slots[i]
            s.tel.ttft = max(self.now - s.t_submit, 0.0)
        self._joined_since_step = 0
        self._step_idx += 1
        return emitted_by_slot

    # -- batch=1 compatibility ------------------------------------------ #

    def generate(self, prompt: List[int], max_new: int = 128, *,
                 controller=None, request_id: str = "", task: str = "",
                 stop_token: Optional[int] = None,
                 enc_out=None) -> GenerationResult:
        """Drive a single request to completion (other live slots advance
        alongside it). At max_batch=1 this is the legacy `ServingEngine`
        loop, token for token."""
        idx = self.join(prompt, max_new, controller=controller,
                        request_id=request_id, task=task,
                        stop_token=stop_token, enc_out=enc_out)
        while not self.slots[idx].done:
            self.step()
        return self.retire(idx)
