"""Speculation-utility telemetry (paper §4).

Definition 4.1:  U = benefit / cost
    benefit = ETR_spec            (tokens emitted per target iteration)
    cost    = t_iter_spec / t_iter_base

Theorem 4.2:     TPOT_spec = TPOT_base / U
(so maximizing utility minimizes time-per-output-token; verified by a
property test in tests/test_core.py).

The UtilityAnalyzer mirrors the paper's vLLM implementation: it tracks
recent per-iteration (tokens, time) samples, maintains a no-speculation
baseline iteration time measured from the first few decode iterations and
refreshed infrequently (§5.3), and reports windowed utility."""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional, Tuple


@dataclass
class IterationRecord:
    k: int              # speculation length used (0 = no speculation)
    tokens: int         # tokens emitted this iteration (>=1)
    t_iter: float       # iteration time (seconds, wall-clock or cost model)
    t_draft: float = 0.0
    t_verify: float = 0.0   # under batching: this request's attributed share
    t_sample: float = 0.0
    batch: int = 1      # requests sharing the verification pass


@dataclass
class UtilityAnalyzer:
    """Per-request utility tracker.

    Parameters mirror §5.3: `baseline_iters` no-spec iterations measured at
    request start, refreshed every `baseline_refresh` iterations."""

    baseline_iters: int = 4
    baseline_refresh: int = 100
    window: int = 16

    _records: Deque[IterationRecord] = field(default_factory=lambda: deque(maxlen=512))
    _baseline_samples: Deque[float] = field(default_factory=lambda: deque(maxlen=16))
    _iters_since_refresh: int = 0
    total_iters: int = 0

    # ------------------------------------------------------------------ #

    def observe(self, rec: IterationRecord) -> None:
        self._records.append(rec)
        self.total_iters += 1
        self._iters_since_refresh += 1
        if rec.k == 0:
            self._baseline_samples.append(rec.t_iter)
            self._iters_since_refresh = 0

    @property
    def baseline_time(self) -> Optional[float]:
        """Average no-speculation iteration time (None until measured)."""
        if not self._baseline_samples:
            return None
        return sum(self._baseline_samples) / len(self._baseline_samples)

    def needs_baseline(self) -> bool:
        """True while the manager should run no-spec iterations to (re)measure
        the baseline (first `baseline_iters`, then every `baseline_refresh`)."""
        if len(self._baseline_samples) < self.baseline_iters:
            return True
        return self._iters_since_refresh >= self.baseline_refresh

    # ------------------------------------------------------------------ #

    def _window_records(self, n: Optional[int] = None, k: Optional[int] = None):
        n = n or self.window
        recs = [r for r in self._records if k is None or r.k == k]
        return recs[-n:]

    def etr(self, n: Optional[int] = None, k: Optional[int] = None) -> float:
        recs = self._window_records(n, k)
        if not recs:
            return 1.0
        return sum(r.tokens for r in recs) / len(recs)

    def cost(self, n: Optional[int] = None, k: Optional[int] = None) -> float:
        """Mean iteration time over window / baseline time."""
        base = self.baseline_time
        recs = self._window_records(n, k)
        if not recs or not base:
            return 1.0
        return (sum(r.t_iter for r in recs) / len(recs)) / base

    def utility(self, n: Optional[int] = None, k: Optional[int] = None) -> float:
        """Definition 4.1 over the last `n` iterations (optionally only those
        run at speculation length `k`)."""
        c = self.cost(n, k)
        return self.etr(n, k) / max(c, 1e-9)

    def accept_rate(self, n: Optional[int] = None) -> Optional[float]:
        """Windowed per-draft acceptance estimate: accepted draft tokens /
        drafted tokens over the last `n` records that speculated (k > 0) —
        filtered *before* windowing, so a run of K=0 iterations (a
        backed-off set phase, planner preemptions) does not blank out the
        estimate while real speculative history exists. None until a
        speculative record exists — callers fall back to their prior.
        `tokens` counts the bonus token, so accepted = tokens - 1; a
        stop-token-truncated iteration undercounts, deliberately: the
        planner should not bank on tokens past a stop. Capped below 1 so
        geometric-series consumers stay finite."""
        recs = [r for r in self._records if r.k > 0][-(n or self.window):]
        drafted = sum(r.k for r in recs)
        if drafted <= 0:
            return None
        accepted = sum(min(max(r.tokens - 1, 0), r.k) for r in recs)
        return min(accepted / drafted, 0.999)

    def accept_curve(self, max_k: int, n: Optional[int] = None
                     ) -> Optional[list]:
        """Per-position conditional acceptance over the last `n`
        speculative records: curve[p] = P(draft p+1 accepted | position
        reached). No extra recording is needed — speculative verification
        accepts a *prefix*, so a record (k, tokens) pins down every
        position's outcome: positions 0..tokens-2 were reached and
        accepted, position tokens-1 was reached and rejected (when it was
        drafted, tokens-1 < k), and positions past the first rejection
        were never reached (and must not count — that truncation is
        exactly why a flat mean over-estimates deep drafts: acceptance
        decays with depth, the ROADMAP's acceptance-model item).

        Positions with no observations fall back to the flat windowed
        `accept_rate`; None until any speculative record exists (callers
        fall back to their prior). Stop-token-truncated iterations
        undercount deliberately, like `accept_rate`. Values capped below
        1 so geometric consumers stay finite."""
        recs = [r for r in self._records if r.k > 0][-(n or self.window):]
        flat = self.accept_rate(n)
        if flat is None or max_k <= 0:
            return None
        curve = []
        for p in range(max_k):
            reached = sum(1 for r in recs
                          if r.k > p and min(r.tokens - 1, r.k) >= p)
            accepted = sum(1 for r in recs
                           if r.k > p and min(r.tokens - 1, r.k) > p)
            curve.append(min(accepted / reached, 0.999) if reached
                         else flat)
        return curve

    def trial_utility(self, trial_records) -> float:
        """Utility of an explicit list of records (one test-phase trial)."""
        base = self.baseline_time
        if not trial_records or not base:
            return 1.0
        etr = sum(r.tokens for r in trial_records) / len(trial_records)
        cost = (sum(r.t_iter for r in trial_records) / len(trial_records)) / base
        return etr / max(cost, 1e-9)

    # -- diagnostics ---------------------------------------------------- #

    def breakdown(self, n: Optional[int] = None) -> Tuple[float, float, float]:
        recs = self._window_records(n)
        if not recs:
            return (0.0, 0.0, 0.0)
        m = len(recs)
        return (sum(r.t_draft for r in recs) / m,
                sum(r.t_verify for r in recs) / m,
                sum(r.t_sample for r in recs) / m)
