"""CascadeController: the per-request composition of utility analyzer and
speculation manager — the object the serving engine talks to.

    ctl = CascadeController(CascadeConfig())
    k = ctl.next_k()                 # draft k tokens (0 = no speculation)
    ... run draft + verify ...
    ctl.observe(tokens_emitted, t_iter, t_draft, t_verify, t_sample)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .manager import CascadeConfig, SpeculationManager
from .utility import IterationRecord, UtilityAnalyzer


def cascade_for_model(cfg_model, hw=None, **overrides) -> "CascadeController":
    """Build a controller whose first trial K comes from the analytic
    cost-model prior for this architecture (beyond-paper §Perf item)."""
    from . import cost_model as _cm
    hw = hw or _cm.TPU_V5E
    k0 = _cm.suggest_k_start(cfg_model, hw)
    return CascadeController(CascadeConfig(k_start=k0, **overrides))


@dataclass
class CascadeController:
    config: CascadeConfig = field(default_factory=CascadeConfig)
    manager: Optional[SpeculationManager] = None
    _last_k: int = 0

    def __post_init__(self):
        if self.manager is None:
            self.manager = SpeculationManager(cfg=self.config)

    # ------------------------------------------------------------------ #

    @property
    def analyzer(self) -> UtilityAnalyzer:
        return self.manager.analyzer

    @property
    def phase(self) -> str:
        return self.manager.phase

    def next_k(self) -> int:
        self._last_k = self.manager.next_k()
        return self._last_k

    def hold(self) -> int:
        """Batch-planner phase hook: postpone a TEST-phase trial by one
        iteration and run the steady-state K instead (see
        `SpeculationManager.hold`). A no-op `next_k()` outside TEST."""
        self._last_k = self.manager.hold()
        return self._last_k

    def observe(self, tokens: int, t_iter: float, *, t_draft: float = 0.0,
                t_verify: float = 0.0, t_sample: float = 0.0,
                k: Optional[int] = None, batch: int = 1) -> None:
        """Feed back one completed iteration. Under continuous batching the
        times are this request's *attributed* share of the shared pass
        (cost_model.batch_iteration_time's marginal-bytes split), so the
        utility signal keeps meaning 'what this request's speculation costs
        the cluster' even when B requests verify together."""
        rec = IterationRecord(k=self._last_k if k is None else k,
                              tokens=tokens, t_iter=t_iter, t_draft=t_draft,
                              t_verify=t_verify, t_sample=t_sample,
                              batch=batch)
        self.manager.observe(rec)

    def utility(self, n: Optional[int] = None) -> float:
        return self.analyzer.utility(n)


class StaticKController:
    """Baseline controller: fixed speculation length (the paper's static-K
    comparison points, with K=0 being the no-speculation baseline).

    Under `BatchedEngine`'s default policy="joint" the batch planner may
    cap or preempt these fixed asks at B>1 like any other request's (there
    is no TEST phase to protect — 'static' is the ask, not a grant
    guarantee). A faithful static-K *measurement* therefore needs
    `BatchedEngine(policy="independent")` (as `--batch-sweep` pins) or the
    single-request `ServingEngine`."""

    def __init__(self, k: int):
        self.k = k
        self.analyzer = UtilityAnalyzer()
        self.phase = "static"

    def next_k(self) -> int:
        return self.k

    def observe(self, tokens: int, t_iter: float, *, t_draft: float = 0.0,
                t_verify: float = 0.0, t_sample: float = 0.0,
                k: Optional[int] = None, batch: int = 1) -> None:
        self.analyzer.observe(IterationRecord(
            k=self.k if k is None else k, tokens=tokens, t_iter=t_iter,
            t_draft=t_draft, t_verify=t_verify, t_sample=t_sample,
            batch=batch))

    def utility(self, n: Optional[int] = None) -> float:
        return self.analyzer.utility(n)
