"""Cascade — the paper's contribution: utility-driven speculative decoding
management for MoE serving."""

from .controller import (CascadeController, StaticKController,
                         cascade_for_model)
from .cost_model import (Hardware, Precision, TPU_V5E, RTX_6000_ADA,
                         batch_iteration_time, expected_unique_experts,
                         expected_unique_experts_batch, iteration_bytes,
                         iteration_flops, iteration_time, draft_time,
                         sample_time, kv_bytes_per_token)
from .cost_model import (BatchCostOracle, Calibration, ExpertPlacement,
                         a2a_bytes, expected_emitted,
                         expected_emitted_curve,
                         expected_unique_experts_sharded,
                         fetch_hide_schedule, fetch_time_layered,
                         moe_hide_fracs)
from .manager import BASELINE, TEST, SET, CascadeConfig, SpeculationManager
from .planner import (ADMIT, DEFER, SHED, AdmissionConstraint,
                      AdmissionDecision, BatchPlan, BatchSpecPlanner,
                      BreakEvenConstraint, DraftYieldModel,
                      FetchDeadlineConstraint, GrantConstraint,
                      MemoryCapConstraint, PlanDecision, PlannerConfig,
                      PredictiveTTFTAdmission, SLOTpotConstraint,
                      greedy_allocate)
from .residency import (ResidencyState, expert_hbm_bytes,
                        moe_layer_count)
from .slo import (LATENCY, THROUGHPUT, RequestSLO, tpot_within,
                  ttft_violated)
from .utility import IterationRecord, UtilityAnalyzer

__all__ = [
    "CascadeController", "StaticKController", "CascadeConfig",
    "SpeculationManager", "UtilityAnalyzer", "IterationRecord",
    "Hardware", "Precision", "TPU_V5E", "RTX_6000_ADA",
    "expected_unique_experts",
    "expected_unique_experts_batch", "batch_iteration_time",
    "BatchCostOracle", "Calibration", "iteration_bytes", "iteration_flops",
    "iteration_time", "draft_time", "sample_time", "kv_bytes_per_token",
    "BASELINE", "TEST", "SET", "cascade_for_model",
    "BatchSpecPlanner", "BatchPlan", "PlanDecision", "PlannerConfig",
    "expected_emitted", "expected_emitted_curve", "greedy_allocate",
    "ExpertPlacement", "expected_unique_experts_sharded", "a2a_bytes",
    "RequestSLO", "LATENCY", "THROUGHPUT", "tpot_within", "ttft_violated",
    "GrantConstraint", "BreakEvenConstraint", "SLOTpotConstraint",
    "MemoryCapConstraint", "FetchDeadlineConstraint",
    "AdmissionConstraint", "AdmissionDecision", "PredictiveTTFTAdmission",
    "ADMIT", "DEFER", "SHED",
    "ResidencyState", "expert_hbm_bytes", "moe_layer_count",
    "fetch_hide_schedule", "fetch_time_layered", "moe_hide_fracs",
    "DraftYieldModel",
]
