"""Per-request service-level objectives (beyond-paper; the §8.3 discussion
made concrete).

A `RequestSLO` rides on `serving.Request` through the engine's slot table
into the batch planner: TPOT/TTFT bounds become *constraints on the joint
allocation* (docs/slo.md), not just the per-request `CascadeConfig.slo_tpot`
check — under continuous batching a grant to one request lengthens the
shared verification pass for every co-scheduled request, so a latency-tier
request can be pushed past its bound by someone else's speculation, which
no per-request gate can see.

`tpot_within` is the ONE comparison rule every SLO consumer shares: the
manager's measured-TPOT trial gate (`SpeculationManager._slo_allows`), the
planner's predicted-TPOT grant constraint (`planner.SLOTpotConstraint`),
and the serving-side violation counters. None-bounds and None-estimates
always pass — an unknown is not a violation."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: scheduling tiers: latency-tier requests are admitted ahead of FIFO and
#: weight the planner's water level; throughput-tier is the default
LATENCY, THROUGHPUT = "latency", "throughput"


def tpot_within(bound: Optional[float], tpot: Optional[float]) -> bool:
    """True when a TPOT estimate satisfies a bound. The shared predicate:
    no bound, or no estimate yet, always passes (testing/observing is how
    bounds get learned; absence of data must not read as a violation)."""
    if bound is None or tpot is None:
        return True
    return tpot <= bound


def ttft_violated(bound: Optional[float], ttft: Optional[float]) -> bool:
    """True when a request's TTFT violated its bound. TTFT is an *outcome*,
    not an estimate, so the no-data rule is the OPPOSITE of `tpot_within`:
    a bounded request that never produced a first token (shed, or still
    queued at the replay horizon — ttft None or <= 0) has by construction
    blown any finite TTFT bound. Mapping "never served" to "no violation"
    is exactly the silent-zero-violation failure mode this predicate
    exists to close. No bound still always passes."""
    if bound is None:
        return False
    if ttft is None or ttft <= 0:
        return True
    return ttft > bound


@dataclass(frozen=True)
class RequestSLO:
    """Per-request latency objective.

    tpot  — mean seconds per output token the request may experience
            (experienced = it waits out the whole shared pass between its
            token batches; see `RequestTelemetry.experienced_tpot`).
    ttft  — seconds from submit to first token; enforced on the admission
            side (latency-tier requests jump the FIFO queue) and counted,
            not enforced, by the planner (a queued request has no grants
            to constrain).
    tier  — "latency" requests are admitted ahead of FIFO and raise the
            planner's water level (`PlannerConfig.latency_tier_weight`);
            "throughput" (default) is plain FIFO + break-even planning.
    """
    tpot: Optional[float] = None
    ttft: Optional[float] = None
    tier: str = THROUGHPUT

    def __post_init__(self):
        if self.tier not in (LATENCY, THROUGHPUT):
            raise ValueError(f"unknown SLO tier {self.tier!r} "
                             f"(expected {LATENCY!r} or {THROUGHPUT!r})")
        for name in ("tpot", "ttft"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"slo {name} bound must be positive, "
                                 f"got {v!r}")

    @property
    def is_latency_tier(self) -> bool:
        return self.tier == LATENCY

    @classmethod
    def latency(cls, tpot: Optional[float] = None,
                ttft: Optional[float] = None) -> "RequestSLO":
        """Convenience constructor for a latency-tier objective."""
        return cls(tpot=tpot, ttft=ttft, tier=LATENCY)
