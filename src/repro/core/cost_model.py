"""Data-movement cost model for MoE speculative verification (paper §2.4,
adapted from the paper's GPU to our TPU v5e target — DESIGN.md §3).

Single-batch decoding is memory-bandwidth-bound: iteration time is governed
by the bytes fetched from HBM — all attention weights, the *unique* experts
activated by the in-flight tokens, the KV cache read, and the unembedding.
Verifying K+1 tokens multiplies the expert term by the number of unique
experts they collectively activate (bucket-and-balls, damped by expert
affinity), which is exactly why speculation can slow MoEs down.

The same model is used by (1) the serving engine's deterministic virtual
clock on CPU, (2) the paper-figure simulator, and (3) the §Roofline
active-expert correction for MoE decode."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class Hardware:
    name: str
    hbm_bw: float            # bytes/s
    peak_flops: float        # FLOP/s at serving precision
    ici_bw: float = 0.0      # bytes/s per link (TPU interconnect)
    weight_bytes: int = 2    # serving precision (bf16/fp16 = 2)
    #: host<->HBM link bandwidth (PCIe/DMA class) — the path an offloaded
    #: (host-tier) expert's weights cross to become HBM-resident
    #: (docs/offload.md). 0 = no offload path: fetch pricing raises.
    host_bw: float = 0.0
    #: HBM capacity in bytes (0 = unspecified). Informational for the
    #: large-config sanity checks; residency caps are set per shard on
    #: `ResidencyState`, not read from here.
    hbm_bytes: float = 0.0


TPU_V5E = Hardware("tpu-v5e", hbm_bw=819e9, peak_flops=197e12, ici_bw=50e9,
                   host_bw=32e9, hbm_bytes=16e9)
# the paper's workstation GPU (RTX 6000 Ada): ~960 GB/s GDDR6, ~91 TFLOP/s fp16
RTX_6000_ADA = Hardware("rtx-6000-ada", hbm_bw=960e9, peak_flops=91e12,
                        host_bw=32e9, hbm_bytes=48e9)


@dataclass(frozen=True)
class Precision:
    """Bytes-per-param by tensor class — the ONE source of truth for
    serving precision (docs/quantization.md).

    The paper's utility calculus is bytes-moved-per-pass, and quantization
    changes the bytes: int8/fp8 expert weights halve `_expert_read_bytes`,
    shifting the roofline crossover and with it every planner decision
    (break-even floor, grant steering, residency capacity, fetch
    deadlines). A single global `Hardware.weight_bytes` cannot express
    mixed precision — the quantized path keeps dense/attention weights at
    bf16 while experts stream at 1 byte/param — so pricing takes a
    per-tensor-class spec instead. Every bytes function threads this spec;
    the scattered `wb=2` defaults all resolve through `DEFAULT` so a
    precision change cannot silently half-apply.

    `precision=None` everywhere means `Precision.DEFAULT` (all classes at
    2 bytes) and is bit-identical to the pre-quantization stack — the same
    degradation contract as `calibration=None` / `placement=None`, pinned
    by a tier-1 property test."""
    dense: int = 2     # attention / dense-FFN / router / unembedding
    expert: int = 2    # routed expert weights (the quantization target)
    kv: int = 2        # KV-cache rows
    label: str = "bf16"   # telemetry tag; never enters arithmetic

    @classmethod
    def int8_experts(cls) -> "Precision":
        """Weight-only int8 routed experts (per-expert absmax scales,
        dequant-in-kernel); dense/attention/KV stay bf16."""
        return cls(expert=1, label="int8-experts")

    @classmethod
    def fp8_experts(cls) -> "Precision":
        """fp8(e4m3) routed experts — same 1 byte/param pricing as int8;
        the numerics differ (kernels/moe_gmm/quant.py fake-quant on CPU)."""
        return cls(expert=1, label="fp8-experts")

    @property
    def quantized_experts(self) -> bool:
        return self.expert < self.dense


#: module default: bf16 everywhere — what `precision=None` resolves to
Precision.DEFAULT = Precision()


def _resolve_precision(precision: Optional["Precision"],
                       wb: Optional[int] = None) -> "Precision":
    """`precision` if given; else a uniform spec from a legacy `wb` int;
    else the bf16 default. Keeps old `wb=` call sites working while the
    spec stays the single source of truth."""
    if precision is not None:
        return precision
    if wb is not None:
        return Precision(dense=wb, expert=wb, kv=wb, label=f"wb{wb}")
    return Precision.DEFAULT


# --------------------------------------------------------------------- #
# Wall-clock calibration (ROADMAP "calibration" item; fitted by
# `benchmarks/serving_micro.py --calibrate`)
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class Calibration:
    """Measured-residual correction for the analytic pass-time model.

    The planner predicts each step's pass time analytically (expected
    union + roofline); the engine then measures it (`StepTelemetry.t_step`
    vs `t_step_predicted`, aggregated as `plan_time_error`).  The residual
    is dominated by systematic terms — analytic-union vs actual routing,
    grants the drafter didn't fill — so a least-squares scale/offset on
    (predicted, measured) pairs removes most of it.  The all-to-all term
    gets its own scale (`a2a_scale`): it prices interconnect, not HBM, and
    its residual is independent of the roofline's.

    Applied on the *prediction* side only (`BatchCostOracle(calibration=)`
    via `BatchSpecPlanner(calibration=)`); the engine's measured costs are
    never calibrated, so before/after residuals stay comparable.
    `calibration=None` everywhere is bit-identical to the uncalibrated
    stack."""
    time_scale: float = 1.0     # multiplier on the roofline + overhead term
    time_offset: float = 0.0    # additive seconds
    a2a_scale: float = 1.0      # multiplier on the all-to-all term
    resid_before: float = 0.0   # mean |pred-meas|/meas of the fitted pairs
    resid_after: float = 0.0    # same, after applying the fit

    def apply(self, t: float, t_a2a: float = 0.0) -> float:
        """Calibrated pass seconds for an analytic prediction `t` whose
        all-to-all component was `t_a2a` (0 when unsharded)."""
        base = t - t_a2a
        return max(self.time_scale * base + self.a2a_scale * t_a2a
                   + self.time_offset, 0.0)

    def adapted_util_floor(self, base: float = 1.0) -> float:
        """Break-even utility floor with an uncertainty margin: after
        calibration the model still mispredicts by `resid_after` on
        average, so grants must clear break-even by that margin before
        they are trusted (planner.PlannerConfig.util_floor)."""
        return base * (1.0 + max(self.resid_after, 0.0))

    @classmethod
    def fit(cls, predicted, measured, a2a=None) -> "Calibration":
        """Least-squares fit of measured ≈ scale*(pred - a2a) +
        a2a_scale*a2a + offset over per-step pairs.  Without any nonzero
        `a2a` the collective column is dropped (a2a_scale stays 1.0).  A
        degenerate system falls back to the identity transform."""
        pred = [float(p) for p in predicted]
        meas = [float(m) for m in measured]
        n = len(pred)
        if n == 0 or len(meas) != n:
            raise ValueError(f"{n} predictions vs {len(meas)} measurements")
        aa = [0.0] * n if a2a is None else [float(x) for x in a2a]
        if len(aa) != n:
            raise ValueError(f"{n} predictions vs {len(aa)} a2a terms")
        base = [p - a for p, a in zip(pred, aa)]
        have_a2a = any(a > 0.0 for a in aa)
        cols = [base, aa, [1.0] * n] if have_a2a else [base, [1.0] * n]
        theta = _lstsq(cols, meas)
        if theta is None:
            s, c, off = 1.0, 1.0, 0.0
        elif have_a2a:
            s, c, off = theta
        else:
            (s, off), c = theta, 1.0
        s = max(s, 1e-6)   # a degenerate fit must not run time backwards
        c = max(c, 0.0)
        rb = _mean_rel_err(pred, meas)
        ra = _mean_rel_err([s * b + c * a + off
                            for b, a in zip(base, aa)], meas)
        return cls(time_scale=s, time_offset=off, a2a_scale=c,
                   resid_before=rb, resid_after=ra)


def _mean_rel_err(pred, meas) -> float:
    """Mean |pred - meas| / meas over pairs with meas > 0 — the same
    definition `serving.telemetry.planner_aggregates` reports as
    `plan_time_error`."""
    errs = [abs(p - m) / m for p, m in zip(pred, meas) if m > 0]
    return sum(errs) / len(errs) if errs else 0.0


def _lstsq(cols, y):
    """Tiny normal-equations least squares (2-3 unknowns): solve
    (A^T A) theta = A^T y by Gaussian elimination with a whisper of ridge.
    Returns None when the system is singular beyond rescue."""
    k = len(cols)
    ata = [[sum(ci * cj for ci, cj in zip(cols[i], cols[j])) + (1e-12 if
            i == j else 0.0) for j in range(k)] for i in range(k)]
    aty = [sum(ci * yi for ci, yi in zip(cols[i], y)) for i in range(k)]
    for col in range(k):          # forward elimination with partial pivot
        piv = max(range(col, k), key=lambda r: abs(ata[r][col]))
        if abs(ata[piv][col]) < 1e-30:
            return None
        ata[col], ata[piv] = ata[piv], ata[col]
        aty[col], aty[piv] = aty[piv], aty[col]
        for r in range(col + 1, k):
            fac = ata[r][col] / ata[col][col]
            for cc in range(col, k):
                ata[r][cc] -= fac * ata[col][cc]
            aty[r] -= fac * aty[col]
    theta = [0.0] * k
    for r in range(k - 1, -1, -1):
        theta[r] = (aty[r] - sum(ata[r][cc] * theta[cc]
                                 for cc in range(r + 1, k))) / ata[r][r]
    return theta


# --------------------------------------------------------------------- #
# Expert activation statistics (paper §2.4)
# --------------------------------------------------------------------- #

def expected_unique_experts(num_experts: int, top_k: int, n_tokens: int,
                            affinity: float = 0.0) -> float:
    """Expected number of distinct experts activated by `n_tokens` tokens,
    each selecting `top_k` distinct experts.

    affinity=0: uniform-random routing (bucket-and-balls):
        E[unique] = E * (1 - (1 - k/E)^T)
    affinity=1: perfect temporal reuse (all tokens share one expert set).
    The paper observes real tasks fall between the two (§2.4: Mixtral math
    shows 3x instead of the random 3.5x at K=7)."""
    if num_experts == 0:
        return 0.0
    n_tokens = max(int(n_tokens), 1)
    e, k = float(num_experts), float(min(top_k, num_experts))
    rand = e * (1.0 - (1.0 - k / e) ** n_tokens)
    floor = k  # one shared expert set
    return floor + (rand - floor) * (1.0 - affinity)


def expected_unique_experts_batch(num_experts: int, top_k: int,
                                  tokens_per_request, affinity: float = 0.0
                                  ) -> dict:
    """Multi-request extension of `expected_unique_experts`: B requests
    jointly verifying sum(n_i) tokens in one shared pass activate the
    *union* of their expert sets.

    Returns:
        union     — E[unique experts] over all sum(n_i) tokens
        marginal  — per-request marginal contribution,
                    m_i = union(all) - union(all minus request i),
                    the bytes request i adds to the shared verification
                    (the batch-level analogue of the paper's Fig. 2 curve:
                    m_i shrinks as the rest of the batch grows, because the
                    batch has already paid for most of i's experts)."""
    ns = [max(int(n), 0) for n in tokens_per_request]
    total = sum(ns)
    if total <= 0:
        return {"union": 0.0, "marginal": [0.0] * len(ns)}
    union = expected_unique_experts(num_experts, top_k, total, affinity)
    marginal = []
    for n in ns:
        if n <= 0:
            marginal.append(0.0)
        elif total - n <= 0:
            marginal.append(union)
        else:
            marginal.append(union - expected_unique_experts(
                num_experts, top_k, total - n, affinity))
    return {"union": union, "marginal": marginal}


# --------------------------------------------------------------------- #
# Expert-parallel placement + per-shard activation statistics
# (docs/expert_parallel.md — under EP the activated-expert union is *per
# shard*: the pass completes only when the hottest shard has streamed its
# local experts, so global-union accounting under-prices skewed routing)
# --------------------------------------------------------------------- #

@dataclass(frozen=True)
class ExpertPlacement:
    """Experts -> EP-shard map: the pricing contract every shard-aware
    consumer (cost model, planner, engine telemetry) shares.

    `shard_of[e]` is the shard holding expert e's weights — an int for the
    common single-home case, or a tuple of distinct shard ids when the
    expert is *replicated* (hot-expert replication: the first id is the
    primary home, the rest hold read-only replicas). Every shard id in
    0..n_shards-1 holds at least one resident expert (primary or replica).
    `contiguous` matches `distributed/expert_parallel.py`'s layout (expert
    e on shard e // (E / n_shards)); `from_sizes` builds contiguous blocks
    of arbitrary sizes, `zipf` the skew-study placement that co-locates
    zipf-proportional expert populations on shard 0 downward, and
    `replicate` adds replica shards to chosen experts of an existing
    placement.

    Replication is a *pricing* feature: a replicated expert's activated
    load can be served from whichever replica shard is coolest, so the
    analytic per-shard union takes min-over-replicas (see
    `_rebalance_replicas` — it can only lower the gating shard, never
    raise it). The measured engine path keeps routing to primary homes
    (`primary_shard_of`); serving-side replica routing is future work.

    Residency tiers (`tier_of`, docs/offload.md): each expert additionally
    carries a memory tier — `"hbm"` (weights always device-resident, the
    default) or `"host"` (weights live in host memory and must cross the
    `Hardware.host_bw` link before the shard can stream them). `tier_of is
    None` means all-`hbm` and degrades bit-exactly to the flat placement.
    A replicated expert cannot be `host`-tier: replication exists to
    relieve the gating shard, and a replica that might not be resident
    would make the min-over-replicas relief unsound. Tiers do not change
    homes — `shard_of`, `counts`, and the routed activation curve are
    tier-blind; what changes is which activated experts cost a host fetch,
    tracked dynamically by `ResidencyState` (core/residency.py)."""
    shard_of: Tuple
    tier_of: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if not self.shard_of:
            raise ValueError("empty placement (no experts)")
        norm = []
        for e, s in enumerate(self.shard_of):
            if isinstance(s, (tuple, list)):
                reps = tuple(int(x) for x in s)
                if not reps or len(set(reps)) != len(reps) or min(reps) < 0:
                    raise ValueError(f"expert {e}: replica shards must be "
                                     f"a non-empty set of distinct "
                                     f"non-negative ids, got {s!r}")
                norm.append(reps if len(reps) > 1 else reps[0])
            else:
                if int(s) < 0:
                    raise ValueError(f"expert {e}: negative shard id {s!r}")
                norm.append(int(s))
        object.__setattr__(self, "shard_of", tuple(norm))
        resident = set()
        for s in self.shard_of:
            resident.update(s if isinstance(s, tuple) else (s,))
        n = max(resident) + 1
        if resident != set(range(n)):
            raise ValueError("shard ids must cover 0..n_shards-1 with every "
                             f"shard non-empty, got {self.shard_of}")
        if self.tier_of is not None:
            tiers = tuple(str(t) for t in self.tier_of)
            if len(tiers) != len(self.shard_of):
                raise ValueError(f"{len(tiers)} tiers vs "
                                 f"{len(self.shard_of)} experts")
            bad = sorted({t for t in tiers if t not in ("hbm", "host")})
            if bad:
                raise ValueError(f"unknown tier(s) {bad}; expected "
                                 f"'hbm' or 'host'")
            for e, (s, t) in enumerate(zip(self.shard_of, tiers)):
                if t == "host" and isinstance(s, tuple):
                    raise ValueError(f"expert {e} is replicated and cannot "
                                     "be host-tier (replica relief assumes "
                                     "residency)")
            object.__setattr__(self, "tier_of", tiers)

    @property
    def num_experts(self) -> int:
        return len(self.shard_of)

    @property
    def n_shards(self) -> int:
        return max(max(s) if isinstance(s, tuple) else s
                   for s in self.shard_of) + 1

    @property
    def primary_shard_of(self) -> Tuple[int, ...]:
        """Each expert's primary home — the layout the measured engine
        path routes on (ints, usable as `ep_shard_ids`)."""
        return tuple(s[0] if isinstance(s, tuple) else s
                     for s in self.shard_of)

    @property
    def has_replication(self) -> bool:
        return any(isinstance(s, tuple) for s in self.shard_of)

    @property
    def counts(self) -> Tuple[int, ...]:
        """Experts homed per shard (primary residence — the population the
        analytic activation curve spreads routed mass over; replicas do
        not add activated population, they add serving *options*, priced
        by `_rebalance_replicas`)."""
        c = [0] * self.n_shards
        for s in self.primary_shard_of:
            c[s] += 1
        return tuple(c)

    @property
    def resident_counts(self) -> Tuple[int, ...]:
        """Expert weights *statically* HBM-resident per shard, replicas
        included — the pinned HBM footprint view. Host-tier experts are
        not counted: their residency is dynamic, tracked by
        `ResidencyState.resident_counts` under a byte cap. Equals `counts`
        for an all-hbm placement without replication."""
        c = [0] * self.n_shards
        tiers = self.tiers
        for e, s in enumerate(self.shard_of):
            if tiers[e] == "host":
                continue
            for x in (s if isinstance(s, tuple) else (s,)):
                c[x] += 1
        return tuple(c)

    @property
    def tiers(self) -> Tuple[str, ...]:
        """Per-expert tier, `tier_of` defaulted to all-`hbm`."""
        return self.tier_of if self.tier_of is not None \
            else ("hbm",) * len(self.shard_of)

    @property
    def has_host_tier(self) -> bool:
        return self.tier_of is not None and "host" in self.tier_of

    @property
    def hbm_tier_counts(self) -> Tuple[int, ...]:
        """Homed hbm-tier experts per shard (primary residence)."""
        c = [0] * self.n_shards
        for s, t in zip(self.primary_shard_of, self.tiers):
            if t == "hbm":
                c[s] += 1
        return tuple(c)

    @property
    def host_tier_counts(self) -> Tuple[int, ...]:
        """Homed host-tier experts per shard (primary residence)."""
        c = [0] * self.n_shards
        for s, t in zip(self.primary_shard_of, self.tiers):
            if t == "host":
                c[s] += 1
        return tuple(c)

    @property
    def replication_groups(self) -> Tuple[Tuple[int, Tuple[int, ...], int],
                                          ...]:
        """Replicated experts grouped by identical replica set:
        (primary_shard, alternate_shards, n_experts) per group — the
        movable-mass units `_rebalance_replicas` shifts off the gating
        shard. Empty without replication."""
        groups: dict = {}
        for s in self.shard_of:
            if isinstance(s, tuple):
                groups[s] = groups.get(s, 0) + 1
        return tuple((reps[0], reps[1:], n)
                     for reps, n in sorted(groups.items()))

    def validate_experts(self, num_experts: int) -> None:
        """The one consistency check every consumer of the pricing
        contract applies (cost model, planner, engine): this placement
        must map exactly the model's experts."""
        if self.num_experts != num_experts:
            raise ValueError(f"placement maps {self.num_experts} experts, "
                             f"model has {num_experts}")

    @classmethod
    def contiguous(cls, num_experts: int, n_shards: int) -> "ExpertPlacement":
        if n_shards <= 0 or num_experts % n_shards:
            raise ValueError(f"{num_experts} experts do not divide evenly "
                             f"over {n_shards} shards")
        e_loc = num_experts // n_shards
        return cls(tuple(e // e_loc for e in range(num_experts)))

    @classmethod
    def from_sizes(cls, sizes: Sequence[int]) -> "ExpertPlacement":
        ids = []
        for s, n in enumerate(sizes):
            if n <= 0:
                raise ValueError(f"shard {s} holds {n} experts")
            ids.extend([s] * int(n))
        return cls(tuple(ids))

    @classmethod
    def zipf(cls, num_experts: int, n_shards: int,
             alpha: float = 2.0) -> "ExpertPlacement":
        """Contiguous blocks with zipf(alpha)-proportional sizes (shard 0
        largest), every shard holding >= 1 expert — a deliberately skewed
        placement that concentrates the routed load on shard 0 even under
        uniform routing (the --ep-sweep skew axis)."""
        if n_shards <= 0 or n_shards > num_experts:
            raise ValueError(f"{n_shards} shards for {num_experts} experts")
        w = [1.0 / (s + 1) ** alpha for s in range(n_shards)]
        tot = sum(w)
        rem = num_experts - n_shards
        quota = [rem * x / tot for x in w]
        base = [int(q) for q in quota]
        left = rem - sum(base)
        order = sorted(range(n_shards), key=lambda s: (quota[s] - base[s], -s),
                       reverse=True)
        for s in order[:left]:
            base[s] += 1
        return cls.from_sizes([1 + b for b in base])

    def replicate(self, replicas: dict) -> "ExpertPlacement":
        """Hot-expert replication: a new placement where each expert in
        `replicas` (expert id -> extra shard id(s)) additionally holds
        read-only replicas on those shards. Primary homes are unchanged,
        so the measured layout (`primary_shard_of`) and activation
        populations (`counts`) stay identical — only the min-over-replicas
        pricing relief changes."""
        new = list(self.shard_of)
        for e, extra in replicas.items():
            if not 0 <= e < self.num_experts:
                raise ValueError(f"expert {e} outside 0..{self.num_experts - 1}")
            extra = tuple(extra) if isinstance(extra, (tuple, list)) \
                else (int(extra),)
            cur = new[e] if isinstance(new[e], tuple) else (new[e],)
            merged = cur + tuple(x for x in extra if x not in cur)
            if max(merged) >= self.n_shards:
                raise ValueError(f"expert {e}: replica shard beyond the "
                                 f"placement's {self.n_shards} shards")
            new[e] = merged
        return ExpertPlacement(tuple(new), self.tier_of)

    def offload(self, expert_ids) -> "ExpertPlacement":
        """A new placement with `expert_ids` demoted to the host tier
        (docs/offload.md). Homes are unchanged; replicated experts cannot
        be offloaded (ValueError via __post_init__)."""
        tiers = list(self.tiers)
        for e in expert_ids:
            if not 0 <= int(e) < self.num_experts:
                raise ValueError(f"expert {e} outside "
                                 f"0..{self.num_experts - 1}")
            tiers[int(e)] = "host"
        return ExpertPlacement(self.shard_of, tuple(tiers))


def _hot_shard(per_shard) -> int:
    """The gating shard: argmax activated experts, ties broken on the
    lowest shard id — the ONE tie-break rule shared by the analytic and
    measured paths (they must never disagree on which shard gates)."""
    return max(range(len(per_shard)), key=lambda s: (per_shard[s], -s))


def _normalized_shard_weights(counts, n_requests: int, shard_weights):
    """Per-request routing profiles normalized to unit mass; None entries
    (and all-zero profiles) fall back to placement-proportional mass
    E_s/E — allocation-independent, so oracles cache the result."""
    e = float(sum(counts))
    base_w = [c / e for c in counts]
    ws = []
    for i in range(n_requests):
        w = None if shard_weights is None else shard_weights[i]
        if w is None:
            ws.append(base_w)
            continue
        w = [max(float(x), 0.0) for x in w]
        if len(w) != len(counts):
            raise ValueError(f"profile of {len(w)} shards vs {len(counts)}")
        tot = sum(w)
        ws.append([x / tot for x in w] if tot > 0 else base_w)
    return ws


def _rebalance_replicas(per_shard, counts, groups, capacity=None):
    """Min-over-replicas pricing relief (hot-expert replication): a
    replicated expert group's activated load can be served from whichever
    of its replica shards is coolest, so activated mass may move off the
    gating shard. Mass on a shard splits uniformly over the shard's homed
    population, so group g on shard s owns `per_shard[s] * n_g / E_s` of
    its activated count; the greedy loop repeatedly halves the gap between
    the current gating shard and a cooler replica target. Every move takes
    mass OFF the argmax shard and lands the target strictly below the old
    max, so the gating count is non-increasing — replication can only
    relieve the gating shard, never create a hotter one (property-tested).
    Shard totals are conserved, so the union is unchanged.

    `capacity` ([S] expert-count headroom, from
    `ResidencyState.capacity_experts` under a residency cap): a shard
    whose activated load already meets its residency capacity cannot
    absorb rebalanced mass — serving a replica from it would force weights
    it has no room to keep resident — so moves are clamped to the target's
    remaining headroom and full shards are skipped. None (no residency
    cap) is bit-identical to the uncapped rebalance."""
    loads = list(per_shard)
    # movable parcels: [mass, shard-it-sits-on, full replica set]
    parcels = []
    for p, alts, n_g in groups:
        if counts[p] > 0 and loads[p] > 0:
            parcels.append([loads[p] * (n_g / counts[p]), p, (p,) + alts])
    for _ in range(16 * max(len(parcels), 1)):
        hot = _hot_shard(loads)
        best = None
        for idx, (m, src, reps) in enumerate(parcels):
            if src != hot or m <= 1e-12:
                continue
            for a in reps:
                if capacity is not None and \
                        loads[a] >= capacity[a] - 1e-12:
                    continue  # no residency headroom on this target
                if loads[a] < loads[hot] - 1e-12 and (
                        best is None or loads[a] < loads[best[1]]):
                    best = (idx, a)
        if best is None:
            break
        idx, tgt = best
        m, src, reps = parcels[idx]
        delta = min(m, (loads[src] - loads[tgt]) / 2.0)
        if capacity is not None:
            delta = min(delta, capacity[tgt] - loads[tgt])
        loads[src] -= delta
        loads[tgt] += delta
        parcels[idx][0] = m - delta
        parcels.append([delta, tgt, reps])
    return loads


def _sharded_union(num_experts: int, top_k: int, ns, counts, norm_ws,
                   affinity: float, replica_groups=None,
                   capacity=None) -> dict:
    """Core per-shard curve over pre-normalized profiles (see
    `expected_unique_experts_sharded` for the derivation and the public
    normalizing entry point). `replica_groups` (from
    `ExpertPlacement.replication_groups`) applies the min-over-replicas
    relief after the primary-home curve; `capacity` bounds what the relief
    may land on each shard (residency headroom, see
    `_rebalance_replicas`)."""
    s_n = len(counts)
    total = sum(ns)
    if num_experts == 0 or total == 0:
        return {"per_shard": [0.0] * s_n, "union": 0.0, "max_shard": 0.0,
                "hot_shard": 0, "n_shards": s_n}
    k = float(min(top_k, num_experts))
    per_shard = []
    for s in range(s_n):
        e_s = float(counts[s])
        if e_s <= 0:           # replica-only shard: no homed population
            per_shard.append(0.0)
            continue
        untouched, mass = 1.0, 0.0
        for i, n in enumerate(ns):
            if n <= 0:
                continue
            q = min(k * norm_ws[i][s] / e_s, 1.0)
            untouched *= (1.0 - q) ** n
            mass += n * norm_ws[i][s]
        rand = e_s * (1.0 - untouched)
        floor = min(k * (mass / total), e_s)
        val = floor + (rand - floor) * (1.0 - affinity)
        per_shard.append(min(max(val, 0.0), e_s))
    if replica_groups:
        per_shard = _rebalance_replicas(per_shard, counts, replica_groups,
                                        capacity)
    hot = _hot_shard(per_shard)
    return {"per_shard": per_shard, "union": sum(per_shard),
            "max_shard": per_shard[hot], "hot_shard": hot, "n_shards": s_n}


def expected_unique_experts_sharded(num_experts: int, top_k: int,
                                    tokens_per_request,
                                    placement: Optional[ExpertPlacement],
                                    affinity: float = 0.0,
                                    shard_weights=None,
                                    capacity=None) -> dict:
    """Per-EP-shard expected distinct-expert activations for B requests
    jointly verifying sum(n_i) tokens in one shared pass.

    Per-expert occupancy with per-request shard profiles: request i routes a
    fraction `shard_weights[i][s]` of its expert picks to shard s (default:
    proportional to the shard's resident population E_s/E — uniform
    routing), spread uniformly over the shard's E_s local experts, so one of
    its tokens leaves a given expert on s untouched with probability
    (1 - k*w_is/E_s). Shard s's random-routing union is then
        rand_s = E_s * (1 - prod_i (1 - k*w_is/E_s)^{n_i}),
    damped toward the affinity floor k * (s's share of the routed mass)
    exactly as `expected_unique_experts` damps the global curve. Under
    uniform profiles the shards partition the global curve
    (sum_s rand_s == E*(1-(1-k/E)^T)); skewed profiles concentrate it — the
    hottest shard's count grows while the total shrinks, which is the whole
    point: the *max* over shards gates a sharded verification pass.

    Returns per_shard [S], union (= sum over shards, the placement-
    consistent global union), max_shard, hot_shard, n_shards. Degrades
    float-exactly to `expected_unique_experts_batch` at n_shards=1 /
    placement=None (delegation, not re-derivation)."""
    ns = [max(int(n), 0) for n in tokens_per_request]
    if placement is not None:
        placement.validate_experts(num_experts)
    if placement is None or placement.n_shards == 1:
        u = expected_unique_experts_batch(num_experts, top_k, ns,
                                          affinity)["union"]
        return {"per_shard": [u], "union": u, "max_shard": u,
                "hot_shard": 0, "n_shards": 1}
    counts = placement.counts
    norm_ws = _normalized_shard_weights(counts, len(ns), shard_weights)
    return _sharded_union(num_experts, top_k, ns, counts, norm_ws, affinity,
                          replica_groups=placement.replication_groups
                          if placement.has_replication else None,
                          capacity=capacity)


def a2a_bytes(cfg, n_tokens: int, n_shards: int, wb: int = None) -> float:
    """All-to-all dispatch volume of one EP-sharded pass: each in-flight
    token's k expert inputs cross shards with probability (S-1)/S, once out
    and once back, per MoE layer (the Switch/GShard pattern
    `distributed/expert_parallel.py` implements). The wire carries
    *activations* (d_model vectors), which stay at dense precision even
    under quantized experts — `wb=None` resolves to `Precision.DEFAULT
    .dense`, not to the expert class."""
    if not cfg.is_moe or n_shards <= 1 or n_tokens <= 0:
        return 0.0
    if wb is None:
        wb = Precision.DEFAULT.dense
    n_moe = sum(1 for kk in cfg.layer_kinds() if kk in ("A", "X"))
    return (2.0 * n_tokens * cfg.experts_per_token * cfg.d_model * wb
            * (n_shards - 1) / n_shards * n_moe)


def _a2a_time(cfg, hw: "Hardware", n_tokens: int, n_shards: int,
              wb: int = None) -> float:
    """Seconds the collective adds to the pass: per-shard egress (the total
    volume spreads across S links) over the interconnect bandwidth.
    Hardware without an interconnect figure cannot host a multi-shard
    placement — this used to silently fall back to HBM bandwidth, which
    priced the collective absurdly cheap on ici-less parts like
    `RTX_6000_ADA`; now it is an explicit error."""
    if n_shards <= 1:
        return 0.0
    if hw.ici_bw <= 0:
        raise ValueError(
            f"hardware {hw.name!r} has no interconnect (ici_bw=0) but the "
            f"placement spans {n_shards} shards; give the Hardware an "
            "ici_bw figure to price multi-shard all-to-all")
    return a2a_bytes(cfg, n_tokens, n_shards, wb) / (hw.ici_bw * n_shards)


# --------------------------------------------------------------------- #
# Per-iteration bytes / flops
# --------------------------------------------------------------------- #

def _per_layer_weight_bytes(cfg, precision: Precision):
    """(attention_bytes, dense_ffn_bytes, one_expert_bytes, shared_bytes).

    Per tensor class: attention/router/dense-FFN price at `precision
    .dense`; routed experts at `precision.expert` (the quantization
    target); shared experts are read every pass like dense FFN and stay at
    dense precision (the quantized path quantizes ROUTED experts only)."""
    attn = cfg._attn_params() * precision.dense
    mult = 3 if cfg.activation == "swiglu" else 2
    if cfg.is_moe:
        expert = mult * cfg.d_model * cfg.moe_d_ff * precision.expert
        shared = (mult * cfg.d_model * cfg.moe_d_ff * cfg.num_shared_experts
                  * precision.dense)
        router = cfg.d_model * cfg.num_experts * precision.dense
        return attn + router, 0, expert, shared
    return attn, mult * cfg.d_model * cfg.d_ff * precision.dense, 0, 0


def kv_bytes_per_token(cfg, wb: int) -> float:
    """KV-cache bytes appended per token per layer (`wb` = the precision
    spec's `kv` class)."""
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * wb
    if cfg.attention_free:
        return 0.0
    return 2 * cfg.num_kv_heads * cfg.head_dim * wb


def _weight_read_bytes(cfg, precision: Precision) -> float:
    """Dense weight bytes read once per iteration regardless of batch:
    attention + dense/shared FFN + router + unembedding (expert bytes are
    accounted separately — they scale with the activated-expert union)."""
    kinds = cfg.layer_kinds()
    wb = precision.dense
    attn_b, ffn_b, expert_b, shared_b = _per_layer_weight_bytes(cfg,
                                                                precision)
    del expert_b
    weights = 0.0
    for k in kinds:
        if k in ("A", "X"):
            weights += attn_b + ffn_b
            if k == "X":
                weights += attn_b  # cross-attention weights
            if cfg.is_moe:
                weights += shared_b
        elif k == "R":
            weights += cfg._rglru_layer_params() * wb + ffn_b
            if not ffn_b:  # hybrid is dense-ffn
                weights += 3 * cfg.d_model * cfg.d_ff * wb
        elif k == "W":
            weights += cfg._rwkv_layer_params() * wb
    # unembedding is read every iteration; embedding read is per-token rows
    weights += cfg.vocab_size * cfg.d_model * wb
    return weights


def _expert_read_bytes(cfg, unique_experts: float,
                       precision: Precision) -> float:
    """Expert weight bytes for `unique_experts` activated per MoE layer —
    priced at the spec's `expert` class, the term quantization shrinks."""
    if not cfg.is_moe:
        return 0.0
    _, _, expert_b, _ = _per_layer_weight_bytes(cfg, precision)
    n_moe = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    return n_moe * min(unique_experts, cfg.num_experts) * expert_b


def _kv_read_bytes(cfg, context_len: int, window: int,
                   precision: Precision) -> float:
    """Per-request state read: KV cache rows (windowed layers read only the
    window) plus recurrent-state reads."""
    kv_read = 0.0
    for k in cfg.layer_kinds():
        if k in ("A", "X"):
            lw = window
            if cfg.layer_pattern and k == "A":
                lw = cfg.local_window
            ctx = context_len if not lw else min(context_len, lw)
            kv_read += ctx * kv_bytes_per_token(cfg, precision.kv)
        elif k == "W":
            kv_read += cfg.rwkv_num_heads * cfg.rwkv_head_size ** 2 * 4
        elif k == "R":
            kv_read += cfg.d_rnn * 4
    return kv_read


def iteration_bytes(cfg, n_tokens: int, context_len: int,
                    unique_experts: float = None, affinity: float = 0.0,
                    window: int = 0, wb: int = None,
                    precision: Optional[Precision] = None) -> dict:
    """HBM bytes moved by one target-model iteration processing `n_tokens`
    in-flight tokens against a `context_len`-token KV cache. `precision`
    prices each tensor class (`wb` kept as a legacy uniform override)."""
    p = _resolve_precision(precision, wb)
    if cfg.is_moe and unique_experts is None:
        unique_experts = expected_unique_experts(
            cfg.num_experts, cfg.experts_per_token, n_tokens, affinity)

    weights = _weight_read_bytes(cfg, p)
    experts = _expert_read_bytes(cfg, unique_experts or 0.0, p)
    kv_read = _kv_read_bytes(cfg, context_len, window, p)

    return {"weights": weights, "experts": experts, "kv": kv_read,
            "total": weights + experts + kv_read,
            "unique_experts": unique_experts or 0.0}


def iteration_flops(cfg, n_tokens: int, context_len: int,
                    window: int = 0) -> float:
    """Approximate FLOPs of one iteration over n_tokens in-flight tokens."""
    active = cfg.active_param_count()
    flops = 2.0 * active * n_tokens
    # attention over the cache
    kinds = cfg.layer_kinds()
    for k in kinds:
        if k in ("A", "X"):
            lw = cfg.local_window if (cfg.layer_pattern and k == "A") else window
            ctx = context_len if not lw else min(context_len, lw)
            hd = cfg.head_dim if not cfg.use_mla else cfg.kv_lora_rank + cfg.qk_rope_dim
            flops += 4.0 * n_tokens * ctx * cfg.num_heads * hd
    return flops


# --------------------------------------------------------------------- #
# Iteration time
# --------------------------------------------------------------------- #

def iteration_time(cfg, hw: Hardware, n_tokens: int, context_len: int,
                   unique_experts: float = None, affinity: float = 0.0,
                   window: int = 0, fixed_overhead: float = 2e-4,
                   precision: Optional[Precision] = None) -> dict:
    """Seconds for one target iteration. max(memory, compute) + overhead —
    single-batch decode is deep in the memory-bound regime, so the memory
    term dominates everywhere the paper (and we) evaluate."""
    b = iteration_bytes(cfg, n_tokens, context_len, unique_experts,
                        affinity, window, precision=precision)
    f = iteration_flops(cfg, n_tokens, context_len, window)
    t_mem = b["total"] / hw.hbm_bw
    t_compute = f / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead
    return {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
            "bytes": b["total"], "expert_bytes": b["experts"],
            "flops": f, "unique_experts": b["unique_experts"]}


def _fetch_time(residency, hw: Hardware, per_shard_active, per_shard_miss,
                fetch_hide: float):
    """Host->HBM fetch pricing of one pass under a residency tier
    (docs/offload.md): `miss_s` host-tier experts missing from shard s's
    HBM must cross the host link before the shard can stream them. Shards
    fetch over independent links, so the pass-level fetch time is the max
    over shards; `fetch_hide` seconds of it overlap work the pass performs
    anyway (the draft+sample window the prefetcher uses), leaving
    `t_unhidden` on the critical path. Misses come measured
    (`per_shard_miss`, [S]) or from the residency's analytic miss curve
    over the per-shard activated counts. The ONE implementation shared by
    `batch_iteration_time` and `BatchCostOracle.t_batch` so the two stay
    float-exact. Returns (miss [S], t_fetch, t_unhidden)."""
    if hw.host_bw <= 0:
        raise ValueError(
            f"hardware {hw.name!r} has no host link (host_bw=0) but the "
            "placement has host-tier experts; give the Hardware a host_bw "
            "figure to price offload fetches")
    if per_shard_miss is not None:
        miss = [max(float(m), 0.0) for m in per_shard_miss]
        if len(miss) != len(per_shard_active):
            raise ValueError(f"{len(miss)} miss counts vs "
                             f"{len(per_shard_active)} shards")
    else:
        miss = residency.expected_misses(per_shard_active)
    t_fetch = max(miss) * residency.expert_bytes / hw.host_bw
    t_unhidden = t_fetch - fetch_hide
    if t_unhidden < 0.0:
        t_unhidden = 0.0
    return miss, t_fetch, t_unhidden


def moe_hide_fracs(cfg) -> list:
    """Per-MoE-layer fraction of a pass that runs before that layer's FFN
    first reads expert weights: (layer_index + 0.5) / n_layers for each
    MoE layer, in stack order (the +0.5: expert weights are consumed by
    the FFN sub-layer, roughly half a layer after its attention block
    starts). `fracs[0]` is PR 7's `pre_moe_frac`; the full list is the
    layered fetch pipeline's compute-overlap ladder — layer l's slices
    have until frac_l of the pass to arrive, not just the pass start
    (docs/offload.md, layered streaming). Monotone in l by construction."""
    kinds = cfg.layer_kinds()
    moe_idx = [i for i, k in enumerate(kinds) if k in ("A", "X")]
    if not moe_idx or not cfg.is_moe:
        return []
    return [(i + 0.5) / len(kinds) for i in moe_idx]


def fetch_hide_schedule(cfg, base: float, t_basis: float) -> list:
    """Per-MoE-layer fetch-hide windows [L]: layer l's staged fetches
    overlap the shared `base` window (draft+sample, plus any double-buffer
    credit from the previous pass's tail) AND the cumulative compute of
    the layers ahead of l in the current pass — `frac_l * t_basis`, with
    `t_basis` the pass's fetch-free priced floor. This is the schedule
    `batch_iteration_time`/`BatchCostOracle` price layered fetches
    against and the engine's prefetch stage measures with; it is
    nondecreasing in l (deeper layers hide more), which a tier-1 test
    pins."""
    return [base + f * t_basis for f in moe_hide_fracs(cfg)]


def fetch_time_layered(residency, hw: Hardware, per_shard_active,
                       per_shard_miss, fetch_hide, staged_per_shard=None):
    """Host->HBM fetch pricing generalized to the residency's granularity
    (docs/offload.md, layered streaming).

    Under granularity="expert" this delegates verbatim to `_fetch_time` —
    same expressions, same float-op order, so whole-expert pricing is
    bit-identical to PR 7's (`fetch_hide` must be the scalar window).

    Under granularity="layer" the fetch is a layer pipeline: shard s must
    have layer l's missing slices across the link before layer l's FFN
    runs, but everything fetched for layer l overlaps the compute of
    layers < l. With R_{s,l} = cumulative fetch seconds of layers <= l on
    shard s's independent link and hide_l the per-layer window
    (`fetch_hide` a scalar — replicated — or a length-L schedule from
    `fetch_hide_schedule`):

        R_{s,l}    = (sum_{j<=l} miss_{s,j}) * unit_bytes / host_bw
        t_unhidden = max(0, max_{s,l} (R_{s,l} - hide_eff_l))
        t_fetch    = max_s R_{s,L-1}

    Misses come measured (`per_shard_miss`, [S] rows of [L] per-layer
    counts) or from the residency's analytic
    `expected_layer_misses(per_shard_active)`. `staged_per_shard` ([S]
    rows of [L] staged unit counts, engine-measured) caps the credit
    honestly, exactly like PR 7's scalar cap: layer l's window cannot
    exceed the link time of the bytes actually staged for layers <= l —
    hide_eff_l = min(hide_l, max_s(cum_staged_{s,l}) * unit_bytes /
    host_bw) — because demand misses are discovered at routing time
    inside the pass and can never borrow the overlap. The analytic
    callers (oracle, planner) pass None and price the uncapped schedule.

    The ONE implementation shared by `batch_iteration_time` and
    `BatchCostOracle.t_batch` in layer mode, keeping the two float-exact.
    Returns (miss_totals [S], t_fetch, t_unhidden, info) with
    info = {"t_fetch_by_layer": [L], "miss_by_layer": [S][L]} (info is
    None under granularity="expert")."""
    granularity = getattr(residency, "granularity", "expert")
    if granularity != "layer":
        if not isinstance(fetch_hide, (int, float)):
            raise ValueError(
                "a fetch_hide schedule needs granularity='layer' "
                "residency units; whole-expert residency prices one "
                "scalar window")
        miss, t_fetch, t_unhid = _fetch_time(residency, hw,
                                             per_shard_active,
                                             per_shard_miss, fetch_hide)
        return miss, t_fetch, t_unhid, None
    if hw.host_bw <= 0:
        raise ValueError(
            f"hardware {hw.name!r} has no host link (host_bw=0) but the "
            "placement has host-tier experts; give the Hardware a host_bw "
            "figure to price offload fetches")
    n_l = residency.n_unit_layers
    if isinstance(fetch_hide, (int, float)):
        hide = [float(fetch_hide)] * n_l
    else:
        hide = [float(h) for h in fetch_hide]
        if len(hide) != n_l:
            raise ValueError(f"{len(hide)} fetch-hide windows vs "
                             f"{n_l} MoE layers")
    if per_shard_miss is not None:
        if len(per_shard_miss) != len(per_shard_active):
            raise ValueError(f"{len(per_shard_miss)} miss rows vs "
                             f"{len(per_shard_active)} shards")
        miss = []
        for row in per_shard_miss:
            row = [max(float(m), 0.0) for m in row]
            if len(row) != n_l:
                raise ValueError(f"{len(row)} per-layer miss counts vs "
                                 f"{n_l} MoE layers")
            miss.append(row)
    else:
        miss = residency.expected_layer_misses(per_shard_active)
    ub, bw = residency.expert_bytes, hw.host_bw
    # honest staged-bytes cap on the window, cumulative through layer l
    # (a layer's credit can ride on earlier layers' staged bytes — the
    # link drains in nomination order — but never on bytes nobody staged)
    cap = None
    if staged_per_shard is not None:
        cum = []
        for row in staged_per_shard:
            c, tot = [], 0.0
            for v in row:
                tot += float(v)
                c.append(tot)
            cum.append(c)
        cap = [max(cum[s][lyr] for s in range(len(cum))) * ub / bw
               for lyr in range(n_l)]
    hide_eff = (hide if cap is None else
                [min(h, c) for h, c in zip(hide, cap)])
    t_fetch = 0.0
    t_unhid = 0.0
    t_by_layer = [0.0] * n_l
    miss_tot = []
    for s, row in enumerate(miss):
        c = 0.0
        r_last = 0.0
        for lyr, m in enumerate(row):
            c += m
            r = c * ub / bw
            slack = r - hide_eff[lyr]
            if slack > t_unhid:
                t_unhid = slack
            lt = m * ub / bw
            if lt > t_by_layer[lyr]:
                t_by_layer[lyr] = lt
            r_last = r
        if r_last > t_fetch:
            t_fetch = r_last
        miss_tot.append(c)
    return miss_tot, t_fetch, t_unhid, {"t_fetch_by_layer": t_by_layer,
                                        "miss_by_layer": miss}


def batch_iteration_time(cfg, hw: Hardware, tokens_per_request,
                         context_lens, *, unique_experts: float = None,
                         per_request_unique=None, affinity: float = 0.0,
                         window: int = 0, fixed_overhead: float = 2e-4,
                         prefill_tokens=None,
                         placement: Optional[ExpertPlacement] = None,
                         shard_weights=None, per_shard_unique=None,
                         assume_balanced: bool = False,
                         calibration: Optional[Calibration] = None,
                         residency=None, per_shard_miss=None,
                         fetch_hide=0.0, staged_per_shard=None,
                         precision: Optional[Precision] = None) -> dict:
    """Seconds for one *shared* verification pass over B requests, request i
    contributing n_i = tokens_per_request[i] in-flight tokens against its own
    context_lens[i]-token KV cache.

    The batch moves: dense weights ONCE (the whole point of batching), the
    *union* of activated expert weights (the paper's data-movement driver,
    now across requests), and each request's own KV rows. `unique_experts`
    overrides the analytic union with a measured per-layer mean; at B=1 with
    identical inputs this reduces exactly to `iteration_time`.

    Per-request attribution ("marginal-bytes split", consumed by each
    request's Cascade controller so per-request utility stays meaningful
    under shared verification):
      * KV bytes       -> owned outright by the request;
      * expert bytes   -> split in proportion to each request's marginal
                          expert contribution m_i = union(all) -
                          union(all \\ i) (or to measured per-request unique
                          counts when `per_request_unique` is given);
      * dense weights + fixed overhead -> split evenly — every request needs
                          the full read, the batch amortizes it.
    sum_i(t_attr_i) == t_iter by construction.

    `prefill_tokens` ([B] ints, default all-zero) marks how many of each
    request's in-flight tokens are co-scheduled prompt-chunk tokens. They
    add the same terms `prefill_time` prices for blocking admission — the
    chunk's KV *writes*, its embedding-row reads, and causal attention over
    itself — so chunked and blocking prefill tick the model clock on
    commensurable units (a decode span's single-span KV append stays
    negligible and unpriced, as before).

    Expert parallelism (`placement` with n_shards > 1, docs/expert_parallel
    .md): the expert term is no longer the global union — each shard
    streams only its resident experts, the pass completes when the
    *hottest* shard has streamed its local activated set, and the
    all-to-all dispatch adds interconnect time. Per-shard activated counts
    come from `per_shard_unique` (measured, [S]) or the analytic
    `expected_unique_experts_sharded` under `shard_weights` per-request
    routing profiles; `assume_balanced=True` is the deliberately naive
    comparator that spreads the union evenly over shards (the
    "global-union" model the --ep-sweep gates against — it under-prices
    skewed routing). `placement=None` / n_shards=1 degrades bit-exactly to
    the unsharded model above.

    Residency (`residency`, a `ResidencyState` over a host-tiered
    placement, docs/offload.md): activated host-tier experts missing from
    HBM add a non-overlapped host-fetch term — `t_fetch_unhidden`, the max
    over shards of miss-count * expert_bytes / host_bw minus the
    `fetch_hide` overlap window — applied AFTER calibration (the
    calibration was fit on fetch-free passes). `per_shard_miss` ([S])
    overrides the analytic miss curve with measured counts, the residency
    analogue of `per_shard_unique`. `residency=None` (or an all-hbm
    placement) is bit-identical to the fetch-free model.

    A `granularity="layer"` residency switches the fetch term to the
    layer-pipelined schedule (`fetch_time_layered`): `fetch_hide` may
    then be a per-MoE-layer sequence (`fetch_hide_schedule`),
    `per_shard_miss` becomes [S] rows of [L] per-layer measured counts,
    and `staged_per_shard` ([S][L] staged unit counts) caps the window at
    the bytes actually prefetched, per layer — the honest-credit rule PR
    7 applied as one scalar. The result gains `t_fetch_by_layer`.

    Returns iteration_time's keys plus `per_request` (list of dicts with
    t_attr / bytes_attr / marginal_experts) and `n_requests`; sharded
    passes additionally report `shard_unique` [S], `max_shard_experts`,
    `hot_shard`, `imbalance` (max/mean over shards), `t_a2a`, and
    `n_shards`; residency-priced passes additionally report `fetch_miss`
    [S], `t_fetch`, `t_fetch_unhidden`, and `fetch_bytes`.

    `precision` (a `Precision` spec, docs/quantization.md) prices each
    tensor class separately — quantized experts shrink the expert term
    (and with it the roofline crossover) while dense/KV bytes stand.
    `precision=None` is bit-identical to `Precision.DEFAULT` (all 2s)."""
    p = _resolve_precision(precision)
    ns = [max(int(n), 0) for n in tokens_per_request]
    cls = list(context_lens)
    if len(ns) != len(cls):
        raise ValueError(f"{len(ns)} token counts vs {len(cls)} contexts")
    b_req = len(ns)
    total_tokens = sum(ns)
    ps = ([0] * b_req if prefill_tokens is None else
          [max(int(p), 0) for p in prefill_tokens])
    if len(ps) != b_req:
        raise ValueError(f"{len(ps)} prefill counts vs {b_req} requests")

    est = expected_unique_experts_batch(
        cfg.num_experts, cfg.experts_per_token, ns, affinity) \
        if cfg.is_moe else {"union": 0.0, "marginal": [0.0] * b_req}
    union = est["union"] if unique_experts is None else float(unique_experts)

    weights = _weight_read_bytes(cfg, p)
    sharded = (placement is not None and placement.n_shards > 1
               and cfg.is_moe)
    fetch_active = (residency is not None and cfg.is_moe
                    and residency.has_host_tier)
    capacity = residency.capacity_experts if fetch_active else None
    shard_info = {}
    if sharded:
        # the hottest shard gates the pass: its local activated experts are
        # the expert stream on the critical path, not the global union
        shard_unique, hot = _resolve_shard_unique(
            cfg, ns, placement, affinity, shard_weights, per_shard_unique,
            capacity=capacity)
        gate = (sum(shard_unique) / placement.n_shards if assume_balanced
                else shard_unique[hot])
        experts = _expert_read_bytes(cfg, gate, p)
        t_a2a = _a2a_time(cfg, hw, total_tokens, placement.n_shards,
                          p.dense)
        mean_shard = sum(shard_unique) / placement.n_shards
        shard_info = {
            "shard_unique": shard_unique,
            "max_shard_experts": shard_unique[hot],
            "hot_shard": hot,
            "imbalance": (shard_unique[hot] / mean_shard
                          if mean_shard > 0 else 1.0),
            "t_a2a": t_a2a, "n_shards": placement.n_shards,
        }
    else:
        experts = _expert_read_bytes(cfg, union, p)
        t_a2a = 0.0
    n_attn = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    prefill_bytes_per_tok = (kv_bytes_per_token(cfg, p.kv) * n_attn
                             + cfg.d_model * p.dense)  # KV write + embed row
    kv_each = [_kv_read_bytes(cfg, c, window, p)
               + pt * prefill_bytes_per_tok if n > 0 else 0.0
               for n, c, pt in zip(ns, cls, ps)]
    total_bytes = weights + experts + sum(kv_each)

    flops = sum(iteration_flops(cfg, n, c + pt, window)
                for n, c, pt in zip(ns, cls, ps) if n > 0)
    t_mem = total_bytes / hw.hbm_bw
    t_compute = flops / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead
    if sharded:
        t = t + t_a2a
    if calibration is not None:
        # prediction-side wall-clock correction; None is bit-identical
        t = calibration.apply(t, t_a2a)
    fetch_info = {}
    if fetch_active:
        # non-overlapped host fetch rides on top of the calibrated pass:
        # the calibration was fit on fetch-free passes, so the fetch term
        # must not be scaled by it
        act = shard_info["shard_unique"] if sharded else [union]
        if getattr(residency, "granularity", "expert") == "layer":
            f_miss, t_fetch, t_unhid, lay = fetch_time_layered(
                residency, hw, act, per_shard_miss, fetch_hide,
                staged_per_shard)
        else:
            f_miss, t_fetch, t_unhid = _fetch_time(residency, hw, act,
                                                   per_shard_miss,
                                                   fetch_hide)
            lay = None
        t = t + t_unhid
        fetch_info = {"fetch_miss": f_miss, "t_fetch": t_fetch,
                      "t_fetch_unhidden": t_unhid,
                      "fetch_bytes": sum(f_miss) * residency.expert_bytes}
        if lay is not None:
            fetch_info["t_fetch_by_layer"] = list(lay["t_fetch_by_layer"])

    # ---- marginal-bytes attribution -------------------------------------
    # non-bytes terms (fixed overhead + the sharded pass's collective) are
    # split evenly — every live request needs them, none owns them
    non_bytes = fixed_overhead + t_a2a if sharded else fixed_overhead
    if fetch_active:
        non_bytes = non_bytes + fetch_info["t_fetch_unhidden"]
    live = [i for i, n in enumerate(ns) if n > 0]
    n_live = max(len(live), 1)
    if per_request_unique is not None:
        mweights = [max(float(u), 0.0) for u in per_request_unique]
    else:
        mweights = est["marginal"]
    msum = sum(mweights[i] for i in live)
    per_request = []
    for i, n in enumerate(ns):
        if n <= 0:
            per_request.append({"t_attr": 0.0, "bytes_attr": 0.0,
                                "marginal_experts": 0.0})
            continue
        if len(live) == 1:
            # sole live request owns the pass outright (bit-exact reduction
            # to iteration_time — no float round-trip through the split)
            per_request.append({"t_attr": t, "bytes_attr": total_bytes,
                                "marginal_experts": est["marginal"][i]})
            continue
        frac_e = (mweights[i] / msum) if msum > 0 else 1.0 / n_live
        bytes_i = weights / n_live + experts * frac_e + kv_each[i]
        t_attr = ((t - non_bytes) * bytes_i / total_bytes
                  if total_bytes > 0 else 0.0) + non_bytes / n_live
        per_request.append({"t_attr": t_attr, "bytes_attr": bytes_i,
                            "marginal_experts": est["marginal"][i]})

    out = {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
           "bytes": total_bytes, "expert_bytes": experts, "flops": flops,
           "unique_experts": union, "n_requests": b_req,
           "n_tokens": total_tokens, "per_request": per_request,
           "precision": p.label,
           # bytes the expert stream saved vs pricing it at the bf16
           # default (exact: expert bytes are linear in bytes-per-param)
           "expert_bytes_saved": (experts
                                  * (Precision.DEFAULT.expert - p.expert)
                                  / p.expert)}
    out.update(shard_info)
    out.update(fetch_info)
    return out


def _resolve_shard_unique(cfg, ns, placement: ExpertPlacement,
                          affinity: float, shard_weights,
                          per_shard_unique, capacity=None):
    """Per-shard activated-expert counts for a sharded pass: measured
    counts when the caller has them, the analytic sharded union otherwise.
    Returns (shard_unique [S], hot_shard). Ties break on the lowest shard
    id, keeping the gating shard deterministic. `capacity` bounds the
    analytic replica relief to shards with residency headroom."""
    if per_shard_unique is not None:
        shard_unique = [max(float(u), 0.0) for u in per_shard_unique]
        if len(shard_unique) != placement.n_shards:
            raise ValueError(f"{len(shard_unique)} shard counts vs "
                             f"{placement.n_shards} shards")
        return shard_unique, _hot_shard(shard_unique)
    est = expected_unique_experts_sharded(
        cfg.num_experts, cfg.experts_per_token, ns, placement,
        affinity, shard_weights, capacity=capacity)
    return est["per_shard"], est["hot_shard"]


class BatchCostOracle:
    """Repeated `batch_iteration_time` total-time queries over candidate
    token allocations, with everything except `tokens_per_request` held
    fixed (contexts, prefill chunks, hardware, affinity).

    The batch planner's water-filling evaluates O(B * k_max) candidate
    allocations per engine step; re-running the full attribution split for
    each would be wasteful, so this caches the allocation-independent terms
    (dense weight read, per-row KV/prefill bytes) at construction.
    `t_batch(ns)` returns exactly `batch_iteration_time(...)["t_iter"]` for
    the same inputs — same expressions, same float-op order — which a
    tier-1 property test pins down.

    `placement` (n_shards > 1) switches the pricing to the EP-sharded
    roofline: max over shards of local activated-expert bytes plus the
    all-to-all collective, under per-row `shard_weights` routing profiles
    (None entries -> uniform). `assume_balanced=True` keeps the placement's
    shard count but spreads the union evenly — the global-union comparator
    planner of docs/expert_parallel.md. Both agree float-exactly with
    `batch_iteration_time` under the same arguments.

    `residency` (a `ResidencyState` over a host-tiered placement) adds the
    analytic non-overlapped fetch term under a `fetch_hide` overlap window
    — same `_fetch_time` implementation as `batch_iteration_time` (and
    the same `fetch_time_layered` under a granularity="layer" residency,
    where `fetch_hide` is the per-MoE-layer schedule), so the
    float-exactness contract extends to fetch-priced passes at both
    granularities. The planner's residency constraints query
    `shard_unique(ns)` / `fetch_unhidden(ns)` for the cap and deadline
    checks (docs/offload.md)."""

    def __init__(self, cfg, hw: Hardware, context_lens, *,
                 affinity: float = 0.0, window: int = 0,
                 fixed_overhead: float = 2e-4, prefill_tokens=None,
                 placement: Optional[ExpertPlacement] = None,
                 shard_weights=None, assume_balanced: bool = False,
                 calibration: Optional[Calibration] = None,
                 residency=None, fetch_hide: float = 0.0,
                 precision: Optional[Precision] = None):
        p = _resolve_precision(precision)
        self.precision = p
        self.calibration = calibration
        self.cfg = cfg
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.fixed_overhead = fixed_overhead
        self.cls = list(context_lens)
        b = len(self.cls)
        self.ps = ([0] * b if prefill_tokens is None else
                   [max(int(p), 0) for p in prefill_tokens])
        if len(self.ps) != b:
            raise ValueError(f"{len(self.ps)} prefill counts vs {b} contexts")
        self.placement = placement
        self.assume_balanced = assume_balanced
        self._sharded = (placement is not None and placement.n_shards > 1
                         and cfg.is_moe)
        if placement is not None and cfg.is_moe:
            placement.validate_experts(cfg.num_experts)
        self.residency = residency
        #: overlap window the fetch term hides behind — one scalar under
        #: granularity="expert", a per-MoE-layer schedule (list, from
        #: `fetch_hide_schedule`) under granularity="layer"
        self.fetch_hide = fetch_hide
        self._fetch = (residency is not None and cfg.is_moe
                       and residency.has_host_tier)
        self._layered = (self._fetch and
                         getattr(residency, "granularity", "expert")
                         == "layer")
        if self._fetch and hw.host_bw <= 0:
            raise ValueError(
                f"hardware {hw.name!r} has no host link (host_bw=0) but "
                "the placement has host-tier experts")
        self._capacity = residency.capacity_experts if self._fetch else None
        if shard_weights is not None and len(shard_weights) != b:
            raise ValueError(f"{len(shard_weights)} shard profiles vs "
                             f"{b} contexts")
        self.shard_weights = shard_weights
        if self._sharded:
            # allocation-independent shard constants, cached like the
            # dense-weight and per-row KV terms: the water-filling queries
            # t_batch O(B*K) times per step and must not re-derive the
            # placement's counts or re-normalize B profiles each time
            self._counts = placement.counts
            self._norm_sw = _normalized_shard_weights(self._counts, b,
                                                      shard_weights)
            self._replica_groups = (placement.replication_groups
                                    if placement.has_replication else None)
        self._weights = _weight_read_bytes(cfg, p)
        n_attn = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
        prefill_bytes_per_tok = (kv_bytes_per_token(cfg, p.kv) * n_attn
                                 + cfg.d_model * p.dense)
        # per-row bytes IF the row is live (n_i > 0); dead rows cost nothing
        self._kv_live = [_kv_read_bytes(cfg, c, window, p)
                         + pt * prefill_bytes_per_tok
                         for c, pt in zip(self.cls, self.ps)]

    def t_batch(self, tokens_per_request) -> float:
        """Seconds for one shared pass at this token allocation (scalar —
        no attribution; use `batch_iteration_time` for the full split)."""
        ns = [max(int(n), 0) for n in tokens_per_request]
        if len(ns) != len(self.cls):
            raise ValueError(f"{len(ns)} token counts vs "
                             f"{len(self.cls)} contexts")
        cfg, hw = self.cfg, self.hw
        total = sum(ns)
        if self._sharded:
            est = _sharded_union(cfg.num_experts, cfg.experts_per_token,
                                 ns, self._counts, self._norm_sw,
                                 self.affinity,
                                 replica_groups=self._replica_groups,
                                 capacity=self._capacity)
            gate = (sum(est["per_shard"]) / self.placement.n_shards
                    if self.assume_balanced else est["max_shard"])
            experts = _expert_read_bytes(cfg, gate, self.precision)
        else:
            union = (expected_unique_experts(cfg.num_experts,
                                             cfg.experts_per_token, total,
                                             self.affinity)
                     if cfg.is_moe and total > 0 else 0.0)
            experts = _expert_read_bytes(cfg, union, self.precision)
        total_bytes = self._weights + experts + sum(
            kv if n > 0 else 0.0 for n, kv in zip(ns, self._kv_live))
        flops = sum(iteration_flops(cfg, n, c + p, self.window)
                    for n, c, p in zip(ns, self.cls, self.ps) if n > 0)
        t_mem = total_bytes / hw.hbm_bw
        t_compute = flops / hw.peak_flops
        t = max(t_mem, t_compute) + self.fixed_overhead
        if self._sharded:
            t_a2a = _a2a_time(cfg, hw, total, self.placement.n_shards,
                              self.precision.dense)
            t = t + t_a2a
        else:
            t_a2a = 0.0
        if self.calibration is not None:
            t = self.calibration.apply(t, t_a2a)
        if self._fetch:
            act = est["per_shard"] if self._sharded else [union]
            if self._layered:
                _, _, t_unhid, _ = fetch_time_layered(
                    self.residency, hw, act, None, self.fetch_hide)
            else:
                _, _, t_unhid = _fetch_time(self.residency, hw, act, None,
                                            self.fetch_hide)
            t = t + t_unhid
        return t

    def shard_unique(self, tokens_per_request) -> list:
        """Predicted per-shard activated-expert counts at this allocation
        ([S]; the global union as a 1-list for unsharded placements) —
        what `MemoryCapConstraint` checks against the residency capacity."""
        ns = [max(int(n), 0) for n in tokens_per_request]
        cfg = self.cfg
        if self._sharded:
            est = _sharded_union(cfg.num_experts, cfg.experts_per_token,
                                 ns, self._counts, self._norm_sw,
                                 self.affinity,
                                 replica_groups=self._replica_groups,
                                 capacity=self._capacity)
            return list(est["per_shard"])
        total = sum(ns)
        union = (expected_unique_experts(cfg.num_experts,
                                         cfg.experts_per_token, total,
                                         self.affinity)
                 if cfg.is_moe and total > 0 else 0.0)
        return [union]

    def fetch_unhidden(self, tokens_per_request) -> float:
        """Predicted non-overlapped host-fetch seconds at this allocation
        (0.0 without a host tier) — what `FetchDeadlineConstraint` bounds."""
        if not self._fetch:
            return 0.0
        act = self.shard_unique(tokens_per_request)
        if self._layered:
            _, _, t_unhid, _ = fetch_time_layered(self.residency, self.hw,
                                                  act, None,
                                                  self.fetch_hide)
        else:
            _, _, t_unhid = _fetch_time(self.residency, self.hw, act, None,
                                        self.fetch_hide)
        return t_unhid

    def predicted_tpot(self, tokens_per_request, emitted_per_request
                       ) -> list:
        """Per-request predicted TPOT under a candidate allocation: every
        request sharing the pass *waits out the whole pass* (max-over-
        shards priced under a placement) between its token batches, so
        request i's experienced seconds-per-token is t_batch(ns) over its
        own expected emissions. This — not the marginal-bytes cost
        attribution, which deliberately charges a grant's bytes to the
        grantee — is the victim quantity the planner's SLO constraint
        bounds (docs/slo.md): a grant to ANY row lengthens every
        co-scheduled row's predicted TPOT. Rows expected to emit nothing
        this pass (prefill chunks, dead rows) report inf."""
        t = self.t_batch(tokens_per_request)
        return [t / e if e > 0 else float("inf")
                for e in emitted_per_request]


# --------------------------------------------------------------------- #
# Prefill pricing (chunked admission — the compute-bound regime)
# --------------------------------------------------------------------- #

def prefill_chunk_bytes(cfg, n_tokens: int, context_len: int = 0,
                        unique_experts: float = None, affinity: float = 0.0,
                        window: int = 0, wb: int = None,
                        precision: Optional[Precision] = None) -> dict:
    """HBM bytes moved by one prefill chunk of `n_tokens` prompt tokens
    entering a cache that already holds `context_len` tokens.

    Differs from decode `iteration_bytes` in two ways that matter for TTFT:
    the chunk *writes* its own KV rows (decode's single-token append is
    negligible; a 128-token chunk's is not), and the expert union is driven
    by the chunk's full token count, which saturates toward `num_experts`
    far faster than a [1+K] decode span."""
    p = _resolve_precision(precision, wb)
    n_tokens = max(int(n_tokens), 0)
    if cfg.is_moe and unique_experts is None:
        unique_experts = expected_unique_experts(
            cfg.num_experts, cfg.experts_per_token, n_tokens, affinity)
    weights = _weight_read_bytes(cfg, p)
    experts = _expert_read_bytes(cfg, unique_experts or 0.0, p)
    kv_read = _kv_read_bytes(cfg, context_len, window, p)
    n_attn = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    kv_write = n_tokens * kv_bytes_per_token(cfg, p.kv) * n_attn
    embed = n_tokens * cfg.d_model * p.dense  # embedding-row reads per token
    total = weights + experts + kv_read + kv_write + embed
    return {"weights": weights, "experts": experts, "kv": kv_read,
            "kv_write": kv_write, "embed": embed, "total": total,
            "unique_experts": unique_experts or 0.0}


def prefill_time(cfg, hw: Hardware, n_tokens: int, context_len: int = 0,
                 unique_experts: float = None, affinity: float = 0.0,
                 window: int = 0, fixed_overhead: float = 2e-4,
                 precision: Optional[Precision] = None) -> dict:
    """Seconds for one prefill pass/chunk under the model clock. Unlike
    decode, prefill crosses the roofline: FLOPs grow linearly (and the
    attention term quadratically) with the chunk while the dominant weight
    read stays constant, so large chunks are compute-bound — max(memory,
    compute) switches sides, which is exactly why the model clock must price
    prefill separately for TTFT to mean anything."""
    n_tokens = max(int(n_tokens), 1)
    b = prefill_chunk_bytes(cfg, n_tokens, context_len, unique_experts,
                            affinity, window, precision=precision)
    # the chunk attends causally to the cached context plus itself
    f = iteration_flops(cfg, n_tokens, context_len + n_tokens, window)
    t_mem = b["total"] / hw.hbm_bw
    t_compute = f / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead
    return {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
            "bytes": b["total"], "expert_bytes": b["experts"],
            "flops": f, "unique_experts": b["unique_experts"],
            "compute_bound": t_compute >= t_mem}


def prefill_crossover_tokens(cfg, hw: Hardware, context_len: int = 0,
                             affinity: float = 0.0, window: int = 0,
                             max_chunk: int = 65536,
                             precision: Optional[Precision] = None) -> int:
    """Smallest chunk size at which prefill becomes compute-bound (crosses
    the roofline) — the natural upper bound for a chunked-admission `chunk`:
    beyond it, bigger chunks stop amortizing the weight read and only add
    head-of-line latency for the decodes sharing the pass. Quantized expert
    precision moves this crossover LEFT (fewer bytes, same FLOPs) — the
    shift the --quant-sweep gates predicted-vs-measured."""
    n = 1
    while n <= max_chunk:
        if prefill_time(cfg, hw, n, context_len, affinity=affinity,
                        window=window,
                        precision=precision)["compute_bound"]:
            return n
        n *= 2
    return max_chunk


def draft_time(hw: Hardware, k: int, drafter_active_params: int = 0,
               per_token_overhead: float = 2e-5,
               wb: int = None,
               precision: Optional[Precision] = None) -> float:
    """Drafting cost: ~free for n-gram (CPU table lookup), weight-bound for
    model drafters (EAGLE-style). Drafter weights price at the dense class
    of `precision` (docs/quantization.md) — a quantized drafter (e.g.
    `Precision(dense=1, ...)` for int8 drafter storage) halves the model
    term's bytes, shrinking the speculation overhead every utility ratio
    and fetch-hide window is built on. `precision=None` prices at
    `Precision.DEFAULT.dense` (bf16), bit-identical to before; an explicit
    `wb` byte width overrides the precision class, matching the byte
    helpers' precedence."""
    if k <= 0:
        return 0.0
    if wb is None:
        wb = (precision.dense if precision is not None
              else Precision.DEFAULT.dense)
    model = (k * drafter_active_params * wb / hw.hbm_bw
             if drafter_active_params else 0.0)
    return model + k * per_token_overhead


def sample_time(k: int, per_token: float = 1.5e-5) -> float:
    """Rejection-sampling cost, linear in verified tokens (paper: 1-2%)."""
    return (k + 1) * per_token


def expected_emitted(accept_rate: float, k: int) -> float:
    """Expected tokens emitted by a [1+k] speculative span when each draft
    is accepted i.i.d. with probability `accept_rate` — the truncated
    geometric series of paper Def. 4.1's ETR (k=0 -> exactly 1). The one
    implementation shared by the analytic K prior below and the batch
    planner's yield predictions."""
    a = min(max(accept_rate, 0.0), 0.999)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def expected_emitted_curve(curve, k: int) -> float:
    """`expected_emitted` generalized to a per-position acceptance curve
    (`UtilityAnalyzer.accept_curve`): E[emitted] = 1 + sum over depths j of
    P(drafts 1..j all accepted) = 1 + sum_j prod_{p<j} curve[p]. A flat
    curve reproduces the geometric series; a depth-decaying curve tightens
    the deep-draft over-prediction the flat mean makes (the planner's
    `use_accept_curve` flag). Positions past the curve reuse its last
    value; k=0 -> exactly 1."""
    if k <= 0:
        return 1.0
    tot, p = 1.0, 1.0
    for j in range(k):
        c = curve[j] if j < len(curve) else (curve[-1] if curve else 0.0)
        p *= min(max(c, 0.0), 0.999)
        tot += p
    return tot


# --------------------------------------------------------------------- #
# Analytic K prior (beyond-paper): warm-start Cascade's hill-climb
# --------------------------------------------------------------------- #

def expected_utility(cfg, hw: Hardware, k: int, accept_rate: float,
                     context_len: int = 1024, affinity: float = 0.3,
                     drafter_params: int = 0) -> float:
    """Analytic Definition-4.1 utility of speculating K tokens when draft
    acceptance is ~accept_rate: ETR from the truncated geometric series,
    cost from the data-movement model."""
    if k <= 0:
        return 1.0
    etr = expected_emitted(accept_rate, k)
    base = iteration_time(cfg, hw, 1, context_len, affinity=affinity)
    spec = iteration_time(cfg, hw, k + 1, context_len, affinity=affinity)
    t_spec = spec["t_iter"] + draft_time(hw, k, drafter_params) + \
        sample_time(k)
    return etr / (t_spec / base["t_iter"])


def suggest_k_start(cfg, hw: Hardware = TPU_V5E, *,
                    accept_rate: float = 0.5, k_max: int = 8,
                    context_len: int = 1024, affinity: float = 0.3,
                    drafter_params: int = 0) -> int:
    """Bucket-and-balls prior for Cascade's first trial K (beyond-paper):
    instead of a fixed k_start=3, pick the analytic utility-maximizing K
    for this architecture — MoEs with steep expert-activation curves get a
    conservative start, dense models an aggressive one. The test-and-set
    loop still measures and adapts; this only saves test iterations."""
    best_k, best_u = 1, -1.0
    for k in range(1, k_max + 1):
        u = expected_utility(cfg, hw, k, accept_rate, context_len, affinity,
                             drafter_params)
        if u > best_u:
            best_k, best_u = k, u
    return best_k
