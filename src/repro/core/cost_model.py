"""Data-movement cost model for MoE speculative verification (paper §2.4,
adapted from the paper's GPU to our TPU v5e target — DESIGN.md §3).

Single-batch decoding is memory-bandwidth-bound: iteration time is governed
by the bytes fetched from HBM — all attention weights, the *unique* experts
activated by the in-flight tokens, the KV cache read, and the unembedding.
Verifying K+1 tokens multiplies the expert term by the number of unique
experts they collectively activate (bucket-and-balls, damped by expert
affinity), which is exactly why speculation can slow MoEs down.

The same model is used by (1) the serving engine's deterministic virtual
clock on CPU, (2) the paper-figure simulator, and (3) the §Roofline
active-expert correction for MoE decode."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    hbm_bw: float            # bytes/s
    peak_flops: float        # FLOP/s at serving precision
    ici_bw: float = 0.0      # bytes/s per link (TPU interconnect)
    weight_bytes: int = 2    # serving precision (bf16/fp16 = 2)


TPU_V5E = Hardware("tpu-v5e", hbm_bw=819e9, peak_flops=197e12, ici_bw=50e9)
# the paper's workstation GPU (RTX 6000 Ada): ~960 GB/s GDDR6, ~91 TFLOP/s fp16
RTX_6000_ADA = Hardware("rtx-6000-ada", hbm_bw=960e9, peak_flops=91e12)


# --------------------------------------------------------------------- #
# Expert activation statistics (paper §2.4)
# --------------------------------------------------------------------- #

def expected_unique_experts(num_experts: int, top_k: int, n_tokens: int,
                            affinity: float = 0.0) -> float:
    """Expected number of distinct experts activated by `n_tokens` tokens,
    each selecting `top_k` distinct experts.

    affinity=0: uniform-random routing (bucket-and-balls):
        E[unique] = E * (1 - (1 - k/E)^T)
    affinity=1: perfect temporal reuse (all tokens share one expert set).
    The paper observes real tasks fall between the two (§2.4: Mixtral math
    shows 3x instead of the random 3.5x at K=7)."""
    if num_experts == 0:
        return 0.0
    n_tokens = max(int(n_tokens), 1)
    e, k = float(num_experts), float(min(top_k, num_experts))
    rand = e * (1.0 - (1.0 - k / e) ** n_tokens)
    floor = k  # one shared expert set
    return floor + (rand - floor) * (1.0 - affinity)


def expected_unique_experts_batch(num_experts: int, top_k: int,
                                  tokens_per_request, affinity: float = 0.0
                                  ) -> dict:
    """Multi-request extension of `expected_unique_experts`: B requests
    jointly verifying sum(n_i) tokens in one shared pass activate the
    *union* of their expert sets.

    Returns:
        union     — E[unique experts] over all sum(n_i) tokens
        marginal  — per-request marginal contribution,
                    m_i = union(all) - union(all minus request i),
                    the bytes request i adds to the shared verification
                    (the batch-level analogue of the paper's Fig. 2 curve:
                    m_i shrinks as the rest of the batch grows, because the
                    batch has already paid for most of i's experts)."""
    ns = [max(int(n), 0) for n in tokens_per_request]
    total = sum(ns)
    if total <= 0:
        return {"union": 0.0, "marginal": [0.0] * len(ns)}
    union = expected_unique_experts(num_experts, top_k, total, affinity)
    marginal = []
    for n in ns:
        if n <= 0:
            marginal.append(0.0)
        elif total - n <= 0:
            marginal.append(union)
        else:
            marginal.append(union - expected_unique_experts(
                num_experts, top_k, total - n, affinity))
    return {"union": union, "marginal": marginal}


# --------------------------------------------------------------------- #
# Per-iteration bytes / flops
# --------------------------------------------------------------------- #

def _per_layer_weight_bytes(cfg, wb: int):
    """(attention_bytes, dense_ffn_bytes, one_expert_bytes, shared_bytes)."""
    attn = cfg._attn_params() * wb
    mult = 3 if cfg.activation == "swiglu" else 2
    if cfg.is_moe:
        expert = mult * cfg.d_model * cfg.moe_d_ff * wb
        shared = mult * cfg.d_model * cfg.moe_d_ff * cfg.num_shared_experts * wb
        router = cfg.d_model * cfg.num_experts * wb
        return attn + router, 0, expert, shared
    return attn, mult * cfg.d_model * cfg.d_ff * wb, 0, 0


def kv_bytes_per_token(cfg, wb: int) -> float:
    """KV-cache bytes appended per token per layer."""
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * wb
    if cfg.attention_free:
        return 0.0
    return 2 * cfg.num_kv_heads * cfg.head_dim * wb


def _weight_read_bytes(cfg, wb: int) -> float:
    """Dense weight bytes read once per iteration regardless of batch:
    attention + dense/shared FFN + router + unembedding (expert bytes are
    accounted separately — they scale with the activated-expert union)."""
    kinds = cfg.layer_kinds()
    attn_b, ffn_b, expert_b, shared_b = _per_layer_weight_bytes(cfg, wb)
    del expert_b
    weights = 0.0
    for k in kinds:
        if k in ("A", "X"):
            weights += attn_b + ffn_b
            if k == "X":
                weights += attn_b  # cross-attention weights
            if cfg.is_moe:
                weights += shared_b
        elif k == "R":
            weights += cfg._rglru_layer_params() * wb + ffn_b
            if not ffn_b:  # hybrid is dense-ffn
                weights += 3 * cfg.d_model * cfg.d_ff * wb
        elif k == "W":
            weights += cfg._rwkv_layer_params() * wb
    # unembedding is read every iteration; embedding read is per-token rows
    weights += cfg.vocab_size * cfg.d_model * wb
    return weights


def _expert_read_bytes(cfg, unique_experts: float, wb: int) -> float:
    """Expert weight bytes for `unique_experts` activated per MoE layer."""
    if not cfg.is_moe:
        return 0.0
    _, _, expert_b, _ = _per_layer_weight_bytes(cfg, wb)
    n_moe = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    return n_moe * min(unique_experts, cfg.num_experts) * expert_b


def _kv_read_bytes(cfg, context_len: int, window: int, wb: int) -> float:
    """Per-request state read: KV cache rows (windowed layers read only the
    window) plus recurrent-state reads."""
    kv_read = 0.0
    for k in cfg.layer_kinds():
        if k in ("A", "X"):
            lw = window
            if cfg.layer_pattern and k == "A":
                lw = cfg.local_window
            ctx = context_len if not lw else min(context_len, lw)
            kv_read += ctx * kv_bytes_per_token(cfg, wb)
        elif k == "W":
            kv_read += cfg.rwkv_num_heads * cfg.rwkv_head_size ** 2 * 4
        elif k == "R":
            kv_read += cfg.d_rnn * 4
    return kv_read


def iteration_bytes(cfg, n_tokens: int, context_len: int,
                    unique_experts: float = None, affinity: float = 0.0,
                    window: int = 0, wb: int = None) -> dict:
    """HBM bytes moved by one target-model iteration processing `n_tokens`
    in-flight tokens against a `context_len`-token KV cache."""
    wb = wb or 2
    if cfg.is_moe and unique_experts is None:
        unique_experts = expected_unique_experts(
            cfg.num_experts, cfg.experts_per_token, n_tokens, affinity)

    weights = _weight_read_bytes(cfg, wb)
    experts = _expert_read_bytes(cfg, unique_experts or 0.0, wb)
    kv_read = _kv_read_bytes(cfg, context_len, window, wb)

    return {"weights": weights, "experts": experts, "kv": kv_read,
            "total": weights + experts + kv_read,
            "unique_experts": unique_experts or 0.0}


def iteration_flops(cfg, n_tokens: int, context_len: int,
                    window: int = 0) -> float:
    """Approximate FLOPs of one iteration over n_tokens in-flight tokens."""
    active = cfg.active_param_count()
    flops = 2.0 * active * n_tokens
    # attention over the cache
    kinds = cfg.layer_kinds()
    for k in kinds:
        if k in ("A", "X"):
            lw = cfg.local_window if (cfg.layer_pattern and k == "A") else window
            ctx = context_len if not lw else min(context_len, lw)
            hd = cfg.head_dim if not cfg.use_mla else cfg.kv_lora_rank + cfg.qk_rope_dim
            flops += 4.0 * n_tokens * ctx * cfg.num_heads * hd
    return flops


# --------------------------------------------------------------------- #
# Iteration time
# --------------------------------------------------------------------- #

def iteration_time(cfg, hw: Hardware, n_tokens: int, context_len: int,
                   unique_experts: float = None, affinity: float = 0.0,
                   window: int = 0, fixed_overhead: float = 2e-4) -> dict:
    """Seconds for one target iteration. max(memory, compute) + overhead —
    single-batch decode is deep in the memory-bound regime, so the memory
    term dominates everywhere the paper (and we) evaluate."""
    b = iteration_bytes(cfg, n_tokens, context_len, unique_experts,
                        affinity, window)
    f = iteration_flops(cfg, n_tokens, context_len, window)
    t_mem = b["total"] / hw.hbm_bw
    t_compute = f / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead
    return {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
            "bytes": b["total"], "expert_bytes": b["experts"],
            "flops": f, "unique_experts": b["unique_experts"]}


def batch_iteration_time(cfg, hw: Hardware, tokens_per_request,
                         context_lens, *, unique_experts: float = None,
                         per_request_unique=None, affinity: float = 0.0,
                         window: int = 0, fixed_overhead: float = 2e-4,
                         prefill_tokens=None) -> dict:
    """Seconds for one *shared* verification pass over B requests, request i
    contributing n_i = tokens_per_request[i] in-flight tokens against its own
    context_lens[i]-token KV cache.

    The batch moves: dense weights ONCE (the whole point of batching), the
    *union* of activated expert weights (the paper's data-movement driver,
    now across requests), and each request's own KV rows. `unique_experts`
    overrides the analytic union with a measured per-layer mean; at B=1 with
    identical inputs this reduces exactly to `iteration_time`.

    Per-request attribution ("marginal-bytes split", consumed by each
    request's Cascade controller so per-request utility stays meaningful
    under shared verification):
      * KV bytes       -> owned outright by the request;
      * expert bytes   -> split in proportion to each request's marginal
                          expert contribution m_i = union(all) -
                          union(all \\ i) (or to measured per-request unique
                          counts when `per_request_unique` is given);
      * dense weights + fixed overhead -> split evenly — every request needs
                          the full read, the batch amortizes it.
    sum_i(t_attr_i) == t_iter by construction.

    `prefill_tokens` ([B] ints, default all-zero) marks how many of each
    request's in-flight tokens are co-scheduled prompt-chunk tokens. They
    add the same terms `prefill_time` prices for blocking admission — the
    chunk's KV *writes*, its embedding-row reads, and causal attention over
    itself — so chunked and blocking prefill tick the model clock on
    commensurable units (a decode span's single-span KV append stays
    negligible and unpriced, as before).

    Returns iteration_time's keys plus `per_request` (list of dicts with
    t_attr / bytes_attr / marginal_experts) and `n_requests`."""
    wb = 2
    ns = [max(int(n), 0) for n in tokens_per_request]
    cls = list(context_lens)
    if len(ns) != len(cls):
        raise ValueError(f"{len(ns)} token counts vs {len(cls)} contexts")
    b_req = len(ns)
    total_tokens = sum(ns)
    ps = ([0] * b_req if prefill_tokens is None else
          [max(int(p), 0) for p in prefill_tokens])
    if len(ps) != b_req:
        raise ValueError(f"{len(ps)} prefill counts vs {b_req} requests")

    est = expected_unique_experts_batch(
        cfg.num_experts, cfg.experts_per_token, ns, affinity) \
        if cfg.is_moe else {"union": 0.0, "marginal": [0.0] * b_req}
    union = est["union"] if unique_experts is None else float(unique_experts)

    weights = _weight_read_bytes(cfg, wb)
    experts = _expert_read_bytes(cfg, union, wb)
    n_attn = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    prefill_bytes_per_tok = (kv_bytes_per_token(cfg, wb) * n_attn
                             + cfg.d_model * wb)   # KV write + embed row
    kv_each = [_kv_read_bytes(cfg, c, window, wb)
               + p * prefill_bytes_per_tok if n > 0 else 0.0
               for n, c, p in zip(ns, cls, ps)]
    total_bytes = weights + experts + sum(kv_each)

    flops = sum(iteration_flops(cfg, n, c + p, window)
                for n, c, p in zip(ns, cls, ps) if n > 0)
    t_mem = total_bytes / hw.hbm_bw
    t_compute = flops / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead

    # ---- marginal-bytes attribution -------------------------------------
    live = [i for i, n in enumerate(ns) if n > 0]
    n_live = max(len(live), 1)
    if per_request_unique is not None:
        mweights = [max(float(u), 0.0) for u in per_request_unique]
    else:
        mweights = est["marginal"]
    msum = sum(mweights[i] for i in live)
    per_request = []
    for i, n in enumerate(ns):
        if n <= 0:
            per_request.append({"t_attr": 0.0, "bytes_attr": 0.0,
                                "marginal_experts": 0.0})
            continue
        if len(live) == 1:
            # sole live request owns the pass outright (bit-exact reduction
            # to iteration_time — no float round-trip through the split)
            per_request.append({"t_attr": t, "bytes_attr": total_bytes,
                                "marginal_experts": est["marginal"][i]})
            continue
        frac_e = (mweights[i] / msum) if msum > 0 else 1.0 / n_live
        bytes_i = weights / n_live + experts * frac_e + kv_each[i]
        t_attr = ((t - fixed_overhead) * bytes_i / total_bytes
                  if total_bytes > 0 else 0.0) + fixed_overhead / n_live
        per_request.append({"t_attr": t_attr, "bytes_attr": bytes_i,
                            "marginal_experts": est["marginal"][i]})

    return {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
            "bytes": total_bytes, "expert_bytes": experts, "flops": flops,
            "unique_experts": union, "n_requests": b_req,
            "n_tokens": total_tokens, "per_request": per_request}


class BatchCostOracle:
    """Repeated `batch_iteration_time` total-time queries over candidate
    token allocations, with everything except `tokens_per_request` held
    fixed (contexts, prefill chunks, hardware, affinity).

    The batch planner's water-filling evaluates O(B * k_max) candidate
    allocations per engine step; re-running the full attribution split for
    each would be wasteful, so this caches the allocation-independent terms
    (dense weight read, per-row KV/prefill bytes) at construction.
    `t_batch(ns)` returns exactly `batch_iteration_time(...)["t_iter"]` for
    the same inputs — same expressions, same float-op order — which a
    tier-1 property test pins down."""

    def __init__(self, cfg, hw: Hardware, context_lens, *,
                 affinity: float = 0.0, window: int = 0,
                 fixed_overhead: float = 2e-4, prefill_tokens=None):
        wb = 2
        self.cfg = cfg
        self.hw = hw
        self.affinity = affinity
        self.window = window
        self.fixed_overhead = fixed_overhead
        self.cls = list(context_lens)
        b = len(self.cls)
        self.ps = ([0] * b if prefill_tokens is None else
                   [max(int(p), 0) for p in prefill_tokens])
        if len(self.ps) != b:
            raise ValueError(f"{len(self.ps)} prefill counts vs {b} contexts")
        self._weights = _weight_read_bytes(cfg, wb)
        n_attn = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
        prefill_bytes_per_tok = (kv_bytes_per_token(cfg, wb) * n_attn
                                 + cfg.d_model * wb)
        # per-row bytes IF the row is live (n_i > 0); dead rows cost nothing
        self._kv_live = [_kv_read_bytes(cfg, c, window, wb)
                         + p * prefill_bytes_per_tok
                         for c, p in zip(self.cls, self.ps)]

    def t_batch(self, tokens_per_request) -> float:
        """Seconds for one shared pass at this token allocation (scalar —
        no attribution; use `batch_iteration_time` for the full split)."""
        ns = [max(int(n), 0) for n in tokens_per_request]
        if len(ns) != len(self.cls):
            raise ValueError(f"{len(ns)} token counts vs "
                             f"{len(self.cls)} contexts")
        cfg, hw = self.cfg, self.hw
        total = sum(ns)
        union = (expected_unique_experts(cfg.num_experts,
                                         cfg.experts_per_token, total,
                                         self.affinity)
                 if cfg.is_moe and total > 0 else 0.0)
        experts = _expert_read_bytes(cfg, union, 2)
        total_bytes = self._weights + experts + sum(
            kv if n > 0 else 0.0 for n, kv in zip(ns, self._kv_live))
        flops = sum(iteration_flops(cfg, n, c + p, self.window)
                    for n, c, p in zip(ns, self.cls, self.ps) if n > 0)
        t_mem = total_bytes / hw.hbm_bw
        t_compute = flops / hw.peak_flops
        return max(t_mem, t_compute) + self.fixed_overhead


# --------------------------------------------------------------------- #
# Prefill pricing (chunked admission — the compute-bound regime)
# --------------------------------------------------------------------- #

def prefill_chunk_bytes(cfg, n_tokens: int, context_len: int = 0,
                        unique_experts: float = None, affinity: float = 0.0,
                        window: int = 0, wb: int = None) -> dict:
    """HBM bytes moved by one prefill chunk of `n_tokens` prompt tokens
    entering a cache that already holds `context_len` tokens.

    Differs from decode `iteration_bytes` in two ways that matter for TTFT:
    the chunk *writes* its own KV rows (decode's single-token append is
    negligible; a 128-token chunk's is not), and the expert union is driven
    by the chunk's full token count, which saturates toward `num_experts`
    far faster than a [1+K] decode span."""
    wb = wb or 2
    n_tokens = max(int(n_tokens), 0)
    if cfg.is_moe and unique_experts is None:
        unique_experts = expected_unique_experts(
            cfg.num_experts, cfg.experts_per_token, n_tokens, affinity)
    weights = _weight_read_bytes(cfg, wb)
    experts = _expert_read_bytes(cfg, unique_experts or 0.0, wb)
    kv_read = _kv_read_bytes(cfg, context_len, window, wb)
    n_attn = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    kv_write = n_tokens * kv_bytes_per_token(cfg, wb) * n_attn
    embed = n_tokens * cfg.d_model * wb  # embedding-row reads per token
    total = weights + experts + kv_read + kv_write + embed
    return {"weights": weights, "experts": experts, "kv": kv_read,
            "kv_write": kv_write, "embed": embed, "total": total,
            "unique_experts": unique_experts or 0.0}


def prefill_time(cfg, hw: Hardware, n_tokens: int, context_len: int = 0,
                 unique_experts: float = None, affinity: float = 0.0,
                 window: int = 0, fixed_overhead: float = 2e-4) -> dict:
    """Seconds for one prefill pass/chunk under the model clock. Unlike
    decode, prefill crosses the roofline: FLOPs grow linearly (and the
    attention term quadratically) with the chunk while the dominant weight
    read stays constant, so large chunks are compute-bound — max(memory,
    compute) switches sides, which is exactly why the model clock must price
    prefill separately for TTFT to mean anything."""
    n_tokens = max(int(n_tokens), 1)
    b = prefill_chunk_bytes(cfg, n_tokens, context_len, unique_experts,
                            affinity, window)
    # the chunk attends causally to the cached context plus itself
    f = iteration_flops(cfg, n_tokens, context_len + n_tokens, window)
    t_mem = b["total"] / hw.hbm_bw
    t_compute = f / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead
    return {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
            "bytes": b["total"], "expert_bytes": b["experts"],
            "flops": f, "unique_experts": b["unique_experts"],
            "compute_bound": t_compute >= t_mem}


def prefill_crossover_tokens(cfg, hw: Hardware, context_len: int = 0,
                             affinity: float = 0.0, window: int = 0,
                             max_chunk: int = 65536) -> int:
    """Smallest chunk size at which prefill becomes compute-bound (crosses
    the roofline) — the natural upper bound for a chunked-admission `chunk`:
    beyond it, bigger chunks stop amortizing the weight read and only add
    head-of-line latency for the decodes sharing the pass."""
    n = 1
    while n <= max_chunk:
        if prefill_time(cfg, hw, n, context_len, affinity=affinity,
                        window=window)["compute_bound"]:
            return n
        n *= 2
    return max_chunk


def draft_time(hw: Hardware, k: int, drafter_active_params: int = 0,
               per_token_overhead: float = 2e-5) -> float:
    """Drafting cost: ~free for n-gram (CPU table lookup), weight-bound for
    model drafters (EAGLE-style)."""
    if k <= 0:
        return 0.0
    model = k * drafter_active_params * 2 / hw.hbm_bw if drafter_active_params else 0.0
    return model + k * per_token_overhead


def sample_time(k: int, per_token: float = 1.5e-5) -> float:
    """Rejection-sampling cost, linear in verified tokens (paper: 1-2%)."""
    return (k + 1) * per_token


def expected_emitted(accept_rate: float, k: int) -> float:
    """Expected tokens emitted by a [1+k] speculative span when each draft
    is accepted i.i.d. with probability `accept_rate` — the truncated
    geometric series of paper Def. 4.1's ETR (k=0 -> exactly 1). The one
    implementation shared by the analytic K prior below and the batch
    planner's yield predictions."""
    a = min(max(accept_rate, 0.0), 0.999)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


# --------------------------------------------------------------------- #
# Analytic K prior (beyond-paper): warm-start Cascade's hill-climb
# --------------------------------------------------------------------- #

def expected_utility(cfg, hw: Hardware, k: int, accept_rate: float,
                     context_len: int = 1024, affinity: float = 0.3,
                     drafter_params: int = 0) -> float:
    """Analytic Definition-4.1 utility of speculating K tokens when draft
    acceptance is ~accept_rate: ETR from the truncated geometric series,
    cost from the data-movement model."""
    if k <= 0:
        return 1.0
    etr = expected_emitted(accept_rate, k)
    base = iteration_time(cfg, hw, 1, context_len, affinity=affinity)
    spec = iteration_time(cfg, hw, k + 1, context_len, affinity=affinity)
    t_spec = spec["t_iter"] + draft_time(hw, k, drafter_params) + \
        sample_time(k)
    return etr / (t_spec / base["t_iter"])


def suggest_k_start(cfg, hw: Hardware = TPU_V5E, *,
                    accept_rate: float = 0.5, k_max: int = 8,
                    context_len: int = 1024, affinity: float = 0.3,
                    drafter_params: int = 0) -> int:
    """Bucket-and-balls prior for Cascade's first trial K (beyond-paper):
    instead of a fixed k_start=3, pick the analytic utility-maximizing K
    for this architecture — MoEs with steep expert-activation curves get a
    conservative start, dense models an aggressive one. The test-and-set
    loop still measures and adapts; this only saves test iterations."""
    best_k, best_u = 1, -1.0
    for k in range(1, k_max + 1):
        u = expected_utility(cfg, hw, k, accept_rate, context_len, affinity,
                             drafter_params)
        if u > best_u:
            best_k, best_u = k, u
    return best_k
