"""Data-movement cost model for MoE speculative verification (paper §2.4,
adapted from the paper's GPU to our TPU v5e target — DESIGN.md §3).

Single-batch decoding is memory-bandwidth-bound: iteration time is governed
by the bytes fetched from HBM — all attention weights, the *unique* experts
activated by the in-flight tokens, the KV cache read, and the unembedding.
Verifying K+1 tokens multiplies the expert term by the number of unique
experts they collectively activate (bucket-and-balls, damped by expert
affinity), which is exactly why speculation can slow MoEs down.

The same model is used by (1) the serving engine's deterministic virtual
clock on CPU, (2) the paper-figure simulator, and (3) the §Roofline
active-expert correction for MoE decode."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    hbm_bw: float            # bytes/s
    peak_flops: float        # FLOP/s at serving precision
    ici_bw: float = 0.0      # bytes/s per link (TPU interconnect)
    weight_bytes: int = 2    # serving precision (bf16/fp16 = 2)


TPU_V5E = Hardware("tpu-v5e", hbm_bw=819e9, peak_flops=197e12, ici_bw=50e9)
# the paper's workstation GPU (RTX 6000 Ada): ~960 GB/s GDDR6, ~91 TFLOP/s fp16
RTX_6000_ADA = Hardware("rtx-6000-ada", hbm_bw=960e9, peak_flops=91e12)


# --------------------------------------------------------------------- #
# Expert activation statistics (paper §2.4)
# --------------------------------------------------------------------- #

def expected_unique_experts(num_experts: int, top_k: int, n_tokens: int,
                            affinity: float = 0.0) -> float:
    """Expected number of distinct experts activated by `n_tokens` tokens,
    each selecting `top_k` distinct experts.

    affinity=0: uniform-random routing (bucket-and-balls):
        E[unique] = E * (1 - (1 - k/E)^T)
    affinity=1: perfect temporal reuse (all tokens share one expert set).
    The paper observes real tasks fall between the two (§2.4: Mixtral math
    shows 3x instead of the random 3.5x at K=7)."""
    if num_experts == 0:
        return 0.0
    n_tokens = max(int(n_tokens), 1)
    e, k = float(num_experts), float(min(top_k, num_experts))
    rand = e * (1.0 - (1.0 - k / e) ** n_tokens)
    floor = k  # one shared expert set
    return floor + (rand - floor) * (1.0 - affinity)


# --------------------------------------------------------------------- #
# Per-iteration bytes / flops
# --------------------------------------------------------------------- #

def _per_layer_weight_bytes(cfg, wb: int):
    """(attention_bytes, dense_ffn_bytes, one_expert_bytes, shared_bytes)."""
    attn = cfg._attn_params() * wb
    mult = 3 if cfg.activation == "swiglu" else 2
    if cfg.is_moe:
        expert = mult * cfg.d_model * cfg.moe_d_ff * wb
        shared = mult * cfg.d_model * cfg.moe_d_ff * cfg.num_shared_experts * wb
        router = cfg.d_model * cfg.num_experts * wb
        return attn + router, 0, expert, shared
    return attn, mult * cfg.d_model * cfg.d_ff * wb, 0, 0


def kv_bytes_per_token(cfg, wb: int) -> float:
    """KV-cache bytes appended per token per layer."""
    if cfg.use_mla:
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * wb
    if cfg.attention_free:
        return 0.0
    return 2 * cfg.num_kv_heads * cfg.head_dim * wb


def iteration_bytes(cfg, n_tokens: int, context_len: int,
                    unique_experts: float = None, affinity: float = 0.0,
                    window: int = 0, wb: int = None) -> dict:
    """HBM bytes moved by one target-model iteration processing `n_tokens`
    in-flight tokens against a `context_len`-token KV cache."""
    wb = wb or 2
    kinds = cfg.layer_kinds()
    attn_b, ffn_b, expert_b, shared_b = _per_layer_weight_bytes(cfg, wb)

    if cfg.is_moe and unique_experts is None:
        unique_experts = expected_unique_experts(
            cfg.num_experts, cfg.experts_per_token, n_tokens, affinity)

    n_attnish = sum(1 for k in kinds if k in ("A", "X"))
    n_rec = sum(1 for k in kinds if k == "R")
    n_rwkv = sum(1 for k in kinds if k == "W")

    weights = 0.0
    experts = 0.0
    for k in kinds:
        if k in ("A", "X"):
            weights += attn_b + ffn_b
            if k == "X":
                weights += attn_b  # cross-attention weights
            if cfg.is_moe:
                experts += min(unique_experts, cfg.num_experts) * expert_b
                weights += shared_b
        elif k == "R":
            weights += cfg._rglru_layer_params() * wb + ffn_b
            if not ffn_b:  # hybrid is dense-ffn
                weights += 3 * cfg.d_model * cfg.d_ff * wb
        elif k == "W":
            weights += cfg._rwkv_layer_params() * wb

    # unembedding is read every iteration; embedding read is per-token rows
    weights += cfg.vocab_size * cfg.d_model * wb

    # KV cache read: every layer reads its cache (windowed layers read only
    # the window)
    eff_ctx = context_len if not window else min(context_len, window)
    kv_read = 0.0
    for k in kinds:
        if k in ("A", "X"):
            lw = window if k == "A" else window
            if cfg.layer_pattern and k == "A":
                lw = cfg.local_window
            ctx = context_len if not lw else min(context_len, lw)
            kv_read += ctx * kv_bytes_per_token(cfg, wb)
        elif k == "W":
            kv_read += cfg.rwkv_num_heads * cfg.rwkv_head_size ** 2 * 4
        elif k == "R":
            kv_read += cfg.d_rnn * 4
    del eff_ctx

    return {"weights": weights, "experts": experts, "kv": kv_read,
            "total": weights + experts + kv_read,
            "unique_experts": unique_experts or 0.0}


def iteration_flops(cfg, n_tokens: int, context_len: int,
                    window: int = 0) -> float:
    """Approximate FLOPs of one iteration over n_tokens in-flight tokens."""
    active = cfg.active_param_count()
    flops = 2.0 * active * n_tokens
    # attention over the cache
    kinds = cfg.layer_kinds()
    for k in kinds:
        if k in ("A", "X"):
            lw = cfg.local_window if (cfg.layer_pattern and k == "A") else window
            ctx = context_len if not lw else min(context_len, lw)
            hd = cfg.head_dim if not cfg.use_mla else cfg.kv_lora_rank + cfg.qk_rope_dim
            flops += 4.0 * n_tokens * ctx * cfg.num_heads * hd
    return flops


# --------------------------------------------------------------------- #
# Iteration time
# --------------------------------------------------------------------- #

def iteration_time(cfg, hw: Hardware, n_tokens: int, context_len: int,
                   unique_experts: float = None, affinity: float = 0.0,
                   window: int = 0, fixed_overhead: float = 2e-4) -> dict:
    """Seconds for one target iteration. max(memory, compute) + overhead —
    single-batch decode is deep in the memory-bound regime, so the memory
    term dominates everywhere the paper (and we) evaluate."""
    b = iteration_bytes(cfg, n_tokens, context_len, unique_experts,
                        affinity, window)
    f = iteration_flops(cfg, n_tokens, context_len, window)
    t_mem = b["total"] / hw.hbm_bw
    t_compute = f / hw.peak_flops
    t = max(t_mem, t_compute) + fixed_overhead
    return {"t_iter": t, "t_mem": t_mem, "t_compute": t_compute,
            "bytes": b["total"], "expert_bytes": b["experts"],
            "flops": f, "unique_experts": b["unique_experts"]}


def draft_time(hw: Hardware, k: int, drafter_active_params: int = 0,
               per_token_overhead: float = 2e-5) -> float:
    """Drafting cost: ~free for n-gram (CPU table lookup), weight-bound for
    model drafters (EAGLE-style)."""
    if k <= 0:
        return 0.0
    model = k * drafter_active_params * 2 / hw.hbm_bw if drafter_active_params else 0.0
    return model + k * per_token_overhead


def sample_time(k: int, per_token: float = 1.5e-5) -> float:
    """Rejection-sampling cost, linear in verified tokens (paper: 1-2%)."""
    return (k + 1) * per_token


# --------------------------------------------------------------------- #
# Analytic K prior (beyond-paper): warm-start Cascade's hill-climb
# --------------------------------------------------------------------- #

def expected_utility(cfg, hw: Hardware, k: int, accept_rate: float,
                     context_len: int = 1024, affinity: float = 0.3,
                     drafter_params: int = 0) -> float:
    """Analytic Definition-4.1 utility of speculating K tokens when draft
    acceptance is ~accept_rate: ETR from the truncated geometric series,
    cost from the data-movement model."""
    if k <= 0:
        return 1.0
    a = min(max(accept_rate, 0.0), 0.999)
    etr = (1.0 - a ** (k + 1)) / (1.0 - a)
    base = iteration_time(cfg, hw, 1, context_len, affinity=affinity)
    spec = iteration_time(cfg, hw, k + 1, context_len, affinity=affinity)
    t_spec = spec["t_iter"] + draft_time(hw, k, drafter_params) + \
        sample_time(k)
    return etr / (t_spec / base["t_iter"])


def suggest_k_start(cfg, hw: Hardware = TPU_V5E, *,
                    accept_rate: float = 0.5, k_max: int = 8,
                    context_len: int = 1024, affinity: float = 0.3,
                    drafter_params: int = 0) -> int:
    """Bucket-and-balls prior for Cascade's first trial K (beyond-paper):
    instead of a fixed k_start=3, pick the analytic utility-maximizing K
    for this architecture — MoEs with steep expert-activation curves get a
    conservative start, dense models an aggressive one. The test-and-set
    loop still measures and adapts; this only saves test iterations."""
    best_k, best_u = 1, -1.0
    for k in range(1, k_max + 1):
        u = expected_utility(cfg, hw, k, accept_rate, context_len, affinity,
                             drafter_params)
        if u > best_u:
            best_k, best_u = k, u
    return best_k
