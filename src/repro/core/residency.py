"""Tiered expert residency: what is actually HBM-resident, under a cap.

`ExpertPlacement.tier_of` says where each expert's weights *live* (hbm =
always device-resident, host = offloaded behind `Hardware.host_bw`);
`ResidencyState` tracks which host-tier experts are *currently* HBM-
resident under a per-shard byte cap, the analytic miss curve the cost
model prices fetches with, and the LRU-by-EMA-load eviction policy the
engine's prefetch stage uses (docs/offload.md).

The motivating regime: production MoEs (deepseek_v2_236b, kimi_k2_1t_a32b
in configs/) whose expert weights alone exceed any single device's HBM —
without a host tier those configs are unservable by this stack, and with
one, speculation's drafted lookahead becomes a *prefetch oracle* (SP-MoE,
arXiv 2510.10302): the router applied to drafted tokens predicts the
verification union one pass ahead, hiding fetch latency behind the
draft+sample window.
"""

from __future__ import annotations

import warnings
from typing import Optional

from .cost_model import Precision

__all__ = ["ResidencyState", "expert_hbm_bytes", "moe_layer_count"]


def expert_hbm_bytes(cfg, weight_bytes: int = None,
                     precision: Optional[Precision] = None,
                     per_layer: bool = False) -> float:
    """HBM bytes of ONE expert across all MoE layers — the unit of
    whole-expert residency accounting (an expert is fetched/evicted whole:
    its slice in every MoE layer moves together, matching the per-expert
    granularity of `_expert_read_bytes`). `per_layer=True` drops the
    layer-count factor and returns the bytes of one expert's slice in ONE
    MoE layer — the unit of `granularity="layer"` residency, where each
    (layer, expert) slice moves independently (docs/offload.md). The two
    are exact multiples: whole == n_moe_layers * per_layer bitwise (both
    are integer-valued floats), which is what lets the layered pricing
    degrade bit-exactly to the whole-expert path. `precision` prices the
    expert class — quantized experts shrink both the fetch bytes a
    host-tier miss costs AND the footprint a cache slot holds, so the same
    cap fits more of them (docs/quantization.md)."""
    if not cfg.is_moe:
        return 0.0
    if weight_bytes is None:
        weight_bytes = (precision.expert if precision is not None
                        else Precision.DEFAULT.expert)
    mult = 3 if cfg.activation == "swiglu" else 2
    n_moe = sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))
    per = float(mult * cfg.d_model * cfg.moe_d_ff * weight_bytes)
    return per if per_layer else float(n_moe) * per


def moe_layer_count(cfg) -> int:
    """Number of MoE layers in the stack — the layer axis of
    `granularity="layer"` residency units and of the layered fetch
    schedule (`cost_model.fetch_time_layered`)."""
    if not cfg.is_moe:
        return 0
    return sum(1 for k in cfg.layer_kinds() if k in ("A", "X"))


class ResidencyState:
    """Per-shard HBM residency of a host-tiered `ExpertPlacement`.

    Each shard pins its hbm-tier experts (primaries and replicas — those
    are always resident) and holds `slots` cache slots for its homed
    host-tier experts, where ``slots = (cap_bytes - pinned_bytes) //
    expert_bytes``. `cap_bytes` may be a scalar (same cap every shard) or
    a per-shard sequence; None means uncapped (every host expert fits, no
    evictions, zero analytic misses — the bit-exact degradation tier).

    Three consumers share this object:

    * the cost model (`batch_iteration_time` / `BatchCostOracle`) prices
      passes with `expected_misses` — the steady-state random-cache miss
      curve — and `capacity_experts` bounds replica rebalancing;
    * the planner's `MemoryCapConstraint` / `FetchDeadlineConstraint`
      read `capacity_experts` and the oracle's fetch predictions;
    * the engine's prefetch stage mutates the cache: `fetch(stage=True)`
      streams predicted experts into a per-shard staging buffer before
      the pass, `access` classifies the pass's activated host experts
      into hits (cached or staged) and demand misses, `fetch` installs
      the misses (evicting the coldest by (EMA load, last use) when
      full), and `note_step` decays the EMA and drains the staging
      buffer (used experts installed, unused discarded).

    Counters (`hits`, `misses`, `evictions`, `bytes_fetched`) feed
    `StepTelemetry` and the sweep artifacts.

    `granularity` picks the residency *unit* (docs/offload.md, layered
    streaming): `"expert"` (the default) moves an expert's slices across
    all MoE layers as one unit keyed by the expert id — PR 7's contract,
    bit-identical to before. `"layer"` moves each (layer, expert) slice
    independently: unit keys become `(moe_layer, expert)` tuples, the unit
    footprint is `expert_hbm_bytes(cfg, per_layer=True)`, staging/LRU/EMA
    state is per unit, and the same byte cap holds `n_moe_layers` times as
    many (smaller) units — the granularity the layer-pipelined fetch
    schedule needs, since layer l's slices have until layer l's own FFN
    (not the pass start) to arrive."""

    GRANULARITIES = ("expert", "layer")

    def __init__(self, placement, cfg=None, *,
                 expert_bytes: Optional[float] = None,
                 cap_bytes=None, ema_decay: float = 0.8,
                 precision: Optional[Precision] = None,
                 hw=None, strict: bool = False,
                 granularity: str = "expert"):
        if granularity not in self.GRANULARITIES:
            raise ValueError(f"unknown granularity {granularity!r} "
                             f"(expected one of {self.GRANULARITIES})")
        self.granularity = granularity
        if granularity == "layer":
            if cfg is None:
                raise ValueError(
                    "granularity='layer' needs cfg — the residency must "
                    "know the MoE layer count to enumerate its units")
            self._unit_layers = max(moe_layer_count(cfg), 1)
            if expert_bytes is None:
                expert_bytes = expert_hbm_bytes(cfg, per_layer=True,
                                                precision=precision)
        else:
            self._unit_layers = 1
            if expert_bytes is None:
                if cfg is None:
                    raise ValueError(
                        "need cfg or expert_bytes to size experts")
                expert_bytes = expert_hbm_bytes(cfg, precision=precision)
        if expert_bytes <= 0:
            raise ValueError(f"non-positive expert_bytes {expert_bytes}")
        if not 0.0 <= ema_decay < 1.0:
            raise ValueError(f"ema_decay {ema_decay} outside [0, 1)")
        # `Hardware.hbm_bytes` used to be purely informational, which let
        # manually-specified caps silently exceed the device's actual HBM
        # — a residency plan the hardware cannot hold. With `hw` given:
        # unset caps default to hw.hbm_bytes (each shard is one device),
        # and an explicit cap above it warns (raises under strict=True).
        if hw is not None and hw.hbm_bytes > 0:
            if cap_bytes is None:
                cap_bytes = float(hw.hbm_bytes)
            else:
                caps0 = self._normalize_caps(cap_bytes, placement.n_shards)
                over = [s for s, c in enumerate(caps0)
                        if c is not None and c > hw.hbm_bytes]
                if over:
                    msg = (f"residency cap exceeds {hw.name!r} HBM "
                           f"({hw.hbm_bytes:.3e} B) on shard(s) {over}")
                    if strict:
                        raise ValueError(msg)
                    warnings.warn(msg, stacklevel=2)
        self.placement = placement
        self.expert_bytes = float(expert_bytes)
        self.ema_decay = float(ema_decay)
        s_n = placement.n_shards
        tiers = placement.tiers
        # pinned hbm-tier residents per shard (replicas included)
        self._pinned = [0] * s_n
        for e, s in enumerate(placement.shard_of):
            if tiers[e] == "hbm":
                for x in (s if isinstance(s, tuple) else (s,)):
                    self._pinned[x] += 1
        # host-tier experts homed per shard (host experts are never
        # replicated, so the home is a plain int). Residency *units* are
        # expert ids under granularity="expert" and (moe_layer, expert)
        # tuples under granularity="layer" — every cache/staging/EMA
        # structure below is keyed by unit, and `_home` maps units to
        # their shard. `_expert_home` keeps the expert-level view both
        # modes share (H_s for the miss curve, is_resident).
        self._host_of_shard = [[] for _ in range(s_n)]
        self._expert_home = {}
        self._home = {}
        for e, (s, t) in enumerate(zip(placement.primary_shard_of, tiers)):
            if t == "host":
                self._host_of_shard[s].append(e)
                self._expert_home[e] = s
                if self.granularity == "layer":
                    for lyr in range(self._unit_layers):
                        self._home[(lyr, e)] = s
                else:
                    self._home[e] = s
        caps = self._normalize_caps(cap_bytes, s_n)
        self._slots = []
        for s in range(s_n):
            n_units = self._unit_layers * len(self._host_of_shard[s])
            if caps[s] is None:
                self._slots.append(n_units)
                continue
            # the pinned footprint is whole experts regardless of the
            # residency granularity: hbm-tier experts never move per layer
            pinned_b = self._pinned[s] * self._unit_layers \
                * self.expert_bytes
            if caps[s] < pinned_b:
                raise ValueError(
                    f"shard {s}: cap {caps[s]:.3e} B below the pinned "
                    f"hbm-tier footprint {pinned_b:.3e} B")
            self._slots.append(
                min(int((caps[s] - pinned_b) // self.expert_bytes),
                    n_units))
        self.cap_bytes = caps
        # cache: per shard, resident host experts -> last-use step
        self._cache = [dict() for _ in range(s_n)]
        # staging buffer: prefetched-not-yet-installed experts per shard
        # (drained every pass by note_step)
        self._staged = [set() for _ in range(s_n)]
        self._staged_used = [set() for _ in range(s_n)]
        self._ema = {e: 0.0 for e in self._home}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_fetched = 0.0

    @staticmethod
    def _normalize_caps(cap_bytes, s_n):
        if cap_bytes is None:
            return [None] * s_n
        if isinstance(cap_bytes, (int, float)):
            return [float(cap_bytes)] * s_n
        caps = [None if c is None else float(c) for c in cap_bytes]
        if len(caps) != s_n:
            raise ValueError(f"{len(caps)} caps vs {s_n} shards")
        return caps

    # ---- static views ------------------------------------------------- #

    @property
    def has_host_tier(self) -> bool:
        return self.placement.has_host_tier

    @property
    def n_shards(self) -> int:
        return self.placement.n_shards

    @property
    def n_unit_layers(self) -> int:
        """MoE layers per residency unit axis: 1 under
        granularity="expert" (an expert's layers move together), the MoE
        layer count under granularity="layer"."""
        return self._unit_layers

    @property
    def slots(self):
        """Cache slots for host-tier units per shard (capped at the
        shard's host unit population). Units are whole experts under
        granularity="expert", (layer, expert) slices under "layer"."""
        return tuple(self._slots)

    @property
    def capacity_experts(self):
        """Max simultaneously HBM-resident experts per shard — pinned
        hbm-tier residents plus host-tier cache slots, in *expert
        equivalents* (layer-granularity slots count 1/n_moe_layers of an
        expert each; at exact multiples this is bitwise the whole-expert
        figure). The activated-load ceiling replica rebalancing must
        respect (`_rebalance_replicas`)."""
        if self.granularity == "layer":
            return [float(p) + sl / self._unit_layers
                    for p, sl in zip(self._pinned, self._slots)]
        return [float(p + sl) for p, sl in zip(self._pinned, self._slots)]

    @property
    def resident_counts(self):
        """Experts *currently* HBM-resident per shard: pinned + cached —
        the live counterpart of `ExpertPlacement.resident_counts`. Under
        granularity="layer" the cached term counts expert equivalents
        (cached units / n_moe_layers), so partial experts show as
        fractions."""
        if self.granularity == "layer":
            return tuple(p + len(c) / self._unit_layers
                         for p, c in zip(self._pinned, self._cache))
        return tuple(p + len(c) for p, c in zip(self._pinned, self._cache))

    def is_resident(self, expert) -> bool:
        """True when `expert`'s weights are in HBM right now (hbm-tier
        experts always are). Accepts an expert id (under
        granularity="layer": resident iff ALL its layer slices are) or a
        (layer, expert) unit key."""
        if isinstance(expert, tuple):
            s = self._home.get(expert)
            if s is None:
                return True
            return expert in self._cache[s]
        s = self._expert_home.get(expert)
        if s is None:
            return True
        if self.granularity == "layer":
            return all((lyr, expert) in self._cache[s]
                       for lyr in range(self._unit_layers))
        return expert in self._cache[s]

    # ---- analytic miss curve (cost-model side) ------------------------ #

    def expected_misses(self, per_shard_active):
        """Steady-state expected host-fetch count per shard when the pass
        activates `per_shard_active[s]` experts on shard s (mean per
        layer): a fraction H_s/E_s of the activated set is host-tier
        (routing is tier-blind), and a random host expert is resident with
        probability slots_s/H_s, so
        miss_s = acts_s * (H_s/E_s) * (1 - slots_s/H_s).
        Uncapped shards (slots_s == H_s) miss nothing — the degradation
        tier the drift gates pin.

        Under granularity="layer" the same curve generalizes to units:
        each of the n_l MoE layers activates acts_s experts, a random host
        *unit* is resident with probability slots_s/(n_l*H_s), and the
        returned figure is the expected missing UNIT count (the sum over
        `expected_layer_misses` rows) — the count that, times the per-unit
        `expert_bytes`, prices the shard's total fetch bytes."""
        if self.granularity == "layer":
            return [sum(row) for row in
                    self.expected_layer_misses(per_shard_active)]
        if len(per_shard_active) != self.n_shards:
            raise ValueError(f"{len(per_shard_active)} activation counts "
                             f"vs {self.n_shards} shards")
        counts = self.placement.counts
        miss = []
        for s, acts in enumerate(per_shard_active):
            h_s = len(self._host_of_shard[s])
            e_s = counts[s]
            if h_s == 0 or e_s == 0 or acts <= 0:
                miss.append(0.0)
                continue
            resident_frac = min(self._slots[s] / h_s, 1.0)
            m = float(acts) * (h_s / e_s) * (1.0 - resident_frac)
            miss.append(max(m, 0.0))
        return miss

    def expected_layer_misses(self, per_shard_active):
        """Per-(shard, MoE layer) expected missing unit counts [S][L] —
        the analytic input of the layered fetch pipeline
        (`cost_model.fetch_time_layered`). Routing is layer-blind in the
        analytic view, so every layer sees the same activated-expert count
        and the per-layer miss is uniform:
        m_{s,l} = acts_s * (H_s/E_s) * (1 - slots_s/(n_l*H_s)).
        Uncapped shards (slots == n_l*H) miss nothing. Only meaningful
        under granularity="layer" (raises otherwise — whole-expert units
        have no layer axis)."""
        if self.granularity != "layer":
            raise ValueError("expected_layer_misses needs "
                             "granularity='layer' residency units")
        if len(per_shard_active) != self.n_shards:
            raise ValueError(f"{len(per_shard_active)} activation counts "
                             f"vs {self.n_shards} shards")
        counts = self.placement.counts
        n_l = self._unit_layers
        out = []
        for s, acts in enumerate(per_shard_active):
            h_s = len(self._host_of_shard[s])
            e_s = counts[s]
            if h_s == 0 or e_s == 0 or acts <= 0:
                out.append([0.0] * n_l)
                continue
            resident_frac = min(self._slots[s] / (n_l * h_s), 1.0)
            m = float(acts) * (h_s / e_s) * (1.0 - resident_frac)
            out.append([max(m, 0.0)] * n_l)
        return out

    # ---- cache mutation (engine side) --------------------------------- #

    def _key(self, u):
        """Normalize a residency unit key: an expert id under
        granularity="expert", a (moe_layer, expert) tuple under "layer".
        Mixing the two is a caller bug, not a miss — it raises."""
        if self.granularity == "layer":
            if not isinstance(u, tuple) or len(u) != 2:
                raise ValueError(
                    f"granularity='layer' residency units are (layer, "
                    f"expert) tuples, got {u!r}")
            return (int(u[0]), int(u[1]))
        if isinstance(u, tuple):
            raise ValueError(
                f"granularity='expert' residency units are expert ids, "
                f"got the tuple {u!r}")
        return int(u)

    def access(self, experts, step: int):
        """Classify activated units at pass time: host-tier residents
        are hits (LRU-touched), staged units are hits too (the pass
        reads them straight from the staging buffer — the conversion a
        prefetch exists for) and are marked for installation, host-tier
        absentees are demand misses the caller should `fetch`. Returns
        (hit_ids, missing_ids). Units follow the granularity: expert ids,
        or (layer, expert) tuples."""
        hit, missing = [], []
        for e in experts:
            e = self._key(e)
            s = self._home.get(e)
            if s is None:
                continue
            if e in self._cache[s]:
                self._cache[s][e] = step
                hit.append(e)
            elif e in self._staged[s]:
                self._staged_used[s].add(e)
                hit.append(e)
            else:
                missing.append(e)
        self.hits += len(hit)
        self.misses += len(missing)
        return hit, missing

    def fetch(self, experts, step: int, *, stage=False):
        """Bring host-tier units over the host link (demand or
        prefetch). Returns {"fetched": n, "per_shard": [S], "bytes": f}.

        Demand mode (stage=False): the expert is installed in its
        shard's cache immediately, evicting the coldest resident — min
        (EMA load, last use, id) — when the slots are full. A shard with
        zero slots streams the weights through without retaining them
        (the fetch still crosses the link and is still billed).

        Staging mode (stage=True, the engine's prefetch path): the
        expert lands in the shard's *staging buffer* — the same bounce
        buffer every streamed fetch flows through — so nothing is
        evicted at prediction time. The pass reads staged weights as
        hits (`access`), and `note_step` then installs the ones the pass
        actually used with post-pass recency while discarding the rest.
        Evicting at prediction time is what this avoids: the predictor
        sees pre-pass recency, so its victims are systematically worse
        than the demand path's post-pass choices, and a mispredicted
        fetch would perturb the cache trajectory instead of costing only
        its (hidden) bytes."""
        per_shard = [0] * self.n_shards
        fetched = 0
        for e in experts:
            e = self._key(e)
            s = self._home.get(e)
            if s is None or e in self._cache[s] or e in self._staged[s]:
                continue
            per_shard[s] += 1
            fetched += 1
            if stage:
                self._staged[s].add(e)
                continue
            if self._slots[s] > 0 and len(self._cache[s]) >= self._slots[s]:
                victim = min(self._cache[s],
                             key=lambda v: (self._ema[v],
                                            self._cache[s][v], v))
                del self._cache[s][victim]
                self.evictions += 1
            if self._slots[s] <= 0:
                continue  # streamed, not retained
            self._cache[s][e] = step
        self.bytes_fetched += fetched * self.expert_bytes
        return {"fetched": fetched, "per_shard": per_shard,
                "bytes": fetched * self.expert_bytes}

    def note_step(self, active_experts, step: int) -> None:
        """End-of-pass bookkeeping: decay every host expert's EMA load
        toward 0 and bump the ones this pass activated — the coldness
        signal `fetch`'s eviction policy ranks by — then drain the
        staging buffer: staged experts the pass actually read are
        installed in the cache with post-pass recency (evicting the
        coldest resident, exactly as a demand fetch would have), unused
        ones are discarded (their only cost was the billed prefetch
        bytes — the cache trajectory stays untouched)."""
        active = {self._key(e) for e in active_experts}
        d = self.ema_decay
        for e in self._ema:
            self._ema[e] = d * self._ema[e] + \
                (0.0 if e not in active else (1.0 - d))
        for s in range(self.n_shards):
            if self._slots[s] > 0:
                for e in sorted(self._staged_used[s]):
                    if e in self._cache[s]:
                        continue
                    if len(self._cache[s]) >= self._slots[s]:
                        victim = min(self._cache[s],
                                     key=lambda v: (self._ema[v],
                                                    self._cache[s][v], v))
                        del self._cache[s][victim]
                        self.evictions += 1
                    self._cache[s][e] = step
            self._staged[s].clear()
            self._staged_used[s].clear()

    def snapshot(self) -> dict:
        """Counters + live residency for telemetry/artifacts."""
        denom = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "bytes_fetched": self.bytes_fetched,
                "hit_rate": (self.hits / denom) if denom else 1.0,
                "resident_counts": list(self.resident_counts),
                "slots": list(self.slots),
                "granularity": self.granularity}
