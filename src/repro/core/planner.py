"""Batch-level speculation planner (beyond-paper; the batching analogue of
the paper's per-request utility rule, §4-§5).

Under continuous batching the verification cost is *shared*: B requests'
draft spans activate a union of experts, so one request's aggressive K
taxes everyone sharing the pass — miscoordination the per-request Cascade
controllers cannot see (each one only observes its own attributed share).
`BatchSpecPlanner` closes the loop at the batch level. Each step it takes
every live request's controller *ask* (the Cascade FSM still drives
exploration and per-request disable), then jointly decides the *grants*
{K_i} by greedy marginal-utility water-filling:

  * price candidate allocations through the data-movement cost model
    (`cost_model.BatchCostOracle` — union expert bytes, per-row KV,
    shared-pass FLOPs, the memory/compute roofline crossover);
  * predict each request's marginal token yield from its windowed draft
    acceptance (`UtilityAnalyzer.accept_rate`): granting the (k+1)-th
    draft token to a request with acceptance a is worth a^(k+1) expected
    extra emissions (or the depth-k product of its per-position
    `accept_curve` under `use_accept_curve` — drafts decay with depth);
  * repeatedly grant +1 draft token to the admissible candidate with the
    highest predicted Δtokens/Δt_batch, where *admissible* is decided by
    a pluggable pipeline of `GrantConstraint` objects.

Constraint pipeline (docs/slo.md): the stopping rule is no longer a
hard-coded water level — each candidate grant is vetted by every
constraint, and the loop stops when no admissible candidate remains.

  * `BreakEvenConstraint` — the paper's break-even rule per grant: the
    marginal rate must beat the (latency-weighted) no-speculation batch
    rate `util_floor * sum(w_i) / t_base`. Latency-tier requests carry
    weight `latency_tier_weight` > 1, raising the bar for everyone's
    marginal grants when latency traffic shares the pass.
  * `SLOTpotConstraint` — victim protection: a grant to ANY row is denied
    when it would push any *co-scheduled* bounded request's predicted
    TPOT (`BatchCostOracle.predicted_tpot`: the whole — max-over-shards —
    pass over that request's expected emissions) past its bound, unless
    the move does not worsen it. No per-request gate can see this: the
    victim's own controller never asked for the grant that hurts it.

  * `MemoryCapConstraint` / `FetchDeadlineConstraint` — residency
    protection under a host-tiered placement (docs/offload.md): deny
    grants whose predicted per-shard activated union exceeds what the
    residency cap can keep HBM-resident, or whose host-fetch time can no
    longer hide behind the draft+sample window. Both carry the same
    don't-worsen escape clause as the SLO constraint, so an already
    over-capacity base state cannot freeze the batch.

Future constraints (replication steering) plug into the same pipeline —
`greedy_allocate(constraints=[...])` is the extension point.

Trial hygiene: the planner staggers Cascade TEST phases so at most one
request trials an off-policy K per shared pass (`SpeculationManager.hold`)
— a concurrent trial shifts the expert union under every other request's
attributed-cost measurement. The one trialing request is granted its probe
K in full, so the FSM measures exactly what it asked to measure — unless
pinning the probe would itself break a co-scheduled SLO bound, in which
case victim protection wins and the probe is water-filled like any grant.

Expert parallelism (docs/expert_parallel.md): under an `ExpertPlacement`
with n_shards > 1 the oracle prices each candidate allocation with the
max-over-shards roofline, so Δt_batch is the *hottest shard's* delta — a
+1 grant to a request whose routing profile concentrates on the gating
shard costs more than one spreading over cold shards, and water-filling
steers grants away from the shard that gates the pass.
`PlannerConfig(shard_aware=False)` is the deliberately naive comparator
that spreads the union evenly over shards (the "global-union" planner the
--ep-sweep gates against).

Degradation: at B=1 (a single span in the pass) the planner is bypassed —
grants equal asks bit for bit, reproducing the legacy per-request
controller path exactly (the request's own SLO is the per-request
`CascadeConfig.slo_tpot` check there) — and `policy="independent"` is the
escape hatch that bypasses it at every batch size. With no SLOs attached
and the default flags, the pipeline is bit-identical — grants, predictions,
telemetry — to the pre-pipeline water-filling (property-tested against a
verbatim reference implementation)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from . import cost_model as cm
from .cost_model import expected_emitted, expected_emitted_curve
from .manager import TEST
from .slo import LATENCY, RequestSLO, tpot_within


@dataclass(frozen=True)
class PlannerConfig:
    #: "joint" — batch-level water-filling; "independent" — escape hatch,
    #: every grant equals its controller's ask (the pre-planner engine)
    policy: str = "joint"
    #: stop granting when the best marginal utility drops below this
    #: (1.0 = the paper's break-even rule at batch level)
    util_floor: float = 1.0
    #: acceptance prior for requests with no speculative history yet
    default_accept: float = 0.5
    #: analyzer window for the acceptance estimate
    accept_window: int = 16
    #: stagger Cascade TEST phases to one trial per shared pass
    stagger_tests: bool = True
    #: with an ExpertPlacement: True prices the max-over-shards roofline
    #: (the hottest shard gates the pass); False is the global-union
    #: comparator that assumes the union spreads evenly over shards
    shard_aware: bool = True
    #: water-level weight of a latency-tier request (throughput tier = 1):
    #: with mixed-tier traffic the no-speculation rate is weighted, so
    #: marginal grants must clear a higher bar when latency requests
    #: share the pass. 1.0 disables the weighting.
    latency_tier_weight: float = 2.0
    #: predict marginal yield from the per-position acceptance curve
    #: (UtilityAnalyzer.accept_curve) instead of the flat windowed mean —
    #: drafts decay with depth, so the flat mean over-grants deep Ks.
    #: Default off: the flat path is the bit-identity baseline.
    use_accept_curve: bool = False


class DraftYieldModel:
    """Predicted draft yield for the water-filling and the SLO constraint:
    `marginal(i, k)` is the expected extra emissions of granting row i its
    (k+1)-th draft token, `emitted(i, k)` its cumulative expected
    emissions at k granted drafts. Flat acceptance a gives the paper's
    truncated geometric series (marginal a^(k+1)); a per-position curve
    (accept-model upgrade, flag-gated) gives the depth-decayed product."""

    def __init__(self, accepts: Dict[int, float],
                 curves: Optional[Dict[int, Sequence[float]]] = None):
        self.accepts = accepts
        self.curves = curves or {}

    def marginal(self, i: int, k: int) -> float:
        curve = self.curves.get(i)
        if curve is None:
            return self.accepts[i] ** (k + 1)
        p = 1.0
        for j in range(k + 1):
            c = curve[j] if j < len(curve) else curve[-1]
            p *= min(max(c, 0.0), 0.999)
        return p

    def emitted(self, i: int, k: int) -> float:
        curve = self.curves.get(i)
        if curve is None:
            return expected_emitted(self.accepts[i], k)
        return expected_emitted_curve(curve, k)


@dataclass
class GrantCandidate:
    """One +1-draft-token proposal the constraint pipeline vets."""
    row: int               # decode row receiving the extra draft
    k_current: int         # drafts already granted to the row
    d_tokens: float        # predicted marginal emissions of the grant
    d_t: float             # marginal batch-pass delta (max-over-shards)
    rate: float            # d_tokens / d_t (inf when the grant is free)
    t_after: float         # predicted pass seconds AFTER the grant


@dataclass
class AllocationContext:
    """Shared state the constraints read (and `greedy_allocate` owns):
    `ns`/`alloc`/`t_cur` are live views updated as grants land."""
    oracle: cm.BatchCostOracle
    decode: Sequence[int]
    caps: Dict[int, int]
    accepts: Dict[int, float]
    yields: DraftYieldModel
    ns: List[int]
    alloc: Dict[int, int]
    t_base: float
    t_cur: float
    fixed: frozenset


class GrantConstraint:
    """One rule of the allocation pipeline. `prepare` runs once per plan
    (after fixed rows are pinned), `admits` vets each candidate grant, and
    `admits_pinned` vets the pinned-trial base state — a constraint that
    rejects it demotes the pinned probes to ordinary candidates. Subclass
    and pass via `greedy_allocate(constraints=[...])` /
    `BatchSpecPlanner(constraints_factory=...)` to extend the planner
    (this is the extension point future constraints — replication
    steering, memory caps — plug into)."""

    name = "constraint"

    def prepare(self, ctx: AllocationContext) -> None:
        pass

    def admits(self, cand: GrantCandidate, ctx: AllocationContext) -> bool:
        return True

    def admits_pinned(self, ctx: AllocationContext) -> bool:
        return True


@dataclass
class BreakEvenConstraint(GrantConstraint):
    """The paper's break-even rule per grant: a candidate must beat the
    batch's no-speculation token rate — the water level
    `util_floor * sum(w_i) / t_base`, with latency-tier rows weighted
    above 1 (`weights`) so mixed-tier passes demand more from every
    marginal grant. With unit weights this is exactly the pre-pipeline
    `util_floor * B_live / t_base` level, float for float."""
    util_floor: float = 1.0
    weights: Optional[Dict[int, float]] = None

    name = "break_even"
    r_floor: float = 0.0

    def prepare(self, ctx: AllocationContext) -> None:
        if not ctx.decode:
            self.r_floor = 0.0
            return
        eff_b = (len(ctx.decode) if self.weights is None
                 else sum(self.weights.get(i, 1.0) for i in ctx.decode))
        self.r_floor = self.util_floor * eff_b / ctx.t_base

    def admits(self, cand: GrantCandidate, ctx: AllocationContext) -> bool:
        return not (cand.rate < self.r_floor)


@dataclass
class SLOTpotConstraint(GrantConstraint):
    """Victim protection: deny any grant that pushes any co-scheduled
    bounded request's *predicted* TPOT past its bound — not just the
    grantee's. Predicted TPOT is the whole pass (already the gating
    shard's time under a placement) over the request's expected
    emissions (`BatchCostOracle.predicted_tpot` semantics, inlined here
    against the candidate's `t_after`).

    The escape clause — a candidate violating row j's bound is still
    admitted when it does not worsen j's predicted TPOT — keeps an
    *infeasibly*-bounded row (its bound below even the no-speculation
    pass) from freezing the whole batch, and lets a bounded row's own
    speculation pull it back under its bound (Theorem 4.2: TPOT falls as
    utility rises). The invariant that survives water-filling, property-
    tested: every bounded row's predicted TPOT ends <= max(its bound, its
    no-speculation TPOT)."""
    bounds: Dict[int, float] = field(default_factory=dict)

    name = "slo_tpot"

    def _tpot(self, j: int, t_pass: float, ctx: AllocationContext,
              extra: int = 0) -> float:
        e = ctx.yields.emitted(j, ctx.alloc[j] + extra)
        return t_pass / e if e > 0 else float("inf")

    def admits(self, cand: GrantCandidate, ctx: AllocationContext) -> bool:
        for j, bound in self.bounds.items():
            extra = 1 if j == cand.row else 0
            after = self._tpot(j, cand.t_after, ctx, extra)
            if tpot_within(bound, after):
                continue
            if after > self._tpot(j, ctx.t_cur, ctx):
                return False   # worsens a bounded victim past its SLO
        return True

    def admits_pinned(self, ctx: AllocationContext) -> bool:
        """A staggered trial's pinned probe K must not break a
        co-scheduled bound either — SLO beats trial fidelity. Compared
        against the no-speculation base state (the demotion target)."""
        if not self.bounds or not ctx.fixed:
            return True
        base_ns = list(ctx.ns)
        for i in ctx.fixed:
            base_ns[i] -= ctx.alloc[i]
        t_zero = ctx.oracle.t_batch(base_ns)
        for j, bound in self.bounds.items():
            after = self._tpot(j, ctx.t_cur, ctx)
            if tpot_within(bound, after):
                continue
            e = ctx.yields.emitted(j, 0 if j in ctx.fixed else ctx.alloc[j])
            if after > (t_zero / e if e > 0 else float("inf")):
                return False
        return True


@dataclass
class MemoryCapConstraint(GrantConstraint):
    """Residency-cap protection (docs/offload.md): deny a grant when the
    predicted per-shard activated union after it exceeds the shard's
    residency capacity (pinned hbm-tier residents + host-tier cache
    slots, `ResidencyState.capacity_experts`) — the pass would activate
    more experts than the shard can keep HBM-resident, forcing streamed
    re-fetches the prefetcher cannot amortize. Same don't-worsen escape
    clause as `SLOTpotConstraint`: a shard already over capacity in the
    base state does not freeze the batch, only grants that push it
    further are denied."""
    residency: object = None

    name = "memory_cap"
    _eps = 1e-9

    def _over(self, per_shard, capacity):
        return [max(u - c, 0.0) for u, c in zip(per_shard, capacity)]

    def admits(self, cand: GrantCandidate, ctx: AllocationContext) -> bool:
        cap = self.residency.capacity_experts
        ns_after = list(ctx.ns)
        ns_after[cand.row] += 1
        after = ctx.oracle.shard_unique(ns_after)
        cur = None
        for s, c in enumerate(cap):
            if after[s] <= c + self._eps:
                continue
            if cur is None:
                cur = ctx.oracle.shard_unique(ctx.ns)
            if after[s] > cur[s] + self._eps:
                return False
        return True

    def admits_pinned(self, ctx: AllocationContext) -> bool:
        if not ctx.fixed:
            return True
        cap = self.residency.capacity_experts
        base_ns = list(ctx.ns)
        for i in ctx.fixed:
            base_ns[i] -= ctx.alloc[i]
        cur = ctx.oracle.shard_unique(ctx.ns)
        base = None
        for s, c in enumerate(cap):
            if cur[s] <= c + self._eps:
                continue
            if base is None:
                base = ctx.oracle.shard_unique(base_ns)
            if cur[s] > base[s] + self._eps:
                return False
        return True


@dataclass
class FetchDeadlineConstraint(GrantConstraint):
    """Fetch-hiding protection (docs/offload.md): a grant is only worth
    its bytes if the host fetches it induces still hide behind the
    draft+sample window the oracle prices with (`fetch_hide`). Deny a
    candidate whose predicted non-overlapped fetch time
    (`BatchCostOracle.fetch_unhidden`) is positive AND worse than the
    current allocation's — speculation that adds un-hideable fetch
    latency has flipped from latency hiding back to latency adding, the
    exact boundary the offload tier's utility calculus cares about."""
    residency: object = None

    name = "fetch_deadline"
    _eps = 1e-12

    def admits(self, cand: GrantCandidate, ctx: AllocationContext) -> bool:
        ns_after = list(ctx.ns)
        ns_after[cand.row] += 1
        after = ctx.oracle.fetch_unhidden(ns_after)
        if after <= self._eps:
            return True
        return not (after > ctx.oracle.fetch_unhidden(ctx.ns) + self._eps)

    def admits_pinned(self, ctx: AllocationContext) -> bool:
        if not ctx.fixed:
            return True
        cur = ctx.oracle.fetch_unhidden(ctx.ns)
        if cur <= self._eps:
            return True
        base_ns = list(ctx.ns)
        for i in ctx.fixed:
            base_ns[i] -= ctx.alloc[i]
        return not (cur > ctx.oracle.fetch_unhidden(base_ns) + self._eps)


# -- admission-side constraints (docs/serving_load.md) ------------------- #
#
# The GrantConstraint pipeline above vets +1-draft grants to rows already
# IN the batch. Under open-loop load the symmetric decision happens one
# level earlier: should a queued request join the batch at all?  Same
# shape — a predicted cost, a bound, an escape clause — applied to joins
# instead of grants.

#: admission verdicts: ADMIT joins the request now; DEFER holds it at the
#: queue head until the batch drains (backpressure); SHED drops it and
#: records the drop as first-class telemetry (a bounded shed request IS a
#: TTFT violation — `slo.ttft_violated`).
ADMIT, DEFER, SHED = "admit", "defer", "shed"


@dataclass
class AdmissionDecision:
    """One join verdict, with the prediction that produced it."""
    action: str                # ADMIT | DEFER | SHED
    predicted_ttft: float = 0.0  # queue delay so far + predicted service
    reason: str = ""


class AdmissionConstraint:
    """One rule of the admission pipeline — the join-side analogue of
    `GrantConstraint`. `decide` vets a single queued request about to
    join; it must be a pure read (no engine or scheduler state mutated),
    so an admission pipeline that always returns ADMIT is bit-identical
    to running without one. Subclass and hand to
    `ContinuousBatchingScheduler(admission=...)`."""

    name = "admission"

    def decide(self, slo, *, queue_delay: float, service_time: float,
               deferrals: int = 0) -> AdmissionDecision:
        return AdmissionDecision(ADMIT, queue_delay + service_time)


@dataclass
class PredictiveTTFTAdmission(AdmissionConstraint):
    """Shed (or defer) joins whose TTFT is already doomed: the request's
    accrued queue delay plus the `BatchCostOracle`-predicted service time
    to its first token (`BatchedEngine.predicted_service_time` — prefill
    passes priced at the CURRENT batch state) already exceeds its TTFT
    bound, so admitting it burns prefill capacity on a guaranteed SLO
    violation and lengthens the shared pass for everyone behind it.

    The escape clause mirrors the grant constraints' don't-worsen rule:
    requests without a TTFT bound are never touched, and a bound met
    within `headroom` admits immediately — under light load the
    constraint never engages and the token streams are bit-identical to
    the unconstrained scheduler. `on_doomed` picks the overload
    behavior: "shed" drops doomed requests (load shedding), "defer"
    holds them at the queue head for up to `max_defers` admission
    rounds (backpressure) before admitting anyway — deferral must never
    become livelock, so the defer budget is the liveness valve."""
    on_doomed: str = "shed"    # "shed" | "defer"
    max_defers: int = 8
    headroom: float = 1.0     # admit when predicted <= headroom * bound

    name = "predictive_ttft"

    def __post_init__(self):
        if self.on_doomed not in (SHED, DEFER):
            raise ValueError(f"on_doomed={self.on_doomed!r} "
                             f"(expected {SHED!r} or {DEFER!r})")

    def decide(self, slo, *, queue_delay: float, service_time: float,
               deferrals: int = 0) -> AdmissionDecision:
        bound = getattr(slo, "ttft", None)
        predicted = queue_delay + service_time
        if bound is None or predicted <= self.headroom * bound:
            return AdmissionDecision(ADMIT, predicted)
        if self.on_doomed == DEFER and deferrals < self.max_defers:
            return AdmissionDecision(DEFER, predicted,
                                     "predicted TTFT past bound")
        return AdmissionDecision(
            SHED if self.on_doomed == SHED else ADMIT, predicted,
            "predicted TTFT past bound" if self.on_doomed == SHED
            else "defer budget exhausted")


@dataclass
class PlanDecision:
    """One request's slice of the step plan."""
    slot: int
    requested: int          # the controller's ask (next_k / hold)
    granted: int            # the planner's joint allocation
    accept_rate: float      # windowed estimate used for the prediction
    phase: str              # controller phase when planned
    held: bool = False      # TEST trial postponed by staggering
    slo_capped: bool = False  # a grant to this row was denied by an SLO

    @property
    def preempted(self) -> bool:
        """Speculation denied outright despite the controller asking."""
        return self.requested > 0 and self.granted == 0


@dataclass
class BatchPlan:
    """The joint allocation for one engine step, plus the predictions the
    telemetry compares against the measured pass (predicted vs measured Δt
    is the planner's own calibration signal)."""
    decisions: Dict[int, PlanDecision] = field(default_factory=dict)
    t_base: float = 0.0        # predicted no-speculation pass seconds
    t_predicted: float = 0.0   # predicted pass seconds at the grants
    tokens_predicted: float = 0.0  # predicted emissions (decode rows)
    held: int = 0              # TEST trials postponed this step
    preempted: int = 0         # requests granted 0 while asking > 0
    slo_denied: int = 0        # rows whose grants an SLO constraint capped
    priced: bool = False       # the oracle actually priced this pass (any
                               # tokens planned) — telemetry's calibration-
                               # sample filter, robust to a predicted 0.0

    @property
    def requested_total(self) -> int:
        return sum(d.requested for d in self.decisions.values())

    @property
    def granted_total(self) -> int:
        return sum(d.granted for d in self.decisions.values())

    @property
    def utility_predicted(self) -> float:
        """Predicted batch utility of the allocation: predicted throughput
        over the batch's predicted no-speculation throughput."""
        n = len(self.decisions)
        if not n or self.t_predicted <= 0 or self.t_base <= 0:
            return 1.0
        return (self.tokens_predicted / self.t_predicted) / (n / self.t_base)


def greedy_allocate(oracle: cm.BatchCostOracle, base_ns, decode, caps,
                    accepts, *, fixed=frozenset(), util_floor: float = 1.0,
                    constraints: Optional[Sequence[GrantConstraint]] = None,
                    yield_model: Optional[DraftYieldModel] = None):
    """Greedy marginal-utility water-filling through the constraint
    pipeline.

    Starting from `base_ns` (every decode row at its committed token, plus
    any co-scheduled prefill chunks), repeatedly grant +1 draft token to
    the *admissible* decode row with the highest predicted Δtokens/Δt_batch,
    where Δtokens comes from `yield_model` (default: the flat-acceptance
    geometric increment accepts[i]^(k_i+1)) and Δt_batch from the cost
    oracle at the *current* allocation — so union saturation cheapens later
    grants and roofline crossover taxes them, exactly as the shared pass
    will. A candidate is admissible when every constraint admits it;
    `constraints=None` builds the default pipeline [BreakEvenConstraint
    (util_floor)], which reproduces the pre-pipeline stopping rule — stop
    when the best marginal rate falls below `util_floor * len(decode) /
    t_base` — bit for bit. The loop ends when no admissible candidate
    remains. Ties break on the lowest row index, keeping the allocation
    deterministic.

    `fixed` rows are pinned at caps[i] before water-filling begins — the
    staggered TEST trial whose probe K must run unmodified. A constraint
    may veto the pinned state (`admits_pinned` — the SLO constraint does,
    when a probe would break a co-scheduled bound); the pins are then
    demoted to ordinary capped candidates.

    Returns (alloc, info) with alloc = {row: drafts granted} and info
    carrying t_base / t_alloc / r_floor plus `denied` ({constraint name:
    rows it vetoed at least once}) for telemetry."""
    ym = yield_model or DraftYieldModel(accepts)
    cons = (list(constraints) if constraints is not None
            else [BreakEvenConstraint(util_floor=util_floor)])
    ns = list(base_ns)
    alloc = {i: 0 for i in decode}
    t_base = oracle.t_batch(ns)
    for i in fixed:
        alloc[i] = caps[i]
        ns[i] += caps[i]
    t_cur = oracle.t_batch(ns)
    ctx = AllocationContext(oracle=oracle, decode=decode, caps=caps,
                            accepts=accepts, yields=ym, ns=ns, alloc=alloc,
                            t_base=t_base, t_cur=t_cur, fixed=fixed)
    denied: Dict[str, set] = {}
    if fixed and not all(c.admits_pinned(ctx) for c in cons):
        for i in fixed:
            ns[i] -= caps[i]
            alloc[i] = 0
            denied.setdefault("pinned", set()).add(i)
        fixed = ctx.fixed = frozenset()
        ctx.t_cur = t_cur = oracle.t_batch(ns)
    for c in cons:
        c.prepare(ctx)
    while True:
        best = None
        for i in decode:
            if i in fixed or alloc[i] >= caps[i]:
                continue
            d_tok = ym.marginal(i, alloc[i])
            ns[i] += 1
            t_after = oracle.t_batch(ns)
            ns[i] -= 1
            d_t = t_after - t_cur
            rate = (d_tok / d_t) if d_t > 0 else float("inf")
            cand = GrantCandidate(row=i, k_current=alloc[i], d_tokens=d_tok,
                                  d_t=d_t, rate=rate, t_after=t_after)
            veto = next((c for c in cons if not c.admits(cand, ctx)), None)
            if veto is not None:
                denied.setdefault(veto.name, set()).add(i)
                continue
            if best is None or cand.rate > best.rate:
                best = cand
        if best is None:
            break
        alloc[best.row] += 1
        ns[best.row] += 1
        ctx.t_cur = t_cur = oracle.t_batch(ns)
    floor = next((c.r_floor for c in cons
                  if isinstance(c, BreakEvenConstraint)), 0.0)
    return alloc, {"t_base": t_base, "t_alloc": t_cur, "r_floor": floor,
                   "denied": denied}


class BatchSpecPlanner:
    """Joint {K_i} allocator for one `BatchedEngine` (see module docstring).

    Stateless across steps except the staggering round-robin pointer, so a
    planner can be shared by the engine for the whole serving run."""

    def __init__(self, cfg, hw: cm.Hardware = None, *, affinity: float = 0.0,
                 window: int = 0, config: Optional[PlannerConfig] = None,
                 placement: Optional[cm.ExpertPlacement] = None,
                 calibration: Optional[cm.Calibration] = None,
                 residency=None,
                 precision: Optional[cm.Precision] = None,
                 drafter_precision: Optional[cm.Precision] = None):
        self.cfg = cfg
        self.hw = hw or cm.TPU_V5E
        self.affinity = affinity
        self.window = window
        self.config = config or PlannerConfig()
        #: per-tensor-class bytes-per-param spec (cost_model.Precision,
        #: docs/quantization.md) every oracle this planner builds prices
        #: with — quantized experts move the break-even water level and
        #: the fetch deadlines; None is bit-identical to the bf16 default
        self.precision = precision
        #: bytes-per-param spec for the *drafter's* weights (priced at the
        #: dense class — an int8 drafter halves the draft window the fetch
        #: scheduler hides behind); None is bit-identical to bf16
        self.drafter_precision = drafter_precision
        #: wall-clock residual correction (cost_model.Calibration, fitted
        #: by --calibrate) applied to every oracle this planner prices
        #: with; None is bit-identical to the uncalibrated planner
        self.calibration = calibration
        if residency is not None and placement is None:
            placement = residency.placement
        if placement is not None:
            if not cfg.is_moe:
                raise ValueError(
                    f"ExpertPlacement supplied for the dense (non-MoE) "
                    f"config {cfg.name!r} — there are no experts to shard")
            placement.validate_experts(cfg.num_experts)
        if residency is not None and \
                residency.placement.shard_of != placement.shard_of:
            raise ValueError("residency tracks a different placement than "
                             "the planner prices with")
        self.placement = placement
        #: core.residency.ResidencyState over a host-tiered placement —
        #: switches oracles to fetch-aware pricing and arms the residency
        #: constraints; None is bit-identical to the flat planner
        self.residency = residency
        self._stagger_tick = 0   # round-robin fairness across trialing rows

    # ------------------------------------------------------------------ #

    def _accept_rate(self, controller) -> Optional[float]:
        analyzer = getattr(controller, "analyzer", None)
        if analyzer is None or not hasattr(analyzer, "accept_rate"):
            return None
        return analyzer.accept_rate(self.config.accept_window)

    def _accept_curve(self, controller, max_k: int) -> Optional[list]:
        analyzer = getattr(controller, "analyzer", None)
        if analyzer is None or not hasattr(analyzer, "accept_curve"):
            return None
        return analyzer.accept_curve(max_k, self.config.accept_window)

    def build_constraints(self, decode, requested,
                          slos: Dict[int, RequestSLO]
                          ) -> List[GrantConstraint]:
        """The default pipeline: the (latency-weighted) break-even water
        level plus victim-protecting TPOT bounds. Override or extend in a
        subclass to plug in additional constraints."""
        cfgp = self.config
        weights = None
        if cfgp.latency_tier_weight != 1.0:
            lat = {i: cfgp.latency_tier_weight for i in decode
                   if i in slos and slos[i].tier == LATENCY}
            weights = lat or None
        bounds = {i: slos[i].tpot for i in decode
                  if i in slos and slos[i].tpot is not None}
        cons: List[GrantConstraint] = [
            BreakEvenConstraint(util_floor=cfgp.util_floor,
                                weights=weights),
            SLOTpotConstraint(bounds=bounds)]
        if self.residency is not None and self.residency.has_host_tier:
            cons.append(MemoryCapConstraint(residency=self.residency))
            cons.append(FetchDeadlineConstraint(residency=self.residency))
        return cons

    def plan(self, controllers: Dict[int, object], context_lens, *,
             prefill_tokens: Optional[Dict[int, int]] = None,
             shard_weights: Optional[Dict[int, object]] = None,
             slos: Optional[Dict[int, RequestSLO]] = None) -> BatchPlan:
        """Plan one step. `controllers` maps decode row -> its controller
        (asks are collected here: `next_k()`, or `hold()` for staggered
        TEST rows); `context_lens` is the full [B] row table's cache
        lengths; `prefill_tokens` maps prefill rows to their co-scheduled
        chunk sizes (they share the pass and its expert union, so the
        water-filling prices them in); `shard_weights` maps rows to their
        measured per-shard routing profiles ([n_shards] weights, e.g. the
        engine's EMA of per-row per-shard activation telemetry) so the
        sharded oracle can tell a hot-shard-bound grant from a cold one
        (rows without a profile default to placement-proportional mass);
        `slos` maps decode rows to their `RequestSLO`s — TPOT bounds and
        tiers become constraints on the joint allocation (docs/slo.md)."""
        cfgp = self.config
        b = len(context_lens)
        pre = {i: max(int(p), 0)
               for i, p in (prefill_tokens or {}).items() if p > 0}
        decode = sorted(controllers)
        slos = slos or {}
        joint = cfgp.policy == "joint"

        # -- phase staggering: at most one TEST trial per shared pass ----
        held = frozenset()
        if joint and cfgp.stagger_tests and len(decode) > 1:
            testers = [i for i in decode
                       if getattr(controllers[i], "phase", "") == TEST
                       and hasattr(controllers[i], "hold")]
            if len(testers) > 1:
                keep = testers[self._stagger_tick % len(testers)]
                held = frozenset(t for t in testers if t != keep)
                self._stagger_tick += 1

        requested, phases, accepts = {}, {}, {}
        for i in decode:
            ctl = controllers[i]
            phases[i] = getattr(ctl, "phase", "")
            requested[i] = int(ctl.hold() if i in held else ctl.next_k())
            a = self._accept_rate(ctl)
            accepts[i] = cfgp.default_accept if a is None else a
        curves = None
        if cfgp.use_accept_curve:
            curves = {}
            for i in decode:
                c = self._accept_curve(controllers[i],
                                       max(requested[i], 1))
                if c is not None:
                    curves[i] = c
        ym = DraftYieldModel(accepts, curves)

        base_ns = [0] * b
        for i in decode:
            base_ns[i] = 1
        for i, p in pre.items():
            base_ns[i] = p
        sw = None
        if self.placement is not None and shard_weights:
            sw = [shard_weights.get(i) for i in range(b)]
        fetch_hide = 0.0
        if self.residency is not None and self.residency.has_host_tier:
            # the overlap window a fetch can hide behind: drafting and
            # rejection sampling happen off the verification pass's
            # critical path, so the longest row's draft+sample span (at
            # its *asked* K — grants are not known yet) bounds what the
            # prefetcher overlaps; on top of that, a fetch for layer l's
            # experts also hides behind the compute of layers < l in the
            # same pass, priced from a fetch-free preliminary oracle's
            # base-pass time (docs/offload.md)
            base_hide = max(
                (cm.draft_time(self.hw, requested[i],
                               precision=self.drafter_precision)
                 + cm.sample_time(requested[i]) for i in decode),
                default=0.0)
            t_pre = 0.0
            if decode or pre:
                pre_oracle = cm.BatchCostOracle(
                    self.cfg, self.hw, context_lens,
                    affinity=self.affinity, window=self.window,
                    prefill_tokens=[pre.get(i, 0) for i in range(b)],
                    placement=self.placement, shard_weights=sw,
                    assume_balanced=not cfgp.shard_aware,
                    calibration=self.calibration,
                    precision=self.precision)
                t_pre = pre_oracle.t_batch(base_ns)
            if self.residency.granularity == "layer":
                fetch_hide = cm.fetch_hide_schedule(
                    self.cfg, base_hide, t_pre)
            else:
                fracs = cm.moe_hide_fracs(self.cfg)
                fetch_hide = base_hide + (fracs[0] * t_pre
                                          if fracs else 0.0)
        oracle = cm.BatchCostOracle(
            self.cfg, self.hw, context_lens, affinity=self.affinity,
            window=self.window,
            prefill_tokens=[pre.get(i, 0) for i in range(b)],
            placement=self.placement, shard_weights=sw,
            assume_balanced=not cfgp.shard_aware,
            calibration=self.calibration,
            residency=self.residency, fetch_hide=fetch_hide,
            precision=self.precision)

        # -- allocate ----------------------------------------------------
        # bypass: independent policy, or a single-span pass (B=1 — the
        # paper's regime, where Cascade alone is the policy, the planner
        # must be invisible bit for bit, and the request's own SLO is the
        # per-request CascadeConfig.slo_tpot check)
        singleton = len(decode) == 1 and not pre
        slo_capped: set = set()
        if not joint or singleton:
            alloc = dict(requested)
        else:
            # the (single) surviving trial runs its probe K unmodified
            fixed = frozenset(
                i for i in decode
                if phases[i] == TEST and i not in held and requested[i] > 0)
            alloc, info = greedy_allocate(
                oracle, base_ns, decode, requested, accepts, fixed=fixed,
                util_floor=cfgp.util_floor, yield_model=ym,
                constraints=self.build_constraints(decode, requested, slos))
            slo_capped = (info["denied"].get("slo_tpot", set())
                          | info["denied"].get("pinned", set()))

        # -- predictions + decisions ------------------------------------
        ns = list(base_ns)
        for i in decode:
            ns[i] += alloc[i]
        any_tokens = bool(decode or pre)
        t_base = oracle.t_batch(base_ns) if any_tokens else 0.0
        t_pred = oracle.t_batch(ns) if any_tokens else 0.0
        decisions = {
            i: PlanDecision(slot=i, requested=requested[i],
                            granted=alloc[i], accept_rate=accepts[i],
                            phase=phases[i], held=i in held,
                            slo_capped=i in slo_capped)
            for i in decode}
        return BatchPlan(
            decisions=decisions, t_base=t_base, t_predicted=t_pred,
            tokens_predicted=sum(ym.emitted(i, alloc[i]) for i in decode),
            held=len(held),
            preempted=sum(1 for d in decisions.values() if d.preempted),
            slo_denied=len(slo_capped), priced=any_tokens)
