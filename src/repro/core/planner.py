"""Batch-level speculation planner (beyond-paper; the batching analogue of
the paper's per-request utility rule, §4-§5).

Under continuous batching the verification cost is *shared*: B requests'
draft spans activate a union of experts, so one request's aggressive K
taxes everyone sharing the pass — miscoordination the per-request Cascade
controllers cannot see (each one only observes its own attributed share).
`BatchSpecPlanner` closes the loop at the batch level. Each step it takes
every live request's controller *ask* (the Cascade FSM still drives
exploration and per-request disable), then jointly decides the *grants*
{K_i} by greedy marginal-utility water-filling:

  * price candidate allocations through the data-movement cost model
    (`cost_model.BatchCostOracle` — union expert bytes, per-row KV,
    shared-pass FLOPs, the memory/compute roofline crossover);
  * predict each request's marginal token yield from its windowed draft
    acceptance (`UtilityAnalyzer.accept_rate`): granting the (k+1)-th
    draft token to a request with acceptance a is worth a^(k+1) expected
    extra emissions;
  * repeatedly grant +1 draft token to the request with the highest
    predicted Δtokens/Δt_batch, and stop when the best marginal utility —
    that rate over the batch's no-speculation rate B/t_base — drops below
    `util_floor` (= 1: the paper's "disable speculation" rule, now per
    grant instead of per request, which also preempts speculation when
    prefill chunks or high occupancy have pushed the shared pass past the
    roofline crossover where every extra token costs real time).

Trial hygiene: the planner staggers Cascade TEST phases so at most one
request trials an off-policy K per shared pass (`SpeculationManager.hold`)
— a concurrent trial shifts the expert union under every other request's
attributed-cost measurement. The one trialing request is granted its probe
K in full, so the FSM measures exactly what it asked to measure.

Expert parallelism (docs/expert_parallel.md): under an `ExpertPlacement`
with n_shards > 1 the oracle prices each candidate allocation with the
max-over-shards roofline, so Δt_batch is the *hottest shard's* delta — a
+1 grant to a request whose routing profile concentrates on the gating
shard costs more than one spreading over cold shards, and water-filling
steers grants away from the shard that gates the pass.
`PlannerConfig(shard_aware=False)` is the deliberately naive comparator
that spreads the union evenly over shards (the "global-union" planner the
--ep-sweep gates against).

Degradation: at B=1 (a single span in the pass) the planner is bypassed —
grants equal asks bit for bit, reproducing the legacy per-request
controller path exactly — and `policy="independent"` is the escape hatch
that bypasses it at every batch size.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from . import cost_model as cm
from .cost_model import expected_emitted
from .manager import TEST


@dataclass(frozen=True)
class PlannerConfig:
    #: "joint" — batch-level water-filling; "independent" — escape hatch,
    #: every grant equals its controller's ask (the pre-planner engine)
    policy: str = "joint"
    #: stop granting when the best marginal utility drops below this
    #: (1.0 = the paper's break-even rule at batch level)
    util_floor: float = 1.0
    #: acceptance prior for requests with no speculative history yet
    default_accept: float = 0.5
    #: analyzer window for the acceptance estimate
    accept_window: int = 16
    #: stagger Cascade TEST phases to one trial per shared pass
    stagger_tests: bool = True
    #: with an ExpertPlacement: True prices the max-over-shards roofline
    #: (the hottest shard gates the pass); False is the global-union
    #: comparator that assumes the union spreads evenly over shards
    shard_aware: bool = True


@dataclass
class PlanDecision:
    """One request's slice of the step plan."""
    slot: int
    requested: int          # the controller's ask (next_k / hold)
    granted: int            # the planner's joint allocation
    accept_rate: float      # windowed estimate used for the prediction
    phase: str              # controller phase when planned
    held: bool = False      # TEST trial postponed by staggering

    @property
    def preempted(self) -> bool:
        """Speculation denied outright despite the controller asking."""
        return self.requested > 0 and self.granted == 0


@dataclass
class BatchPlan:
    """The joint allocation for one engine step, plus the predictions the
    telemetry compares against the measured pass (predicted vs measured Δt
    is the planner's own calibration signal)."""
    decisions: Dict[int, PlanDecision] = field(default_factory=dict)
    t_base: float = 0.0        # predicted no-speculation pass seconds
    t_predicted: float = 0.0   # predicted pass seconds at the grants
    tokens_predicted: float = 0.0  # predicted emissions (decode rows)
    held: int = 0              # TEST trials postponed this step
    preempted: int = 0         # requests granted 0 while asking > 0

    @property
    def requested_total(self) -> int:
        return sum(d.requested for d in self.decisions.values())

    @property
    def granted_total(self) -> int:
        return sum(d.granted for d in self.decisions.values())

    @property
    def utility_predicted(self) -> float:
        """Predicted batch utility of the allocation: predicted throughput
        over the batch's predicted no-speculation throughput."""
        n = len(self.decisions)
        if not n or self.t_predicted <= 0 or self.t_base <= 0:
            return 1.0
        return (self.tokens_predicted / self.t_predicted) / (n / self.t_base)


def greedy_allocate(oracle: cm.BatchCostOracle, base_ns, decode, caps,
                    accepts, *, fixed=frozenset(), util_floor: float = 1.0):
    """Greedy marginal-utility water-filling.

    Starting from `base_ns` (every decode row at its committed token, plus
    any co-scheduled prefill chunks), repeatedly grant +1 draft token to
    the decode row with the highest predicted Δtokens/Δt_batch, where
    Δtokens = accepts[i]^(k_i+1) (the next draft's expected yield) and
    Δt_batch comes from the cost oracle at the *current* allocation — so
    union saturation cheapens later grants and roofline crossover taxes
    them, exactly as the shared pass will. Stops when the best marginal
    rate falls below `util_floor * len(decode) / t_base`, the batch's
    no-speculation token rate: a grant below that water level would lower
    batch throughput (util_floor=1 is the paper's break-even rule).

    `fixed` rows are pinned at caps[i] before water-filling begins — the
    staggered TEST trial whose probe K must run unmodified. Ties break on
    the lowest row index, keeping the allocation deterministic.

    Returns (alloc, info) with alloc = {row: drafts granted} and info
    carrying t_base / t_alloc / r_floor for telemetry."""
    ns = list(base_ns)
    alloc = {i: 0 for i in decode}
    t_base = oracle.t_batch(ns)
    r_floor = (util_floor * len(decode) / t_base) if decode else 0.0
    for i in fixed:
        alloc[i] = caps[i]
        ns[i] += caps[i]
    t_cur = oracle.t_batch(ns)
    while True:
        best, best_rate = None, 0.0
        for i in decode:
            if i in fixed or alloc[i] >= caps[i]:
                continue
            d_tok = accepts[i] ** (alloc[i] + 1)
            ns[i] += 1
            d_t = oracle.t_batch(ns) - t_cur
            ns[i] -= 1
            rate = (d_tok / d_t) if d_t > 0 else float("inf")
            if best is None or rate > best_rate:
                best, best_rate = i, rate
        if best is None or best_rate < r_floor:
            break
        alloc[best] += 1
        ns[best] += 1
        t_cur = oracle.t_batch(ns)
    return alloc, {"t_base": t_base, "t_alloc": t_cur, "r_floor": r_floor}


class BatchSpecPlanner:
    """Joint {K_i} allocator for one `BatchedEngine` (see module docstring).

    Stateless across steps except the staggering round-robin pointer, so a
    planner can be shared by the engine for the whole serving run."""

    def __init__(self, cfg, hw: cm.Hardware = None, *, affinity: float = 0.0,
                 window: int = 0, config: Optional[PlannerConfig] = None,
                 placement: Optional[cm.ExpertPlacement] = None):
        self.cfg = cfg
        self.hw = hw or cm.TPU_V5E
        self.affinity = affinity
        self.window = window
        self.config = config or PlannerConfig()
        if placement is not None:
            if not cfg.is_moe:
                raise ValueError(
                    f"ExpertPlacement supplied for the dense (non-MoE) "
                    f"config {cfg.name!r} — there are no experts to shard")
            placement.validate_experts(cfg.num_experts)
        self.placement = placement
        self._stagger_tick = 0   # round-robin fairness across trialing rows

    # ------------------------------------------------------------------ #

    def _accept_rate(self, controller) -> Optional[float]:
        analyzer = getattr(controller, "analyzer", None)
        if analyzer is None or not hasattr(analyzer, "accept_rate"):
            return None
        return analyzer.accept_rate(self.config.accept_window)

    def plan(self, controllers: Dict[int, object], context_lens, *,
             prefill_tokens: Optional[Dict[int, int]] = None,
             shard_weights: Optional[Dict[int, object]] = None) -> BatchPlan:
        """Plan one step. `controllers` maps decode row -> its controller
        (asks are collected here: `next_k()`, or `hold()` for staggered
        TEST rows); `context_lens` is the full [B] row table's cache
        lengths; `prefill_tokens` maps prefill rows to their co-scheduled
        chunk sizes (they share the pass and its expert union, so the
        water-filling prices them in); `shard_weights` maps rows to their
        measured per-shard routing profiles ([n_shards] weights, e.g. the
        engine's EMA of per-row per-shard activation telemetry) so the
        sharded oracle can tell a hot-shard-bound grant from a cold one
        (rows without a profile default to placement-proportional mass)."""
        cfgp = self.config
        b = len(context_lens)
        pre = {i: max(int(p), 0)
               for i, p in (prefill_tokens or {}).items() if p > 0}
        decode = sorted(controllers)
        joint = cfgp.policy == "joint"

        # -- phase staggering: at most one TEST trial per shared pass ----
        held = frozenset()
        if joint and cfgp.stagger_tests and len(decode) > 1:
            testers = [i for i in decode
                       if getattr(controllers[i], "phase", "") == TEST
                       and hasattr(controllers[i], "hold")]
            if len(testers) > 1:
                keep = testers[self._stagger_tick % len(testers)]
                held = frozenset(t for t in testers if t != keep)
                self._stagger_tick += 1

        requested, phases, accepts = {}, {}, {}
        for i in decode:
            ctl = controllers[i]
            phases[i] = getattr(ctl, "phase", "")
            requested[i] = int(ctl.hold() if i in held else ctl.next_k())
            a = self._accept_rate(ctl)
            accepts[i] = cfgp.default_accept if a is None else a

        base_ns = [0] * b
        for i in decode:
            base_ns[i] = 1
        for i, p in pre.items():
            base_ns[i] = p
        sw = None
        if self.placement is not None and shard_weights:
            sw = [shard_weights.get(i) for i in range(b)]
        oracle = cm.BatchCostOracle(
            self.cfg, self.hw, context_lens, affinity=self.affinity,
            window=self.window,
            prefill_tokens=[pre.get(i, 0) for i in range(b)],
            placement=self.placement, shard_weights=sw,
            assume_balanced=not cfgp.shard_aware)

        # -- allocate ----------------------------------------------------
        # bypass: independent policy, or a single-span pass (B=1 — the
        # paper's regime, where Cascade alone is the policy and the
        # planner must be invisible, bit for bit)
        singleton = len(decode) == 1 and not pre
        if not joint or singleton:
            alloc = dict(requested)
        else:
            # the (single) surviving trial runs its probe K unmodified
            fixed = frozenset(
                i for i in decode
                if phases[i] == TEST and i not in held and requested[i] > 0)
            alloc, _ = greedy_allocate(oracle, base_ns, decode, requested,
                                       accepts, fixed=fixed,
                                       util_floor=cfgp.util_floor)

        # -- predictions + decisions ------------------------------------
        ns = list(base_ns)
        for i in decode:
            ns[i] += alloc[i]
        any_tokens = bool(decode or pre)
        t_base = oracle.t_batch(base_ns) if any_tokens else 0.0
        t_pred = oracle.t_batch(ns) if any_tokens else 0.0
        decisions = {
            i: PlanDecision(slot=i, requested=requested[i],
                            granted=alloc[i], accept_rate=accepts[i],
                            phase=phases[i], held=i in held)
            for i in decode}
        return BatchPlan(
            decisions=decisions, t_base=t_base, t_predicted=t_pred,
            tokens_predicted=sum(
                expected_emitted(accepts[i], alloc[i]) for i in decode),
            held=len(held),
            preempted=sum(1 for d in decisions.values() if d.preempted))
