"""Cascade speculation manager (paper §5): the test-and-set state machine
with dynamic disable, adaptive back-off, and hill-climbing K search.

Per-request FSM:

    BASELINE --(baseline measured)--> TEST --(trials done)--> SET --+
        ^                                                           |
        +--------------------(set phase expires)--------------------+

  * BASELINE: run `baseline_iters` iterations at K=0 to measure the
    no-speculation iteration time (§5.3); re-entered when the analyzer's
    refresh interval expires.
  * TEST: up to `max_trials` trials of `trial_len` iterations each; the K
    for each trial comes from hill-climbing on (K, utility) of previous
    trials (§5.6) with three early exits: monotone utility decline,
    K reaching the floor with U<1, and successive utilities within 10%.
  * SET: hold best-K for `set_len` iterations; if best utility < 1, hold
    K=0 instead (§5.4) and double the set length — adaptive back-off (§5.5).

Ablation switches (`enable_disable`, `enable_backoff`, `enable_hillclimb`)
reproduce the paper's Fig. 18 increments."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .slo import tpot_within
from .utility import IterationRecord, UtilityAnalyzer

BASELINE, TEST, SET = "baseline", "test", "set"


@dataclass
class CascadeConfig:
    trial_len: int = 4          # t  (§6)
    max_trials: int = 4         # M; T = M*t = 16
    set_len: int = 16           # S
    max_set_len: int = 512      # back-off ceiling
    k_start: int = 3            # first-ever trial K (§7.4: default static-K)
    k_max: int = 8
    k_min: int = 1
    converge_tol: float = 0.10  # early-exit (3): utilities within 10%
    enable_disable: bool = True
    enable_backoff: bool = True
    enable_hillclimb: bool = True
    baseline_iters: int = 4
    baseline_refresh: int = 100
    # beyond-paper (§8.3 discussion): per-request TPOT SLO. Trial/set K
    # values whose *measured* per-K TPOT estimate exceeds the bound are
    # excluded; K=0 (TPOT = t_base) always satisfies any SLO >= t_base.
    slo_tpot: Optional[float] = None
    # beyond-paper: probe k_max as the second trial before hill-climbing.
    # Fixes the non-monotone utility landscapes of multi-branch (tree)
    # drafters, where the paper's directional search from k_start descends
    # into K=0 and misses a high-K peak (EXPERIMENTS.md §Beyond-paper 7).
    multi_start: bool = False


@dataclass
class SpeculationManager:
    cfg: CascadeConfig = field(default_factory=CascadeConfig)
    analyzer: Optional[UtilityAnalyzer] = None

    phase: str = BASELINE
    _phase_left: int = 0
    _k_now: int = 0
    # test-phase bookkeeping
    _trials: List[Tuple[int, float]] = field(default_factory=list)  # (k, U)
    _trial_records: List[IterationRecord] = field(default_factory=list)
    _trials_done: int = 0
    # set-phase bookkeeping
    _set_len_now: int = 0
    _last_set_k: Optional[int] = None
    # batch-planner phase hook: when True the upcoming iteration is an
    # off-schedule filler (a postponed TEST trial) — observe() feeds the
    # analyzer but freezes the FSM for that iteration
    _held: bool = False
    # history of (k, utility) across whole request, for K_start selection
    history: List[Tuple[int, float]] = field(default_factory=list)

    def __post_init__(self):
        if self.analyzer is None:
            self.analyzer = UtilityAnalyzer(
                baseline_iters=self.cfg.baseline_iters,
                baseline_refresh=self.cfg.baseline_refresh)
        self._set_len_now = self.cfg.set_len
        self._enter_baseline()

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #

    def next_k(self) -> int:
        """Speculation length to use for the upcoming iteration."""
        if not self.cfg.enable_disable:
            # Fig. 18 'no optimizations': static K = k_start (after baseline)
            return 0 if self.phase == BASELINE else self.cfg.k_start
        return self._k_now

    def hold(self) -> int:
        """Batch-planner phase hook: postpone the upcoming TEST-phase trial
        iteration by one step (the planner staggers trials so at most one
        request runs an off-policy K per shared pass — a concurrent trial
        would pollute every other request's attributed-cost signal).

        The postponed iteration runs at the steady-state K instead — the
        last set-phase K, or 0 before one exists — and its record feeds the
        analyzer (k-tagged, so windowed stats stay honest) but does NOT
        count toward the trial: the FSM is frozen for exactly one observe().
        Outside TEST this is just `next_k()` — there is nothing to stagger.
        """
        if not self.cfg.enable_disable or self.phase != TEST:
            return self.next_k()
        self._held = True
        k = self._last_set_k if self._last_set_k is not None else 0
        return max(0, min(k, self.cfg.k_max))

    def observe(self, rec: IterationRecord) -> None:
        """Feed back the completed iteration; advances the FSM (unless this
        iteration was a planner-held filler — see `hold`)."""
        self.analyzer.observe(rec)
        if self._held:
            self._held = False
            return
        if not self.cfg.enable_disable:
            # static mode: only track the initial baseline measurement
            if self.phase == BASELINE:
                self._phase_left -= 1
                if self._phase_left <= 0:
                    self.phase = SET
            return
        if self.phase == TEST:
            self._trial_records.append(rec)
        self._phase_left -= 1
        if self._phase_left <= 0:
            self._advance()

    # ------------------------------------------------------------------ #
    # FSM transitions
    # ------------------------------------------------------------------ #

    def _enter_baseline(self):
        self.phase = BASELINE
        self._k_now = 0
        self._phase_left = self.cfg.baseline_iters

    def _enter_test(self):
        self.phase = TEST
        self._trials = []
        self._trials_done = 0
        self._trial_records = []
        self._k_now = self._pick_k_start()
        self._phase_left = self.cfg.trial_len

    def _enter_set(self, k: int):
        self.phase = SET
        self._k_now = k
        if k == 0 and self.cfg.enable_backoff:
            self._set_len_now = min(self._set_len_now * 2,
                                    self.cfg.max_set_len)
        elif k > 0:
            self._set_len_now = self.cfg.set_len
        self._last_set_k = k
        self._phase_left = self._set_len_now

    def _advance(self):
        if self.phase == BASELINE:
            self._enter_test()
            return
        if self.phase == SET:
            if self.analyzer.needs_baseline():
                self._enter_baseline()
            else:
                self._enter_test()
            return
        # TEST: a trial just finished
        u = self.analyzer.trial_utility(self._trial_records)
        self._trials.append((self._k_now, u))
        self.history.append((self._k_now, u))
        self._trial_records = []
        self._trials_done += 1

        nxt = self._next_trial_k()
        if nxt is None or self._trials_done >= self.cfg.max_trials:
            self._enter_set(self._choose_set_k())
        else:
            self._k_now = nxt
            self._phase_left = self.cfg.trial_len

    # ------------------------------------------------------------------ #
    # hill-climbing search (§5.6)
    # ------------------------------------------------------------------ #

    def _pick_k_start(self) -> int:
        """§5.3: scan recent history for the non-zero K with highest utility;
        §5.4: after a disabled set phase, restart conservatively at K=1."""
        if self._last_set_k == 0:
            return self.cfg.k_min
        recent = [h for h in self.history[-12:] if h[0] > 0]
        if recent:
            k = max(recent, key=lambda h: h[1])[0]
            return max(self.cfg.k_min, min(k, self.cfg.k_max))
        return max(self.cfg.k_min, min(self.cfg.k_start, self.cfg.k_max))

    def _slo_allows(self, k: int) -> bool:
        """True if K's measured TPOT estimate satisfies the SLO (unknown Ks
        are allowed — testing them is how we learn). The comparison itself
        is `slo.tpot_within`, the one predicate shared with the batch
        planner's predicted-TPOT grant constraint (docs/slo.md)."""
        if self.cfg.slo_tpot is None or k == 0:
            return True
        base = self.analyzer.baseline_time
        if base is None:
            return True
        recs = [r for r in self.analyzer._records if r.k == k][-8:]
        if not recs:
            return True
        tpot = (sum(r.t_iter for r in recs) / max(
            sum(r.tokens for r in recs), 1))
        return tpot_within(self.cfg.slo_tpot, tpot)

    def _next_trial_k(self) -> Optional[int]:
        """Next K to trial, or None to exit the test phase early."""
        k_cur, u_cur = self._trials[-1]

        # multi-start: second trial probes the far end of the K range
        if (self.cfg.multi_start and len(self._trials) == 1
                and k_cur != self.cfg.k_max and self.cfg.k_max > 1):
            return self.cfg.k_max

        # early exit: at the conservative floor and still losing -> disable
        if u_cur < 1.0 and k_cur <= self.cfg.k_min:
            return None

        if not self.cfg.enable_hillclimb:
            return None  # single trial at K_start (Fig. 18 increments)

        if len(self._trials) == 1:
            direction = 1 if u_cur >= 1.0 else -1
        else:
            k_prev, u_prev = self._trials[-2]
            # early exit: utilities converged within 10%
            if u_prev > 0 and abs(u_cur - u_prev) / u_prev < self.cfg.converge_tol:
                return None
            # early exit: monotone decline past the peak
            if len(self._trials) >= 3:
                u3 = [u for _, u in self._trials[-3:]]
                if u3[0] > u3[1] > u3[2]:
                    return None
            move = k_cur - k_prev
            improved = u_cur >= u_prev
            if move == 0:
                direction = 1 if improved else -1
            else:
                direction = (1 if move > 0 else -1) * (1 if improved else -1)

        nxt = k_cur + direction
        if nxt < self.cfg.k_min:
            return None  # would leave the valid range downward -> disable
        nxt = min(nxt, self.cfg.k_max)
        if any(k == nxt for k, _ in self._trials):
            return None  # revisiting -> converged
        while nxt > self.cfg.k_min and not self._slo_allows(nxt):
            nxt -= 1     # SLO: climb no higher than the latency bound allows
        if not self._slo_allows(nxt):
            # the downclimb bottomed out at k_min and even k_min violates
            # the bound: trialing it anyway would knowingly run an
            # SLO-breaking K for trial_len iterations. Disable instead —
            # _choose_set_k's SLO filter then settles on K=0.
            return None
        if any(k == nxt for k, _ in self._trials):
            return None
        return nxt

    def _choose_set_k(self) -> int:
        trials = [t for t in self._trials if self._slo_allows(t[0])]
        if not trials:
            return 0  # no K satisfies the SLO -> no speculation
        best_k, best_u = max(trials, key=lambda t: t[1])
        if best_u < 1.0:
            return 0  # §5.4: disable speculation for the set phase
        return best_k
