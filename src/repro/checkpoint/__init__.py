from .checkpoint import restore, save
