"""Pytree checkpointing via msgpack (no orbax in this environment).

Arrays are stored as (dtype, shape, raw bytes); bfloat16 round-trips through
uint16 views. Restores onto host then device_put — adequate for the example
runs; a production deployment would swap in tensorstore-backed async
checkpointing behind the same two functions."""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_BF16 = "bfloat16"


def _encode_leaf(x):
    x = np.asarray(x)
    if x.dtype == jnp.bfloat16:
        return {"__arr__": True, "dtype": _BF16, "shape": list(x.shape),
                "data": x.view(np.uint16).tobytes()}
    return {"__arr__": True, "dtype": str(x.dtype), "shape": list(x.shape),
            "data": x.tobytes()}


def _decode_leaf(d):
    if d["dtype"] == _BF16:
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    arr = np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])
    return jnp.asarray(arr)


def _to_serializable(tree):
    if isinstance(tree, dict):
        return {k: _to_serializable(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__seq__": type(tree).__name__,
                "items": [_to_serializable(v) for v in tree]}
    if hasattr(tree, "shape"):
        return _encode_leaf(tree)
    return {"__py__": True, "value": tree}


def _from_serializable(obj):
    if isinstance(obj, dict):
        if obj.get("__arr__"):
            return _decode_leaf(obj)
        if obj.get("__py__"):
            return obj["value"]
        if "__seq__" in obj:
            items = [_from_serializable(v) for v in obj["items"]]
            return tuple(items) if obj["__seq__"] == "tuple" else items
        return {k: _from_serializable(v) for k, v in obj.items()}
    return obj


def save(path: str, tree: Any) -> None:
    tree = jax.device_get(tree)
    payload = msgpack.packb(_to_serializable(tree), use_bin_type=True)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)  # atomic


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        obj = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    return _from_serializable(obj)
