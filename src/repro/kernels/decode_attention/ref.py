"""Oracle for single-token GQA decode attention over a (ring) KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, cache_pos, q_pos,
                         window: int = 0):
    """q: [B,H,D]; k_cache/v_cache: [B,S,Hkv,D]; cache_pos: [B,S] (-1 empty);
    q_pos: [B]. Returns [B,H,D]."""
    b, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) / jnp.sqrt(d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    valid = (cache_pos >= 0) & (cache_pos <= q_pos[:, None])
    if window:
        valid = valid & (cache_pos > q_pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)
