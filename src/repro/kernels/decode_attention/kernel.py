"""Pallas TPU kernel: single-token GQA decode attention over a long KV
(ring) cache — the memory-bound hot loop of `decode_32k` / `long_500k`.

Tiling: grid = (B, H, S/bs); the KV cache is streamed through VMEM in
(bs × D) blocks while the online-softmax running statistics (m, l) and the
accumulator stay resident in revisited output blocks for the (b,h) pair.
HBM traffic = one read of the cache (the floor); GQA means each KV block is
re-read once per query head in its group — the group-batched variant
(q-heads of one KV group share a block fetch) is the §Perf follow-up."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, qpos_ref, o_ref, m_ref, l_ref,
            *, ns, window, scale):
    s_i = pl.program_id(2)

    @pl.when(s_i == 0)
    def _init():
        o_ref[0, 0] = jnp.zeros_like(o_ref[0, 0])
        m_ref[0, 0] = jnp.full_like(m_ref[0, 0], NEG_INF)
        l_ref[0, 0] = jnp.zeros_like(l_ref[0, 0])

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [D]
    k = k_ref[0, :, 0].astype(jnp.float32)               # [bs, D]
    v = v_ref[0, :, 0].astype(jnp.float32)               # [bs, D]
    pos = pos_ref[0]                                     # [bs]
    qpos = qpos_ref[0]                                   # scalar

    s = jnp.sum(k * q[None, :], axis=-1)                 # [bs]
    valid = (pos >= 0) & (pos <= qpos)
    if window:
        valid = valid & (pos > qpos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0, 0][0]
    l_prev = l_ref[0, 0][0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # [bs]
    l_new = l_prev * alpha + jnp.sum(p)
    acc = o_ref[0, 0] * alpha + jnp.sum(p[:, None] * v, axis=0)

    m_ref[0, 0] = jnp.full_like(m_ref[0, 0], m_new)
    l_ref[0, 0] = jnp.full_like(l_ref[0, 0], l_new)

    @pl.when(s_i == ns - 1)
    def _final():
        o_ref[0, 0] = acc / jnp.maximum(l_new, 1e-30)

    @pl.when(s_i < ns - 1)
    def _store():
        o_ref[0, 0] = acc


@functools.partial(jax.jit, static_argnames=("bs", "window", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_pos, q_pos, *,
                     window: int = 0, bs: int = 128,
                     interpret: bool = False):
    """q: [B,H,D]; k_cache/v_cache: [B,S,Hkv,D]; cache_pos: [B,S];
    q_pos: [B] -> out [B,H,D]."""
    b, h, d = q.shape
    s_len, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    bs = min(bs, s_len)
    assert s_len % bs == 0, (s_len, bs)
    ns = s_len // bs
    scale = 1.0 / (d ** 0.5)

    kv_spec = pl.BlockSpec((1, bs, 1, d),
                           lambda ib, ih, is_: (ib, is_, ih // g, 0))
    out, m, l = pl.pallas_call(
        functools.partial(_kernel, ns=ns, window=window, scale=scale),
        grid=(b, h, ns),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda ib, ih, is_: (ib, ih, 0)),
            kv_spec, kv_spec,
            pl.BlockSpec((1, bs), lambda ib, ih, is_: (ib, is_)),
            pl.BlockSpec((1,), lambda ib, ih, is_: (ib,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda ib, ih, is_: (ib, ih, 0)),
            pl.BlockSpec((1, 1, 8), lambda ib, ih, is_: (ib, ih, 0)),
            pl.BlockSpec((1, 1, 8), lambda ib, ih, is_: (ib, ih, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 8), jnp.float32),
            jax.ShapeDtypeStruct((b, h, 8), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, cache_pos, q_pos)
    del m, l
    return out.astype(q.dtype)
