from .ops import *  # noqa
