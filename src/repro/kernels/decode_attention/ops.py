"""Backend dispatch for decode_attention."""

from __future__ import annotations

import jax

from .kernel import decode_attention as decode_attention_pallas
from .ref import decode_attention_ref

__all__ = ["decode_attention", "decode_attention_pallas",
           "decode_attention_ref"]


def decode_attention(q, k_cache, v_cache, cache_pos, q_pos, *,
                     window: int = 0, force_pallas: bool = False, **kw):
    if jax.default_backend() == "tpu":
        return decode_attention_pallas(q, k_cache, v_cache, cache_pos, q_pos,
                                       window=window, **kw)
    if force_pallas:
        return decode_attention_pallas(q, k_cache, v_cache, cache_pos, q_pos,
                                       window=window, interpret=True, **kw)
    return decode_attention_ref(q, k_cache, v_cache, cache_pos, q_pos,
                                window=window)
