"""Pallas TPU kernels for the compute hot spots, each with:
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py    — jit'd wrapper (backend dispatch: TPU=compiled, CPU=ref)
    ref.py    — pure-jnp oracle used by the models and the allclose tests

Kernels:
    moe_gmm          grouped expert matmul over the capacity dispatch layout
                     (the paper's MoE verification hot spot)
    flash_attention  blockwise causal / sliding-window attention (prefill)
    decode_attention single-step GQA attention over a long KV ring cache
    rwkv_scan        RWKV-6 decayed outer-product recurrence
    linear_scan      RG-LRU elementwise linear recurrence (RecurrentGemma)
"""
