"""Pallas TPU flash attention (prefill): blockwise online-softmax causal /
sliding-window GQA.

Tiling: grid = (B, H, S/bq, S/bk), kv innermost-sequential. Q blocks are
(bq × D) and KV blocks (bk × D) in VMEM — MXU-aligned for D ∈ {64,128,256}
and bq=bk=128 by default. Running (m, l) statistics and the accumulator for
each (b, h, iq) live in revisited output blocks. Fully-masked KV blocks
(beyond the causal frontier or outside the sliding window) are skipped with
`pl.when`, giving the ~2x causal saving and O(window) work in windowed
mode."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            bq, bk, nk, window, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        o_ref[0, :, 0, :] = jnp.zeros_like(o_ref[0, :, 0, :])
        m_ref[0, 0, :] = jnp.full_like(m_ref[0, 0, :], NEG_INF)
        l_ref[0, 0, :] = jnp.zeros_like(l_ref[0, 0, :])

    q_start = iq * bq
    k_start = ik * bk
    # block-level skip: entirely above the diagonal, or entirely out-of-window
    above_diag = k_start > q_start + bq - 1
    out_of_window = (window > 0) & (k_start + bk - 1 <= q_start - window)
    live = jnp.logical_not(above_diag | out_of_window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # [bq,D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [bk,D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k.T                                           # [bq,bk]
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kj <= qi
        if window:
            valid = valid & (kj > qi - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[0, 0, :]                               # [bq]
        l_prev = l_ref[0, 0, :]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # fully-masked rows (can happen in ragged window tails): keep zeros
        p = jnp.where(valid, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = o_ref[0, :, 0, :] * alpha[:, None] + p @ v
        m_ref[0, 0, :] = m_new
        l_ref[0, 0, :] = l_new
        o_ref[0, :, 0, :] = acc

    @pl.when(ik == nk - 1)
    def _final():
        l = l_ref[0, 0, :]
        o_ref[0, :, 0, :] = (o_ref[0, :, 0, :]
                             / jnp.maximum(l, 1e-30)[:, None])


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "window", "interpret"))
def flash_attention(q, k, v, *, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = False):
    """q: [B,S,H,D]; k,v: [B,S,Hkv,D] -> [B,S,H,D] (causal)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)

    out, m, l = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, window=window,
                          scale=scale),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda ib, ih, iq, ik: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda ib, ih, iq, ik: (ib, ik, ih // g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    del m, l
    return out.astype(q.dtype)
