from .ops import *  # noqa
