"""Oracle for blockwise causal / sliding-window GQA prefill attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, window: int = 0):
    """q: [B,S,H,D]; k,v: [B,S,Hkv,D] -> [B,S,H,D]. Causal; optional
    sliding window."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, s, hkv, g, d) / jnp.sqrt(d)
    scores = jnp.einsum("bthgd,bshd->bhgts", qf, k.astype(jnp.float32))
    idx = jnp.arange(s)
    valid = idx[None, :] <= idx[:, None]
    if window:
        valid = valid & (idx[None, :] > idx[:, None] - window)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
