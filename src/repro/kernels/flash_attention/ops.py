"""Backend dispatch for flash_attention."""

from __future__ import annotations

import jax

from .kernel import flash_attention as flash_attention_pallas
from .ref import flash_attention_ref

__all__ = ["flash_attention", "flash_attention_pallas", "flash_attention_ref"]


def flash_attention(q, k, v, *, window: int = 0, force_pallas: bool = False,
                    **kw):
    if jax.default_backend() == "tpu":
        return flash_attention_pallas(q, k, v, window=window, **kw)
    if force_pallas:
        return flash_attention_pallas(q, k, v, window=window,
                                      interpret=True, **kw)
    return flash_attention_ref(q, k, v, window=window)
