"""Pallas TPU kernels: grouped expert matmul (MoE FFN) over the capacity
dispatch layout — the paper's verification hot spot (§2.4).

Two kernels live here:

`moe_gmm` — the single grouped matmul y[e] = x[e] @ w[e] over the dense
[E, C, d] dispatch buffer, where counts[e] says how many capacity slots
actually hold tokens.  During MoE *verification* most experts have zero
tokens (only the unique experts routed by the K+1 in-flight tokens are
live) — exactly the effect Cascade's cost model prices.  The kernel skips
the MXU work of dead tiles with `pl.when(count > row_block_start)`.

`moe_gmm_fused` — the union-packed swiglu/gelu FFN.  It consumes the
*packed* [U_pad, C, d] layout produced by `models.moe.apply_moe(packed=
True)` (only the bucketed union of activated experts is materialised) and
fuses gate/up/activation/down into one pass: for each (expert, row-block)
it runs all three matmuls per F-tile and accumulates the down-projection
into the output block, so the intermediate [C, F] activation never touches
HBM.  Expert liveness arrives as a *scalar-prefetched* counts vector
(`pltpu.PrefetchScalarGridSpec`): the weight-block index_maps read it and
redirect dead experts' fetches to block 0, so a dead expert's HBM weight
traffic is never issued — the TPU analogue of the GPU
only-fetch-active-experts behaviour the paper's analysis rests on.  The
same spec works under `interpret=True`, keeping the kernel CPU-portable.

Both kernels pad non-divisible C/F/d internally (zero rows/columns are
exact no-ops through matmul and through silu/gelu, which fix 0) so
arbitrary capacity and model dims never crash the Pallas path.

Tiling: `moe_gmm` uses grid (E, C/bc, F/bf, d/bd) with d innermost for
accumulation; `moe_gmm_fused` uses grid (U, C/bc, F/bf) with F innermost
(the activation is elementwise in F, so each F-tile's contribution to the
[bc, d] output block is complete) and keeps d whole per block so the three
matmuls fuse without a d-reduction loop.  All tiles are MXU-aligned with
the 128x128 defaults."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad_to(a, axis: int, mult: int):
    """Zero-pad `a` along `axis` up to the next multiple of `mult`."""
    n = a.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


# --------------------------------------------------------------------- #
# moe_gmm: grouped matmul over the dense [E, C, d] dispatch buffer
# --------------------------------------------------------------------- #

def _kernel(counts_ref, x_ref, w_ref, o_ref, *, bc):
    ic = pl.program_id(1)
    id_ = pl.program_id(3)

    @pl.when(id_ == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    count = counts_ref[0]
    live = count > ic * bc  # any live token rows in this block?

    @pl.when(live)
    def _compute():
        x = x_ref[0].astype(jnp.float32)      # [bc, bd]
        w = w_ref[0].astype(jnp.float32)      # [bd, bf]
        o_ref[0] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm(x, w, counts, *, bc: int = 128, bf: int = 128, bd: int = 128,
            interpret: bool = False):
    """x: [E,C,d]; w: [E,d,F]; counts: [E] i32 -> y [E,C,F]."""
    e, c, d = x.shape
    f = w.shape[2]
    bc = min(bc, c)
    bf = min(bf, f)
    bd = min(bd, d)
    # Non-divisible dims are zero-padded (padding rows/cols contribute
    # exact zeros through the matmul) and the result sliced back.
    xp = _pad_to(_pad_to(x, 1, bc), 2, bd)
    wp = _pad_to(_pad_to(w, 1, bd), 2, bf)
    cp, dp = xp.shape[1], xp.shape[2]
    fp = wp.shape[2]
    grid = (e, cp // bc, fp // bf, dp // bd)

    # On real TPU hardware the weight-block index_map below would be
    #   lambda ie, ic, if_, id_: (ie if counts[ie] else 0, id_, if_)
    # via PrefetchScalarGridSpec so dead experts' weights are never fetched
    # (moe_gmm_fused does exactly that); plain BlockSpec keeps this legacy
    # dense-layout kernel simple — its dead tiles still skip the MXU work.
    y = pl.pallas_call(
        functools.partial(_kernel, bc=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ie, ic, if_, id_: (ie,)),
            pl.BlockSpec((1, bc, bd), lambda ie, ic, if_, id_: (ie, ic, id_)),
            pl.BlockSpec((1, bd, bf), lambda ie, ic, if_, id_: (ie, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ie, ic, if_, id_: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), jnp.float32),
        interpret=interpret,
    )(counts, xp, wp)
    return y[:, :c, :f].astype(x.dtype)


# --------------------------------------------------------------------- #
# moe_gmm_fused: packed-union swiglu/gelu FFN in one pass
# --------------------------------------------------------------------- #

def _fused_kernel(counts_ref, *refs, bc, activation):
    if activation == "swiglu":
        x_ref, wg_ref, wu_ref, wd_ref, o_ref = refs
    else:
        x_ref, wu_ref, wd_ref, o_ref = refs
        wg_ref = None
    iu = pl.program_id(0)
    ic = pl.program_id(1)
    if_ = pl.program_id(2)

    @pl.when(if_ == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    live = counts_ref[iu] > ic * bc

    @pl.when(live)
    def _compute():
        x = x_ref[0].astype(jnp.float32)                     # [bc, d]
        up = jnp.dot(x, wu_ref[0].astype(jnp.float32),
                     preferred_element_type=jnp.float32)     # [bc, bf]
        if activation == "swiglu":
            gate = jnp.dot(x, wg_ref[0].astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            h = jax.nn.silu(gate) * up
        else:
            h = jax.nn.gelu(up)
        o_ref[0] += jnp.dot(h, wd_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("activation", "bc", "bf", "interpret"))
def moe_gmm_fused(x, wg, wu, wd, counts, *, activation: str = "swiglu",
                  bc: int = 128, bf: int = 128, interpret: bool = False):
    """Fused packed-union FFN.

    x:  [U, C, d]  packed dispatch buffer (slot u holds the tokens routed
                   to the u-th activated expert; dead slots hold zeros)
    wg: [U, d, F]  gathered gate weights (ignored / may be None for gelu)
    wu: [U, d, F]  gathered up weights
    wd: [U, F, d]  gathered down weights
    counts: [U] i32 live tokens per packed slot -> y [U, C, d].

    counts is scalar-prefetched: dead slots' weight (and token) block
    fetches are steered to block 0 so their HBM traffic is never issued,
    and their MXU work is skipped outright.
    """
    if activation not in ("swiglu", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    u, c, d = x.shape
    f = wu.shape[2]
    bc = min(bc, c)
    bf = min(bf, f)
    # Zero-pad non-divisible C/F: silu/gelu fix 0 and padded wd rows are
    # zero, so padding contributes exact zeros twice over.
    xp = _pad_to(x, 1, bc)
    wup = _pad_to(wu, 2, bf)
    wdp = _pad_to(wd, 1, bf)
    cp, fp = xp.shape[1], wup.shape[2]
    grid = (u, cp // bc, fp // bf)

    def _steer(iu, cnt):
        # Dead packed slots (counts == 0) re-fetch slot 0's blocks instead
        # of issuing their own HBM reads.
        return jnp.where(cnt[iu] > 0, iu, 0)

    x_spec = pl.BlockSpec((1, bc, d), lambda iu, ic, if_, cnt:
                          (_steer(iu, cnt), ic, 0))
    wu_spec = pl.BlockSpec((1, d, bf), lambda iu, ic, if_, cnt:
                           (_steer(iu, cnt), 0, if_))
    wd_spec = pl.BlockSpec((1, bf, d), lambda iu, ic, if_, cnt:
                           (_steer(iu, cnt), if_, 0))
    in_specs = [x_spec, wu_spec, wd_spec]
    operands = [xp, wup, wdp]
    if activation == "swiglu":
        wgp = _pad_to(wg, 2, bf)
        in_specs.insert(1, pl.BlockSpec((1, d, bf), lambda iu, ic, if_, cnt:
                                        (_steer(iu, cnt), 0, if_)))
        operands.insert(1, wgp)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, d), lambda iu, ic, if_, cnt:
                               (iu, ic, 0)),
    )
    y = pl.pallas_call(
        functools.partial(_fused_kernel, bc=bc, activation=activation),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((u, cp, d), jnp.float32),
        interpret=interpret,
    )(counts, *operands)
    return y[:, :c].astype(x.dtype)


# --------------------------------------------------------------------- #
# moe_gmm_fused_quant: int8 weights, dequant fused into the tiles
# --------------------------------------------------------------------- #

def _fused_quant_kernel(counts_ref, *refs, bc, activation):
    """`_fused_kernel` with int8 weight tiles dequantized in-register:
    each expert's per-matrix absmax scale rides the scalar-prefetch path
    next to the counts vector (SMEM), so the dequant `w.astype(f32) *
    scale` costs no extra HBM traffic — the weights stream at 1
    byte/param, accumulation stays f32. Dead slots skip compute exactly
    as the bf16 kernel does (their steered weight fetch is garbage from
    slot 0, but `pl.when(live)` never consumes it, preserving the
    exact-zero dead-slot outputs)."""
    if activation == "swiglu":
        (sg_ref, su_ref, sd_ref,
         x_ref, wg_ref, wu_ref, wd_ref, o_ref) = refs
    else:
        su_ref, sd_ref, x_ref, wu_ref, wd_ref, o_ref = refs
        sg_ref = wg_ref = None
    iu = pl.program_id(0)
    ic = pl.program_id(1)
    if_ = pl.program_id(2)

    @pl.when(if_ == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    live = counts_ref[iu] > ic * bc

    @pl.when(live)
    def _compute():
        x = x_ref[0].astype(jnp.float32)                      # [bc, d]
        wu = wu_ref[0].astype(jnp.float32) * su_ref[iu]       # dequant
        up = jnp.dot(x, wu, preferred_element_type=jnp.float32)
        if activation == "swiglu":
            wg = wg_ref[0].astype(jnp.float32) * sg_ref[iu]
            gate = jnp.dot(x, wg, preferred_element_type=jnp.float32)
            h = jax.nn.silu(gate) * up
        else:
            h = jax.nn.gelu(up)
        wd = wd_ref[0].astype(jnp.float32) * sd_ref[iu]
        o_ref[0] += jnp.dot(h, wd, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("activation", "bc", "bf", "interpret"))
def moe_gmm_fused_quant(x, wg, wu, wd, s_gate, s_up, s_down, counts, *,
                        activation: str = "swiglu", bc: int = 128,
                        bf: int = 128, interpret: bool = False):
    """`moe_gmm_fused` over int8 expert weights with per-expert absmax
    scales (kernels/moe_gmm/quant.py), dequant fused into the tiles.

    x:  [U, C, d]   packed dispatch buffer (activations stay bf16/f32)
    wg/wu/wd:       int8 gathered weights, same layouts as the bf16 kernel
    s_gate/s_up/s_down: [U] f32 per-expert scales (s_gate ignored for gelu)
    counts: [U] i32 live tokens per packed slot -> y [U, C, d].

    The scales ride the scalar-prefetch path alongside counts
    (`num_scalar_prefetch=4`, 3 for gelu): they live in SMEM, sized [U],
    and every weight-block index_map simply ignores the extra refs — the
    dead-slot steering is byte-for-byte the bf16 kernel's."""
    if activation not in ("swiglu", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    u, c, d = x.shape
    f = wu.shape[2]
    bc = min(bc, c)
    bf = min(bf, f)
    xp = _pad_to(x, 1, bc)
    wup = _pad_to(wu, 2, bf)
    wdp = _pad_to(wd, 1, bf)
    cp, fp = xp.shape[1], wup.shape[2]
    grid = (u, cp // bc, fp // bf)

    def _steer(iu, cnt):
        return jnp.where(cnt[iu] > 0, iu, 0)

    # every index_map takes (grid idxs, counts, *scale refs) — the scales
    # are only read inside the kernel body, never steer a fetch
    x_spec = pl.BlockSpec((1, bc, d), lambda iu, ic, if_, cnt, *s:
                          (_steer(iu, cnt), ic, 0))
    wu_spec = pl.BlockSpec((1, d, bf), lambda iu, ic, if_, cnt, *s:
                           (_steer(iu, cnt), 0, if_))
    wd_spec = pl.BlockSpec((1, bf, d), lambda iu, ic, if_, cnt, *s:
                           (_steer(iu, cnt), if_, 0))
    in_specs = [x_spec, wu_spec, wd_spec]
    operands = [xp, wup, wdp]
    scalars = [counts, jnp.asarray(s_up, jnp.float32),
               jnp.asarray(s_down, jnp.float32)]
    if activation == "swiglu":
        wgp = _pad_to(wg, 2, bf)
        in_specs.insert(1, pl.BlockSpec((1, d, bf),
                                        lambda iu, ic, if_, cnt, *s:
                                        (_steer(iu, cnt), 0, if_)))
        operands.insert(1, wgp)
        scalars.insert(1, jnp.asarray(s_gate, jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, d), lambda iu, ic, if_, cnt, *s:
                               (iu, ic, 0)),
    )
    y = pl.pallas_call(
        functools.partial(_fused_quant_kernel, bc=bc,
                          activation=activation),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((u, cp, d), jnp.float32),
        interpret=interpret,
    )(*scalars, *operands)
    return y[:, :c].astype(x.dtype)
