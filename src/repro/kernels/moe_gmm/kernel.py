"""Pallas TPU kernel: grouped expert matmul (MoE FFN) over the capacity
dispatch layout — the paper's verification hot spot (§2.4).

y[e] = x[e] @ w[e] for each expert e, where x is the [E, C, d] dispatched
token buffer and counts[e] says how many capacity slots actually hold
tokens. During MoE *verification* most experts have zero tokens (only the
unique experts routed by the K+1 in-flight tokens are live) — exactly the
effect Cascade's cost model prices. The kernel skips the MXU work of dead
tiles with `pl.when(count > row_block_start)`; on a real TPU the BlockSpec
index_map additionally redirects dead weight-block fetches to block 0 so
the HBM traffic (not just the FLOPs) scales with *unique activated
experts* — this is the TPU analogue of the GPU only-fetch-active-experts
behaviour the paper's analysis rests on.

Tiling: grid = (E, C/bc, F/bf, d/bd), d innermost for accumulation; all
three tiles ((bc,bd) x, (bd,bf) w, (bc,bf) out) are MXU-aligned with the
128x128 defaults."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(counts_ref, x_ref, w_ref, o_ref, *, bc, nd):
    ic = pl.program_id(1)
    id_ = pl.program_id(3)

    @pl.when(id_ == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    count = counts_ref[0]
    live = count > ic * bc  # any live token rows in this block?

    @pl.when(live)
    def _compute():
        x = x_ref[0].astype(jnp.float32)      # [bc, bd]
        w = w_ref[0].astype(jnp.float32)      # [bd, bf]
        o_ref[0] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm(x, w, counts, *, bc: int = 128, bf: int = 128, bd: int = 128,
            interpret: bool = False):
    """x: [E,C,d]; w: [E,d,F]; counts: [E] i32 -> y [E,C,F]."""
    e, c, d = x.shape
    f = w.shape[2]
    bc = min(bc, c)
    bf = min(bf, f)
    bd = min(bd, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (c, f, d, bc, bf, bd)
    grid = (e, c // bc, f // bf, d // bd)

    # On real TPU hardware the weight-block index_map below would be
    #   lambda ie, ic, if_, id_: (ie if counts[ie] else 0, id_, if_)
    # via PrefetchScalarGridSpec so dead experts' weights are never fetched;
    # plain BlockSpec keeps the kernel interpret-mode portable here.
    y = pl.pallas_call(
        functools.partial(_kernel, bc=bc, nd=d // bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ie, ic, if_, id_: (ie,)),
            pl.BlockSpec((1, bc, bd), lambda ie, ic, if_, id_: (ie, ic, id_)),
            pl.BlockSpec((1, bd, bf), lambda ie, ic, if_, id_: (ie, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ie, ic, if_, id_: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), jnp.float32),
        interpret=interpret,
    )(counts, x, w)
    return y.astype(x.dtype)
