"""Oracle for the grouped expert matmul over the capacity dispatch layout."""

from __future__ import annotations

import jax.numpy as jnp


def moe_gmm_ref(x, w, counts=None):
    """x: [E,C,d]; w: [E,d,F]; counts: [E] valid tokens per expert (slots
    beyond the count hold zeros by construction). Returns [E,C,F]."""
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if counts is not None:
        c = x.shape[1]
        mask = jnp.arange(c)[None, :] < counts[:, None]
        y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)
