"""Oracles for the grouped expert matmul and the fused packed-union FFN
over the capacity dispatch layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gmm_ref(x, w, counts=None):
    """x: [E,C,d]; w: [E,d,F]; counts: [E] valid tokens per expert (slots
    beyond the count hold zeros by construction). Returns [E,C,F]."""
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if counts is not None:
        c = x.shape[1]
        mask = jnp.arange(c)[None, :] < counts[:, None]
        y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)


def moe_gmm_fused_ref(x, wg, wu, wd, counts=None, *,
                      activation: str = "swiglu"):
    """Oracle for `moe_gmm_fused`: the packed-union swiglu/gelu FFN.

    x: [U,C,d]; wg/wu: [U,d,F]; wd: [U,F,d]; counts: [U] valid tokens per
    packed slot. Returns [U,C,d]."""
    if activation not in ("swiglu", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    xf = x.astype(jnp.float32)
    up = jnp.einsum("ucd,udf->ucf", xf, wu.astype(jnp.float32))
    if activation == "swiglu":
        gate = jnp.einsum("ucd,udf->ucf", xf, wg.astype(jnp.float32))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("ucf,ufd->ucd", h, wd.astype(jnp.float32))
    if counts is not None:
        c = x.shape[1]
        mask = jnp.arange(c)[None, :] < counts[:, None]
        y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)


def moe_gmm_fused_quant_ref(x, wg, wu, wd, s_gate, s_up, s_down,
                            counts=None, *, activation: str = "swiglu"):
    """Oracle for `moe_gmm_fused_quant`: dequantize the int8 gathered
    weights with their per-expert scales (`quant.dequantize_int8` layout —
    w_f32 = q8 * scale[u]) and run the bf16 oracle. The kernel fuses this
    dequant into its tiles; numerically both compute x @ (q * s) in f32.

    wg/wu/wd: int8 [U,d,F]/[U,d,F]/[U,F,d]; s_*: f32 [U]."""
    from .quant import dequantize_int8
    wu_f = dequantize_int8(wu, jnp.asarray(s_up, jnp.float32))
    wd_f = dequantize_int8(wd, jnp.asarray(s_down, jnp.float32))
    wg_f = (dequantize_int8(wg, jnp.asarray(s_gate, jnp.float32))
            if activation == "swiglu" else wg)
    return moe_gmm_fused_ref(x, wg_f, wu_f, wd_f, counts,
                             activation=activation)
