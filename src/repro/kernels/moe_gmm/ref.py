"""Oracles for the grouped expert matmul and the fused packed-union FFN
over the capacity dispatch layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gmm_ref(x, w, counts=None):
    """x: [E,C,d]; w: [E,d,F]; counts: [E] valid tokens per expert (slots
    beyond the count hold zeros by construction). Returns [E,C,F]."""
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if counts is not None:
        c = x.shape[1]
        mask = jnp.arange(c)[None, :] < counts[:, None]
        y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)


def moe_gmm_fused_ref(x, wg, wu, wd, counts=None, *,
                      activation: str = "swiglu"):
    """Oracle for `moe_gmm_fused`: the packed-union swiglu/gelu FFN.

    x: [U,C,d]; wg/wu: [U,d,F]; wd: [U,F,d]; counts: [U] valid tokens per
    packed slot. Returns [U,C,d]."""
    if activation not in ("swiglu", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    xf = x.astype(jnp.float32)
    up = jnp.einsum("ucd,udf->ucf", xf, wu.astype(jnp.float32))
    if activation == "swiglu":
        gate = jnp.einsum("ucd,udf->ucf", xf, wg.astype(jnp.float32))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("ucf,ufd->ucd", h, wd.astype(jnp.float32))
    if counts is not None:
        c = x.shape[1]
        mask = jnp.arange(c)[None, :] < counts[:, None]
        y = jnp.where(mask[..., None], y, 0.0)
    return y.astype(x.dtype)
