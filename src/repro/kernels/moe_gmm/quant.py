"""Expert-weight quantization: absmax scale calibration + packing.

The quantized expert path (docs/quantization.md) stores each routed
expert's gate/up/down matrices as int8 with one f32 absmax scale per
expert per matrix — the weights stream from HBM at 1 byte/param while
`moe_gmm_fused_quant` dequantizes inside the tile (`w.astype(f32) *
scale`) and accumulates in f32. fp8(e4m3) is *simulated* on CPU: weights
round-trip through `float8_e4m3fn` at calibration time (fake-quant) and
run the standard bf16 kernel — same 1 byte/param pricing in the cost
model, different numerics, no second kernel.

Scale fitting is per-expert absmax by default; `quantile < 1.0` clips the
scale to that quantile of |w| (outlier-robust — the error bound of the
kernel-numerics tests scales with the chosen quantile), and
`fit_expert_scales_from_batches` pools a handful of weight batches the
way an activation-calibration pass would.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["fit_expert_scales", "fit_expert_scales_from_batches",
           "quantize_int8", "dequantize_int8", "fake_quant_fp8",
           "quantize_moe_experts", "QUANT_SUFFIX", "SCALE_SUFFIX"]

#: params-dict key suffixes of the packed storage format `models/moe.py`
#: routes through: `w_up` -> `w_up_q8` (int8 [E, ...]) + `w_up_s` (f32 [E])
QUANT_SUFFIX = "_q8"
SCALE_SUFFIX = "_s"

_INT8_MAX = 127.0


def fit_expert_scales(w, quantile: float = 1.0):
    """Per-expert absmax scales for an [E, ...] weight stack: scale_e =
    quantile_q(|w_e|) / 127, floored away from zero so an all-zero expert
    still round-trips (its quantized weights are exact zeros either way).
    Returns f32 [E]."""
    if not 0.0 < quantile <= 1.0:
        raise ValueError(f"quantile {quantile} outside (0, 1]")
    absw = jnp.abs(w.astype(jnp.float32)).reshape(w.shape[0], -1)
    if quantile >= 1.0:
        amax = jnp.max(absw, axis=1)
    else:
        amax = jnp.quantile(absw, quantile, axis=1)
    return jnp.maximum(amax, 1e-12) / _INT8_MAX


def fit_expert_scales_from_batches(batches, quantile: float = 1.0):
    """Absmax scale fit pooled over a handful of [E, ...] weight batches
    (the calibration-pass idiom): the per-expert max of each batch's
    per-expert quantile. One batch degenerates to `fit_expert_scales`."""
    scales = None
    for w in batches:
        s = fit_expert_scales(w, quantile)
        scales = s if scales is None else jnp.maximum(scales, s)
    if scales is None:
        raise ValueError("no calibration batches")
    return scales


def quantize_int8(w, scales=None, quantile: float = 1.0):
    """Symmetric int8 quantization of an [E, ...] stack under per-expert
    scales (fit from `w` when not given). Returns (q8 int8, scales f32
    [E]); `dequantize_int8(q8, scales)` recovers w to within scale/2 per
    element (exactly, when w is already a scale-multiple grid)."""
    if scales is None:
        scales = fit_expert_scales(w, quantile)
    s = scales.reshape((-1,) + (1,) * (w.ndim - 1))
    q = jnp.round(w.astype(jnp.float32) / s)
    return jnp.clip(q, -_INT8_MAX, _INT8_MAX).astype(jnp.int8), scales


def dequantize_int8(q8, scales):
    """f32 dequantization — the oracle-side inverse the kernel fuses into
    its tiles (`ref.moe_gmm_fused_quant_ref` uses exactly this)."""
    s = scales.reshape((-1,) + (1,) * (q8.ndim - 1))
    return q8.astype(jnp.float32) * s


def fake_quant_fp8(w):
    """fp8(e4m3) simulated on CPU: round-trip through float8_e4m3fn and
    return in w's dtype. The bytes saving is priced by the cost model
    (`Precision.fp8_experts()`); compute runs the standard kernel."""
    return w.astype(jnp.float8_e4m3fn).astype(w.dtype)


def quantize_moe_experts(params, mode: str = "int8",
                         quantile: float = 1.0) -> dict:
    """Quantize a `models/moe.py` params dict's ROUTED expert tensors
    (w_gate/w_up/w_down), leaving router/shared weights untouched — the
    mixed-precision storage `apply_moe` detects and routes through.

    mode="int8": each `w_x` [E, ...] is replaced by `w_x_q8` (int8) +
    `w_x_s` (f32 [E]) and removed — experts exist only in quantized form,
    exactly the HBM situation the cost model prices at 1 byte/param.
    mode="fp8": weights are fake-quantized in place (same keys, same
    dtype) — the storage stays dense, only the numerics change."""
    out = dict(params)
    names = [k for k in ("w_gate", "w_up", "w_down") if k in params]
    if not names:
        raise ValueError("params hold no routed expert tensors "
                         "(w_gate/w_up/w_down)")
    if mode == "fp8":
        for k in names:
            out[k] = fake_quant_fp8(params[k])
        return out
    if mode != "int8":
        raise ValueError(f"unknown quantization mode {mode!r}")
    for k in names:
        q, s = quantize_int8(params[k], quantile=quantile)
        out[k + QUANT_SUFFIX] = q
        out[k + SCALE_SUFFIX] = s
        del out[k]
    return out
