from .ops import *  # noqa
