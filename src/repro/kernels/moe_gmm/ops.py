"""Backend dispatch for moe_gmm."""

from __future__ import annotations

import jax

from .kernel import moe_gmm as moe_gmm_pallas
from .ref import moe_gmm_ref

__all__ = ["moe_gmm", "moe_gmm_pallas", "moe_gmm_ref"]


def moe_gmm(x, w, counts, *, force_pallas: bool = False, **kw):
    if jax.default_backend() == "tpu":
        return moe_gmm_pallas(x, w, counts, **kw)
    if force_pallas:
        return moe_gmm_pallas(x, w, counts, interpret=True, **kw)
    return moe_gmm_ref(x, w, counts)
