"""Backend dispatch for the moe_gmm kernel family.

Three backends, selected explicitly via `backend=`:

  "pallas"    compiled Pallas kernel (TPU)
  "interpret" the same Pallas kernel under the interpreter (CPU-portable,
              exercises the real BlockSpec/grid machinery)
  "ref"       pure-jnp oracle

`backend=None` auto-selects: "pallas" on TPU, else "ref" ("interpret" if
`force_pallas=True`, kept for backward compatibility).  Tile-size kwargs
are honored on both Pallas backends and are accepted-but-tiling-free on
the ref path (the oracle has no tiles); unknown kwargs raise instead of
being silently swallowed."""

from __future__ import annotations

import jax

from .kernel import moe_gmm as moe_gmm_pallas
from .kernel import moe_gmm_fused as moe_gmm_fused_pallas
from .kernel import moe_gmm_fused_quant as moe_gmm_fused_quant_pallas
from .quant import (fake_quant_fp8, fit_expert_scales,
                    fit_expert_scales_from_batches, quantize_int8,
                    dequantize_int8, quantize_moe_experts)
from .ref import moe_gmm_fused_quant_ref, moe_gmm_fused_ref, moe_gmm_ref

__all__ = ["moe_gmm", "moe_gmm_pallas", "moe_gmm_ref",
           "moe_gmm_fused", "moe_gmm_fused_pallas", "moe_gmm_fused_ref",
           "moe_gmm_fused_quant", "moe_gmm_fused_quant_pallas",
           "moe_gmm_fused_quant_ref",
           "fit_expert_scales", "fit_expert_scales_from_batches",
           "quantize_int8", "dequantize_int8", "fake_quant_fp8",
           "quantize_moe_experts"]

_BACKENDS = ("pallas", "interpret", "ref")


def _resolve_backend(backend, force_pallas):
    if backend is None:
        if jax.default_backend() == "tpu":
            backend = "pallas"
        elif force_pallas:
            backend = "interpret"
        else:
            backend = "ref"
    if backend not in _BACKENDS:
        raise ValueError(f"unknown moe_gmm backend {backend!r}; "
                         f"expected one of {_BACKENDS}")
    return backend


def moe_gmm(x, w, counts, *, backend: str | None = None,
            force_pallas: bool = False,
            bc: int = 128, bf: int = 128, bd: int = 128):
    """Grouped expert matmul over the dense [E, C, d] dispatch buffer."""
    be = _resolve_backend(backend, force_pallas)
    if be == "ref":
        return moe_gmm_ref(x, w, counts)
    return moe_gmm_pallas(x, w, counts, bc=bc, bf=bf, bd=bd,
                          interpret=(be == "interpret"))


def moe_gmm_fused(x, wg, wu, wd, counts, *, activation: str = "swiglu",
                  backend: str | None = None, force_pallas: bool = False,
                  bc: int = 128, bf: int = 128):
    """Fused packed-union swiglu/gelu FFN over the [U_pad, C, d] layout."""
    be = _resolve_backend(backend, force_pallas)
    if be == "ref":
        return moe_gmm_fused_ref(x, wg, wu, wd, counts,
                                 activation=activation)
    return moe_gmm_fused_pallas(x, wg, wu, wd, counts,
                                activation=activation, bc=bc, bf=bf,
                                interpret=(be == "interpret"))


def moe_gmm_fused_quant(x, wg, wu, wd, s_gate, s_up, s_down, counts, *,
                        activation: str = "swiglu",
                        backend: str | None = None,
                        force_pallas: bool = False,
                        bc: int = 128, bf: int = 128):
    """Fused packed-union FFN over int8 gathered weights with per-expert
    absmax scales, dequant fused into the tiles (docs/quantization.md)."""
    be = _resolve_backend(backend, force_pallas)
    if be == "ref":
        return moe_gmm_fused_quant_ref(x, wg, wu, wd, s_gate, s_up,
                                       s_down, counts,
                                       activation=activation)
    return moe_gmm_fused_quant_pallas(x, wg, wu, wd, s_gate, s_up, s_down,
                                      counts, activation=activation,
                                      bc=bc, bf=bf,
                                      interpret=(be == "interpret"))
