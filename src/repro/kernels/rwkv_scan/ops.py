"""Backend dispatch for rwkv_scan."""

from __future__ import annotations

import jax

from .kernel import rwkv_scan as rwkv_scan_pallas
from .ref import rwkv_scan_ref

__all__ = ["rwkv_scan", "rwkv_scan_pallas", "rwkv_scan_ref"]


def rwkv_scan(r, k, v, w, u, s0, *, force_pallas: bool = False, **kw):
    if jax.default_backend() == "tpu":
        return rwkv_scan_pallas(r, k, v, w, u, s0, **kw)
    if force_pallas:
        return rwkv_scan_pallas(r, k, v, w, u, s0, interpret=True, **kw)
    return rwkv_scan_ref(r, k, v, w, u, s0)
