"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

Per (batch, head): state S ∈ R^{N×N} (N = head_size, 64):
    y_t = r_t^T (S + diag(u) k_t v_t^T)
    S  <- diag(w_t) S + k_t v_t^T

Tiling: grid = (B, H, T/bt) with T innermost-sequential; the N×N f32 state
lives in the `s_last` output block (revisited across T blocks for a fixed
(b,h), initialised from s0 at it==0) so it stays resident in VMEM for the
whole sweep — the kernel reads r/k/v/w once from HBM and writes y once,
which is the bandwidth floor. The inner bt-step loop is a fori_loop of
rank-1 updates: outer products and row-scales are VPU ops; on the MXU this
could be chunked into (bt × N) @ (N × N) dots, which is the documented
next optimization (DESIGN.md §Perf)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_ref, *, bt):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_ref[0, 0] = s0_ref[0, 0]

    u = u_ref[0]                       # [N]
    s = s_ref[0, 0]                    # [N,N] running state

    def step(i, carry):
        s, = carry
        r_t = r_ref[0, i, 0, :]        # [N]
        k_t = k_ref[0, i, 0, :]
        v_t = v_ref[0, i, 0, :]
        w_t = w_ref[0, i, 0, :]
        kv = k_t[:, None] * v_t[None, :]          # [N,N]
        y = jnp.sum((s + u[:, None] * kv) * r_t[:, None], axis=0)
        y_ref[0, i, 0, :] = y
        s = w_t[:, None] * s + kv
        return (s,)

    (s,) = jax.lax.fori_loop(0, bt, step, (s,))
    s_ref[0, 0] = s


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rwkv_scan(r, k, v, w, u, s0, *, bt: int = 32, interpret: bool = False):
    """r,k,v,w: [B,T,H,N] f32; u: [H,N]; s0: [B,H,N,N] f32.
    Returns (y [B,T,H,N], s_last [B,H,N,N])."""
    b, t, h, n = r.shape
    bt = min(bt, t)
    assert t % bt == 0, (t, bt)
    grid = (b, h, t // bt)

    seq_spec = pl.BlockSpec((1, bt, 1, n), lambda ib, ih, it: (ib, it, ih, 0))
    state_spec = pl.BlockSpec((1, 1, n, n), lambda ib, ih, it: (ib, ih, 0, 0))

    y, s_last = pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, n), lambda ib, ih, it: (ih, 0)),
                  state_spec],
        out_specs=[seq_spec, state_spec],
        out_shape=[jax.ShapeDtypeStruct((b, t, h, n), r.dtype),
                   jax.ShapeDtypeStruct((b, h, n, n), s0.dtype)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_last
