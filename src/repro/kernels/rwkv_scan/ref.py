"""Oracle for the RWKV-6 WKV recurrence (same math as models/rwkv.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv_scan_ref(r, k, v, w, u, s0):
    """r,k,v,w: [B,T,H,N] f32; u: [H,N]; s0: [B,H,N,N].
    Returns (y [B,T,H,N], s_last [B,H,N,N])."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                            # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,H,Nk,Nv]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_last
