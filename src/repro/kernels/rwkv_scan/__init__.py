from .ops import *  # noqa
