"""Backend dispatch for linear_scan: compiled Pallas on TPU, oracle on CPU
(interpret-mode Pallas is available for correctness tests via force)."""

from __future__ import annotations

import jax

from .kernel import linear_scan as linear_scan_pallas
from .ref import linear_scan_ref

__all__ = ["linear_scan", "linear_scan_pallas", "linear_scan_ref"]


def linear_scan(a, x, h0, *, force_pallas: bool = False, **kw):
    if jax.default_backend() == "tpu":
        return linear_scan_pallas(a, x, h0, **kw)
    if force_pallas:
        return linear_scan_pallas(a, x, h0, interpret=True, **kw)
    return linear_scan_ref(a, x, h0)
