"""Oracle for the RG-LRU linear recurrence: h_t = a_t * h_{t-1} + x_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a, x, h0):
    """a, x: [B,T,D] (f32); h0: [B,D]. Returns (y [B,T,D], h_last [B,D])."""
    def step(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last
