"""Pallas TPU kernel for the elementwise linear recurrence
    h_t = a_t * h_{t-1} + x_t                     (RG-LRU, Griffin)

Tiling: grid = (B, D/bd, T/bt); the T axis is the innermost (fastest)
sequential grid dimension so the running state for a given (batch, channel
block) can live in a VMEM scratch register file across T blocks. Within a
block the recurrence over bt steps is unrolled as a log-depth associative
combine — MXU-free, pure VPU work, with the HBM traffic being exactly one
read of a,x and one write of y (the roofline floor for this op)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assoc_scan_block(a, x):
    """In-block inclusive scan of h_t = a_t h_{t-1} + x_t over axis 0 via
    the associative combine ((a1,x1)∘(a2,x2) = (a1*a2, x1*a2 + x2))."""
    return jax.lax.associative_scan(
        lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]), (a, x), axis=0)


def _kernel(a_ref, x_ref, h0_ref, y_ref, hlast_ref, *, nt):
    it = pl.program_id(2)
    a = a_ref[0]          # [bt, bd]
    x = x_ref[0]

    @pl.when(it == 0)
    def _init():
        hlast_ref[0, :] = h0_ref[0, :]

    h_in = hlast_ref[0, :]
    a_cum, y = _assoc_scan_block(a, x)
    y = y + a_cum * h_in[None, :]
    y_ref[0] = y
    hlast_ref[0, :] = y[-1, :]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def linear_scan(a, x, h0, *, bt: int = 128, bd: int = 128,
                interpret: bool = False):
    """a, x: [B,T,D] f32; h0: [B,D] f32 -> (y [B,T,D], h_last [B,D])."""
    b, t, d = a.shape
    bt = min(bt, t)
    bd = min(bd, d)
    assert t % bt == 0 and d % bd == 0, (t, d, bt, bd)
    grid = (b, d // bd, t // bt)

    y, h_last = pl.pallas_call(
        functools.partial(_kernel, nt=t // bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((1, bt, bd), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((1, bd), lambda ib, id_, it: (ib, id_)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((1, bd), lambda ib, id_, it: (ib, id_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d), a.dtype),
            jax.ShapeDtypeStruct((b, d), a.dtype),
        ],
        interpret=interpret,
    )(a, x, h0)
    return y, h_last
