from .ops import *  # noqa
