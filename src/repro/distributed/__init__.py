from . import sharding
