"""Explicit expert-parallel MoE dispatch via shard_map + all_to_all
(§Perf / beyond-paper: the paper's vLLM setting is single-GPU; at pod scale
the GSPMD scatter-based dispatch all-gathers tokens — this module routes
them with one all-to-all each way, the Switch/GShard communication pattern,
expressed jax-natively).

Layout contract (matches distributed/sharding.py):
    tokens  x2d [T, d]        T sharded over 'data' (and 'pod' if present)
    experts                   E sharded over 'data'
    expert weights [E, d, F]  E over 'data', F over 'model'
    router [d, E]             replicated

Inside the per-device block:
    1. route locally (top-k over all E experts)
    2. pack a send buffer [n_data, E_local, C_src, d] (slot assignment via
       local cumsum; per-source-shard quota C_src bounds worst-case skew)
    3. all_to_all over 'data'  ->  [n_data, E_local, C_src, d] recv
    4. grouped expert FFN on the local experts (F sharded over 'model',
       contributions psum'd over 'model')
    5. all_to_all back + weighted combine

Collective volume per layer: 2 x T*k*cf*d bytes spread across the data
axis — versus the baseline's involuntary all-gathers of the full dispatch
buffer."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import moe as moe_mod


def _local_pack(cfg, x_loc, idx, n_data: int, c_src: int):
    """Build the send buffer on one device.

    x_loc: [T_loc, d]; idx: [T_loc, k] routed expert ids.
    Returns (send, (dst, e_within, slot_c, keep)) where
      send     [n_data, e_loc, c_src, d] — token inputs slotted by
               (destination shard, local expert, arrival rank), spill
               entries already dropped;
      dst      [T_loc*k] destination shard of each (token, choice);
      e_within [T_loc*k] expert index within its shard;
      slot_c   [T_loc*k] capacity-clamped slot (== c_src for spilled);
      keep     [T_loc*k] bool, False where the (token, choice) overflowed
               its per-source quota and was dropped from the send buffer.
    The combine path gathers with (dst, e_within, slot_c) and zeroes
    dropped choices via `keep` — routing weights are applied there, not
    here."""
    t_loc, d = x_loc.shape
    k = cfg.experts_per_token
    e_loc = cfg.num_experts // n_data

    flat_e = idx.reshape(-1)                        # [T_loc*k]
    dst = flat_e // e_loc
    e_within = flat_e % e_loc
    onehot = jax.nn.one_hot(flat_e, cfg.num_experts, dtype=jnp.int32)
    slot = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
    keep = slot < c_src
    slot_c = jnp.where(keep, slot, c_src)           # spill row

    x_rep = jnp.repeat(x_loc, k, axis=0)
    send = jnp.zeros((n_data, e_loc, c_src + 1, d), x_loc.dtype)
    send = send.at[dst, e_within, slot_c].set(x_rep)
    send = send[:, :, :c_src]
    return send, (dst, e_within, slot_c, keep)


def _expert_ffn(cfg, p, xs):
    """xs: [e_loc, C, d]; local expert weights (F already model-sharded)."""
    if "w_gate" in p and cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xs, p["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def make_expert_parallel_moe(cfg, mesh: Mesh, *, capacity_factor: float = 2.0):
    """Returns apply(p, x2d) with the same semantics as moe.apply_moe
    (minus token-drop differences at quota boundaries)."""
    from .sharding import data_axes
    data_ax = data_axes(mesh)   # 'data' or ('pod','data')
    model_ax = "model"
    sizes = dict(mesh.shape)
    n_data = (sizes[data_ax] if isinstance(data_ax, str)
              else sizes["pod"] * sizes["data"])
    assert cfg.num_experts % n_data == 0

    def per_device(p, x_loc):
        t_loc, d = x_loc.shape
        k, e = cfg.experts_per_token, cfg.num_experts
        c_src = max(int(t_loc * k * capacity_factor) // e + 1, 1)

        weights, idx, probs = moe_mod.route(cfg, p, x_loc)
        send, (dst, e_within, slot_c, keep) = _local_pack(
            cfg, x_loc, idx, n_data, c_src)

        # one all-to-all each way over the data axis
        recv = jax.lax.all_to_all(send, data_ax, split_axis=0,
                                  concat_axis=0, tiled=True)
        # recv: [n_src, e_loc, c_src, d] -> group per expert
        e_loc = e // n_data
        xs = jnp.moveaxis(recv, 0, 1).reshape(e_loc, n_data * c_src, d)

        out = _expert_ffn(cfg, p, xs)                   # [e_loc, C, d]
        out = jax.lax.psum(out, model_ax)               # w_down F-contraction

        back = jnp.moveaxis(out.reshape(e_loc, n_data, c_src, d), 1, 0)
        ret = jax.lax.all_to_all(back, data_ax, split_axis=0,
                                 concat_axis=0, tiled=True)
        # ret: [n_dst, e_loc, c_src, d] == layout of `send`
        pad = jnp.zeros((n_data, e_loc, 1, d), ret.dtype)
        ret = jnp.concatenate([ret, pad], axis=2)
        y_rep = ret[dst, e_within, slot_c]               # [T_loc*k, d]
        w_flat = (weights.reshape(-1) * keep).astype(y_rep.dtype)
        y = jnp.sum((y_rep * w_flat[:, None]).reshape(t_loc, k, d), axis=1)

        if cfg.num_shared_experts:
            from repro.models.layers import apply_mlp
            # shared-expert F dim is model-sharded: partial contributions
            y = y + jax.lax.psum(apply_mlp(cfg, p["shared"], x_loc),
                                 model_ax)

        aux = {
            "lb_loss": jax.lax.pmean(
                moe_mod.load_balance_loss(cfg, probs, idx), data_ax),
            # per-source-shard telemetry (concatenated over data by
            # out_specs); the *global* routing decision is emitted too so
            # batch-aware consumers (per-row attribution, per-expert-shard
            # unions) see the same [T, k] ids the dense path reports
            "unique_experts": moe_mod.unique_expert_count(cfg, idx)[None],
            "dropped": jnp.sum(~keep)[None],
            "expert_idx": idx,
        }
        return y, aux

    p_specs = {
        "router": P(None, None),
        "w_gate": P(data_ax, None, model_ax),
        "w_up": P(data_ax, None, model_ax),
        "w_down": P(data_ax, model_ax, None),
    }
    if cfg.num_shared_experts:
        p_specs["shared"] = {"w_gate": P(None, model_ax),
                             "w_up": P(None, model_ax),
                             "w_down": P(model_ax, None)}

    apply = shard_map(
        per_device, mesh=mesh,
        in_specs=(p_specs, P(data_ax, None)),
        out_specs=(P(data_ax, None),
                   {"lb_loss": P(), "unique_experts": P(data_ax),
                    "dropped": P(data_ax), "expert_idx": P(data_ax, None)}),
        check_rep=False)
    return apply
