"""Divisibility-aware logical-axis sharding rules.

The production mesh is ('data', 'model') = (16, 16) per pod, with an extra
leading 'pod' axis multi-pod. Rules are name+shape based over the params
pytree; a dimension is sharded on an axis only when divisible (whisper's 20
heads, kv_heads ∈ {1,2,4,8}, odd vocabs all fall back to replication of
that dim rather than failing).

Layout summary (DESIGN.md §6):
  * expert weights [E, d, F]: E over 'data' x F over 'model' (2-D expert
    sharding — the only way Kimi-K2's 2 TB of experts fit 16 GB/chip)
  * dense/attention matrices: output-feature dim over 'model', wo/w_down
    transposed accordingly (Megatron-style tensor parallel)
  * embeddings: vocab over 'model'
  * batch dims of inputs over ('pod','data'); long_500k (batch=1) shards
    the KV-cache sequence over 'data' instead (context parallelism)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------- #
# Perf-iteration options (§Perf hillclimbing, EXPERIMENTS.md):
# globally-gated optimization paths so baseline and optimized variants of
# the SAME model code can be lowered and compared. Launchers set these from
# --opts; CPU tests leave them empty (no mesh context -> no constraints).
# --------------------------------------------------------------------- #

OPTIONS: set = set()
_CONTEXT_MESH = [None]


def set_options(names, mesh=None):
    OPTIONS.clear()
    OPTIONS.update(names or [])
    _CONTEXT_MESH[0] = mesh


def opt(name: str) -> bool:
    return name in OPTIONS


def constrain(x, *spec_entries):
    """with_sharding_constraint that is a no-op when no launcher mesh is
    registered (CPU tests), and drops axis names absent from the mesh
    (e.g. 'pod' on the single-pod mesh)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = _CONTEXT_MESH[0]
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def fix(e):
        if e is None or (isinstance(e, str) and e in names):
            return e
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a in names)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return None

    spec = PartitionSpec(*(fix(e) for e in spec_entries))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh


def data_axes(mesh: Mesh):
    """The (composite) batch-parallel axis: ('pod','data') when multi-pod."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    return tuple(names) if len(names) > 1 else names[0]


def axis_size(mesh: Mesh, axes) -> int:
    sizes = mesh_axis_sizes(mesh)
    if isinstance(axes, str):
        return sizes[axes]
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def _div(dim: int, mesh: Mesh, axes) -> Optional[Any]:
    """Return `axes` if dim divides evenly over them, else None."""
    if axes is None:
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


# --------------------------------------------------------------------- #
# Parameter rules
# --------------------------------------------------------------------- #

def _param_rule(path: Tuple[str, ...], shape: Tuple[int, ...],
                mesh: Mesh) -> P:
    """Decide a PartitionSpec for one parameter from its tree path + shape."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    stacked = "blocks" in path  # leading L dim from the layer-stack vmap
    dims = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()

    m = "model"
    dp = data_axes(mesh)

    def spec(*entries):
        return P(*(lead + tuple(entries)))

    if len(dims) == 0:
        return spec()

    # ---- Adafactor factored second-moment states mirror their parameter's
    # spec minus the reduced dim (path = param_path + ('row'|'col',)) ----
    if name == "row" and len(path) >= 2:
        parent_spec = _param_rule(path[:-1], shape + (128,), mesh)
        return P(*tuple(parent_spec)[:-1])
    if name == "col" and len(path) >= 2:
        parent_spec = _param_rule(path[:-1], shape[:-1] + (128, shape[-1]),
                                  mesh)
        ps = tuple(parent_spec)
        return P(*(ps[:-2] + ps[-1:]))

    # ---- embeddings ----
    if name == "embedding":                       # [V, d]
        return spec(_div(dims[0], mesh, m), None)
    if name == "unembed":                         # [d, V]
        return spec(None, _div(dims[1], mesh, m))

    # ---- MoE experts ----
    if parent == "moe" and name in ("w_gate", "w_up") and len(dims) == 3:
        return spec(_div(dims[0], mesh, dp), None, _div(dims[2], mesh, m))
    if parent == "moe" and name == "w_down" and len(dims) == 3:
        return spec(_div(dims[0], mesh, dp), _div(dims[1], mesh, m), None)
    if name == "router":                          # [d, E]
        return spec(None, None)

    # ---- MLA ----
    if name in ("w_qb", "w_uk", "w_uv") and len(dims) == 3:  # [r, H, hd]
        return spec(None, _div(dims[1], mesh, m), None)
    if name in ("w_qa", "w_kva", "w_kr"):
        return spec(None, None)

    # ---- attention / generic matrices ----
    if name in ("wq", "wk", "wv", "wg", "wr", "wk", "w_in", "w_gate",
                "w_up", "w_a", "w_x"):
        if len(dims) == 2:                        # [d_in, d_out]
            return spec(None, _div(dims[1], mesh, m))
    if name in ("wo", "w_down", "w_out", "wv") and len(dims) == 2:
        # output projections contract over the model-sharded dim
        if parent == "cmix" and name == "wv":     # rwkv cmix [F, d]
            return spec(_div(dims[0], mesh, m), None)
        if name == "wv":                          # attention value proj
            return spec(None, _div(dims[1], mesh, m))
        return spec(_div(dims[0], mesh, m), None)
    if name == "conv_w":                          # [cw, dr]
        return spec(None, _div(dims[1], mesh, m))

    # ---- everything small (norms, biases, mixes, loras, u) ----
    return spec(*(None,) * len(dims))


def param_shardings(cfg, params_shapes, mesh: Mesh):
    """params_shapes: pytree of ShapeDtypeStruct (jax.eval_shape output).
    Returns matching pytree of NamedSharding."""
    def fn(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", "")) for p in path)
        keys = tuple(str(k) for k in keys)
        spec = _param_rule(keys, tuple(leaf.shape), mesh)
        if len(spec) != len(leaf.shape):
            spec = P(*(tuple(spec) + (None,) * (len(leaf.shape) - len(spec))))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(fn, params_shapes)


# --------------------------------------------------------------------- #
# Input / cache rules
# --------------------------------------------------------------------- #

def batch_sharding(mesh: Mesh, batch: int, ndim: int) -> NamedSharding:
    dp = data_axes(mesh)
    lead = _div(batch, mesh, dp)
    return NamedSharding(mesh, P(lead, *(None,) * (ndim - 1)))


def cache_shardings(cfg, cache_shapes, mesh: Mesh, batch: int):
    """Shard the KV cache: batch over data axes when divisible, otherwise
    the ring/sequence dim over 'data' (context parallelism, long_500k)."""
    dp = data_axes(mesh)
    m = "model"
    batch_ok = batch % axis_size(mesh, dp) == 0

    def fn(path, leaf):
        keys = tuple(str(getattr(p, "key", "")) for p in path)
        name = keys[-1] if keys else ""
        shp = tuple(leaf.shape)
        if name in ("k", "v"):            # [L,B,R,Hkv,hd]
            b_ax = dp if batch_ok else None
            seq_ax = None if batch_ok else _div(shp[2], mesh, "data")
            h_ax = _div(shp[3], mesh, m)
            hd_ax = None if h_ax else _div(shp[4], mesh, m)
            if opt("cache-seq-shard") and h_ax is None and seq_ax is None:
                # §Perf: when kv-heads don't divide the model axis, shard
                # the cache sequence instead of head_dim — attention then
                # all-reduces small score/output partials instead of
                # all-gathering the whole cache every layer
                seq_ax, hd_ax = _div(shp[2], mesh, m), None
            return NamedSharding(mesh, P(None, b_ax, seq_ax, h_ax, hd_ax))
        if name in ("ckv", "krope"):      # [L,B,R,r]
            b_ax = dp if batch_ok else None
            seq_ax = None if batch_ok else _div(shp[2], mesh, "data")
            return NamedSharding(mesh, P(None, b_ax, seq_ax, None))
        if name in ("enc_k", "enc_v"):    # [L,B,S_enc,H,hd]
            b_ax = dp if batch_ok else None
            return NamedSharding(mesh, P(None, b_ax, None,
                                         _div(shp[3], mesh, m), None))
        if name == "pos":                 # [B,R]
            b_ax = dp if batch_ok else None
            seq_ax = None if batch_ok else _div(shp[1], mesh, "data")
            return NamedSharding(mesh, P(b_ax, seq_ax))
        if name == "wkv":                 # [L,B,H,N,N]
            b_ax = dp if batch_ok else None
            return NamedSharding(mesh, P(None, b_ax,
                                         _div(shp[2], mesh, m), None, None))
        if name in ("sx_att", "sx_ffn"):  # [L,B,d]
            b_ax = dp if batch_ok else None
            return NamedSharding(mesh, P(None, b_ax, _div(shp[2], mesh, m)))
        if name == "h":                   # [L,B,dr]
            b_ax = dp if batch_ok else None
            return NamedSharding(mesh, P(None, b_ax, _div(shp[2], mesh, m)))
        if name == "conv":                # [L,B,cw-1,dr]
            b_ax = dp if batch_ok else None
            return NamedSharding(mesh, P(None, b_ax, None,
                                         _div(shp[3], mesh, m)))
        # length scalar and anything else: replicated
        return NamedSharding(mesh, P(*(None,) * len(shp)))

    return jax.tree_util.tree_map_with_path(fn, cache_shapes)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, P(*(None,) * len(l.shape))), tree)
