"""Paper Figs. 6/7/15/16: iteration-level utility traces.

Dumps per-iteration (utility, K, phase) series for selected
(model, task, policy) combinations — the data behind the paper's trace
figures — and reports the trace-level worst-case slowdown windows that
motivate §7.1's SLO discussion."""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.controller import CascadeController, StaticKController
from repro.sim.simulator import SpeculationSimulator

from .common import emit, save_json


def _trace(sim, task, controller, iters):
    req = sim.run_request(task, iters, controller)
    return [{"k": i.k, "tokens": i.tokens, "t_iter": i.t_iter,
             "utility": i.utility, "phase": i.phase}
            for i in req.iterations]


def main(fast: bool = False):
    iters = 120 if fast else 400
    out = {}

    # Fig. 15: mixtral+math, static K=3 vs Cascade
    cfg = get_config("mixtral-8x7b")
    sim = SpeculationSimulator(cfg, seed=31)
    out["mixtral_math_static3"] = _trace(sim, "math", StaticKController(3),
                                         iters)
    sim = SpeculationSimulator(cfg, seed=31)
    out["mixtral_math_cascade"] = _trace(sim, "math", CascadeController(),
                                         iters)

    # Fig. 7-style: phi + extraction (phases of high/low utility)
    cfg_p = get_config("phi-3.5-moe")
    sim = SpeculationSimulator(cfg_p, seed=37)
    out["phi_extract_static3"] = _trace(sim, "extract", StaticKController(3),
                                        iters)

    # Fig. 16: all-3 mix on mixtral with Cascade
    sim = SpeculationSimulator(cfg, seed=41)
    reqs = sim.run_workload(["code", "math", "extract"], n_requests=3,
                            iters_per_request=iters,
                            controller_factory=lambda: CascadeController())
    out["mixtral_all3_cascade"] = [
        {"task": r.task,
         "utility": [i.utility for i in r.iterations[-8:]]} for r in reqs]

    for name in ("mixtral_math_static3", "mixtral_math_cascade"):
        u = np.array([row["utility"] for row in out[name][8:]])
        emit(f"traces/{name}", 0.0,
             f"min_u={u.min():.3f};mean_u={u.mean():.3f}")
    save_json("traces", out)
    return out


if __name__ == "__main__":
    main()
