"""Trace-driven open-loop load sweep (docs/serving_load.md).

Replays production-shaped traffic — Poisson and diurnal arrivals,
long-tail lengths, mixed tasks and SLO tiers — against the batched
engine on the model clock, in three regimes calibrated to a measured
saturation throughput:

  * light (~0.3x capacity) — the predictive TTFT admission constraint
    must never engage: zero sheds/defers, token streams bit-identical
    to the unconstrained scheduler;
  * overload (diurnal burst at ~3x capacity) — predictive admission
    must beat FIFO-admit-everything on p99 TTFT AND goodput-under-SLO,
    non-vacuously (shed count > 0);
  * starvation — a saturating latency-tier stream with throughput-tier
    probes behind it: the unguarded scheduler (max_queue_jumps=None)
    starves the probes for the whole trace (max queue delay grows with
    trace length), the default bounded-jump guard serves them within a
    bounded delay.

Committed artifact: experiments/bench/serving_load_sweep.json; the same
gates run as a CI `--fast` smoke step."""

from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.core import (CascadeController, Hardware,
                        PredictiveTTFTAdmission, RequestSLO)
from repro.data.workloads import make_sample
from repro.models import transformer as T
from repro.serving import (BatchedEngine, ContinuousBatchingScheduler,
                           LoadSpec, NGramDrafter, Request, run_load)
from repro.serving.load import poisson_arrivals

from .common import emit, save_json


def _gate(ok: bool, msg: str):
    if not ok:
        raise SystemExit(msg)


def _hw():
    # the planner-sweep crossover regime: memory and compute terms both
    # matter, so prefill passes have real cost and queues have real teeth
    return Hardware("tpu-v5e-flops-scaled", hbm_bw=1e9, peak_flops=6e9)


def _make_sched(cfg, params, hw, *, admission=None, max_queue_jumps=8,
                max_batch=8):
    eng = BatchedEngine(cfg, params, lambda: NGramDrafter(),
                        temperature=0.0, clock="model", seed=0, hw=hw,
                        max_len=512, max_batch=max_batch, chunk=32)
    return ContinuousBatchingScheduler(
        eng, controller_factory=lambda: CascadeController(),
        admission=admission, max_queue_jumps=max_queue_jumps)


def _starvation_trace(n_latency: int, rate: float, seed: int = 0,
                      n_probes: int = 3):
    """A saturating latency-tier Poisson stream with `n_probes`
    throughput-tier probes inserted after a FIXED number of latency
    arrivals (not a fixed fraction — the probes' queue position must not
    itself grow with trace length, or boundedness would be unmeasurable).
    Under the unguarded scheduler every later latency arrival jumps the
    probes, so their queue delay tracks the whole trace duration."""
    rng = np.random.default_rng(seed)
    ats = poisson_arrivals(rng, rate, n_latency)
    trace = []
    for i, at in enumerate(ats):
        s = make_sample("extract", rng, vocab=256, prompt_len=12,
                        cont_len=6)
        trace.append((at, Request(request_id=f"lat-{i}", prompt=s.prompt,
                                  max_new=6, task="extract",
                                  slo=RequestSLO.latency())))
    t0 = ats[min(6, n_latency - 1)]
    for j in range(n_probes):
        s = make_sample("code", rng, vocab=256, prompt_len=12, cont_len=6)
        trace.append((t0 + 1e-6 * (j + 1),
                      Request(request_id=f"thr-{j}", prompt=s.prompt,
                              max_new=6, task="code")))
    return trace


def _max_probe_delay(sched) -> float:
    delays = [r.telemetry.t_queue for r in sched.results
              if r.telemetry.request_id.startswith("thr-")]
    return max(delays) if delays else float("inf")


def serving_load_sweep(fast: bool = False):
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    hw = _hw()
    n_light = 40 if fast else 120
    n_over = 60 if fast else 200
    n_starve = 30 if fast else 60

    # -- 1. capacity calibration: a same-shaped burst (everything arrives
    # at once) measures the saturated service rate the open-loop rates
    # are placed against -------------------------------------------------
    burst = LoadSpec(n_requests=24 if fast else 48, rate=1e4, seed=7)
    sched = _make_sched(cfg, params, hw)
    rep = run_load(sched, burst)
    mu = rep["n_served"] / rep["makespan"]   # requests / model-second
    emit("serving_load/capacity_requests_per_s", mu, "burst-calibrated")

    # -- 2. TTFT bound calibration: an unbounded light-load probe gives
    # the observed TTFT ceiling; the bound sits 3x above it, so under
    # light load no request is ever predicted doomed, while overload
    # queue delays blow through it --------------------------------------
    light_probe = LoadSpec(n_requests=n_light, rate=0.3 * mu, seed=11,
                           latency_frac=0.5)
    sched = _make_sched(cfg, params, hw)
    rep = run_load(sched, light_probe)
    idle_svc = sched.engine.predicted_service_time(light_probe.prompt_hi)
    bound = 3.0 * max(rep["p99_ttft"], rep["max_queue_delay"] + idle_svc)
    emit("serving_load/ttft_bound", bound,
         f"3x max(p99_ttft={rep['p99_ttft']:.4f}, "
         f"delay+svc={rep['max_queue_delay'] + idle_svc:.4f})")

    def bounded(spec):
        return LoadSpec(**{**spec.__dict__, "latency_ttft": bound})

    # -- 3. light load: the predictive constraint must be invisible ------
    light = bounded(light_probe)
    base = _make_sched(cfg, params, hw)
    rep_base = run_load(base, light)
    pred = _make_sched(cfg, params, hw,
                       admission=PredictiveTTFTAdmission())
    rep_pred = run_load(pred, light)
    streams_equal = ([r.tokens for r in base.results]
                     == [r.tokens for r in pred.results])
    emit("serving_load/light_shed", rep_pred["n_shed"], "must-be-0")
    emit("serving_load/light_streams_identical", float(streams_equal),
         "must-be-1")
    rows_light = {"base": rep_base, "predictive": rep_pred}
    _gate(rep_pred["n_shed"] == 0 and rep_pred["n_deferred"] == 0,
          f"predictive admission engaged under light load "
          f"(shed={rep_pred['n_shed']}, deferred={rep_pred['n_deferred']})")
    _gate(streams_equal,
          "light-load token streams differ between the predictive and "
          "unconstrained schedulers (the constraint must be invisible "
          "when it never fires)")

    # -- 4. overload burst: predictive TTFT admission vs admit-everything
    over = bounded(LoadSpec(n_requests=n_over, rate=3.0 * mu,
                            arrival="diurnal", amplitude=0.8,
                            period=n_over / (3.0 * mu) / 2.0,
                            seed=13, latency_frac=0.5))
    fifo = _make_sched(cfg, params, hw)
    rep_fifo = run_load(fifo, over)
    pred = _make_sched(cfg, params, hw,
                       admission=PredictiveTTFTAdmission())
    rep_shed = run_load(pred, over)
    emit("serving_load/overload_fifo_p99_ttft", rep_fifo["p99_ttft"],
         f"goodput={rep_fifo['goodput_tokens_per_s']:.1f}")
    emit("serving_load/overload_pred_p99_ttft", rep_shed["p99_ttft"],
         f"goodput={rep_shed['goodput_tokens_per_s']:.1f};"
         f"shed={rep_shed['n_shed']}")
    rows_over = {"fifo": rep_fifo, "predictive": rep_shed}
    _gate(rep_shed["n_shed"] > 0,
          "overload run shed nothing — the predictive-admission gate "
          "would be vacuous")
    _gate(rep_shed["p99_ttft"] < rep_fifo["p99_ttft"],
          f"predictive admission did not improve p99 TTFT under overload "
          f"({rep_shed['p99_ttft']:.4f} vs fifo {rep_fifo['p99_ttft']:.4f})")
    _gate(rep_shed["goodput_tokens_per_s"]
          > rep_fifo["goodput_tokens_per_s"],
          f"predictive admission did not improve goodput under SLO "
          f"({rep_shed['goodput_tokens_per_s']:.2f} vs fifo "
          f"{rep_fifo['goodput_tokens_per_s']:.2f} tokens/s)")

    # -- 5. starvation guard: bounded vs unbounded queue-jumps -----------
    rate = 8.0 * mu
    delays = {}
    for label, guard, n in (("unguarded_1x", None, n_starve),
                            ("unguarded_2x", None, 2 * n_starve),
                            ("guarded_1x", 8, n_starve),
                            ("guarded_2x", 8, 2 * n_starve)):
        sched = _make_sched(cfg, params, hw, max_queue_jumps=guard)
        sched.run_trace(_starvation_trace(n, rate, seed=17))
        delays[label] = _max_probe_delay(sched)
        emit(f"serving_load/starvation_{label}_max_delay", delays[label],
             f"n_latency={n}")
    growth = (delays["unguarded_2x"] / delays["unguarded_1x"]
              if delays["unguarded_1x"] > 0 else float("inf"))
    _gate(growth > 1.3,
          f"unguarded max throughput-tier delay did not grow with trace "
          f"length (x{growth:.2f}) — the starvation gate would be vacuous")
    _gate(delays["guarded_2x"] < 0.5 * delays["unguarded_2x"],
          f"starvation guard did not bound the probes' delay "
          f"({delays['guarded_2x']:.4f} vs unguarded "
          f"{delays['unguarded_2x']:.4f})")
    _gate(delays["guarded_2x"] <= 1.2 * delays["guarded_1x"] + 1e-9,
          f"guarded delay still grew with trace length "
          f"({delays['guarded_1x']:.4f} -> {delays['guarded_2x']:.4f})")

    save_json("serving_load_sweep", {
        "hw": {"name": hw.name, "hbm_bw": hw.hbm_bw,
               "peak_flops": hw.peak_flops},
        "fast": fast,
        "capacity_requests_per_s": mu,
        "ttft_bound": bound,
        "light": rows_light,
        "overload": rows_over,
        "starvation": {"max_probe_delay": delays,
                       "unguarded_growth": growth},
    })
    return {"light": rows_light, "overload": rows_over,
            "starvation": delays}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    serving_load_sweep(fast=args.fast)
