"""Paper Fig. 18: incremental ablation of Cascade's three optimizations on
Mixtral — (none = static k_start) -> +dynamic disable -> +adaptive back-off
-> +hill-climbing. The paper reports each increment is additive."""

from __future__ import annotations

from repro.configs import get_config
from repro.core.manager import CascadeConfig
from repro.data.workloads import MIXES
from repro.sim.simulator import run_point

from .common import PAPER_TASKS, emit, save_json

VARIANTS = [
    ("static_k3", dict(enable_disable=False, enable_backoff=False,
                       enable_hillclimb=False)),
    ("+disable", dict(enable_disable=True, enable_backoff=False,
                      enable_hillclimb=False)),
    ("+backoff", dict(enable_disable=True, enable_backoff=True,
                      enable_hillclimb=False)),
    ("+hillclimb", dict(enable_disable=True, enable_backoff=True,
                        enable_hillclimb=True)),
]


def main(fast: bool = False):
    cfg = get_config("mixtral-8x7b")
    tasks = PAPER_TASKS[:3] if fast else PAPER_TASKS
    n_req, iters = (4, 120) if fast else (8, 300)
    rows = []
    for task in tasks:
        mix = list(MIXES[task])
        rec = {"task": task}
        for name, flags in VARIANTS:
            cc = CascadeConfig(**flags)
            r = run_point(cfg, mix, None, n_requests=n_req, iters=iters,
                          seed=17, cascade_cfg=cc)
            rec[name] = r["speedup"]
        rows.append(rec)
        emit(f"ablation/mixtral/{task}", 0.0,
             ";".join(f"{n}={rec[n]:.3f}" for n, _ in VARIANTS))
    save_json("ablation", rows)
    return rows


if __name__ == "__main__":
    main()
