"""Paper Fig. 8 + Theorem 4.2: measured utility vs measured TPOT speedup
across (model x task x static-K) datapoints. The paper reports R^2 = 99.4%;
this benchmark recomputes the fit on our datapoints."""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.sim.simulator import run_point

from .common import PAPER_MODELS, emit, save_json

TASKS = ["code", "math", "extract"]


def main(fast: bool = False):
    models = PAPER_MODELS[:2] if fast else PAPER_MODELS
    ks = [0, 1, 2, 3, 5, 7] if not fast else [0, 1, 3]
    n_req, iters = (3, 100) if fast else (6, 220)
    xs, ys, rows = [], [], []
    for model in models:
        cfg = get_config(model)
        for task in TASKS:
            for k in ks:
                r = run_point(cfg, [task], k, n_requests=n_req, iters=iters,
                              seed=11)
                # measured utility = ETR / cost = speedup (Thm 4.2); compute
                # utility from raw iteration records, independent of speedup
                reqs, base = r["requests"], r["baseline"]
                t_spec = sum(q.decode_time for q in reqs)
                it_spec = sum(len(q.iterations) for q in reqs)
                t_base = sum(q.decode_time for q in base)
                it_base = sum(len(q.iterations) for q in base)
                etr = sum(q.output_tokens for q in reqs) / it_spec
                cost = (t_spec / it_spec) / (t_base / it_base)
                u = etr / cost
                xs.append(u)
                ys.append(r["speedup"])
                rows.append({"model": model, "task": task, "k": k,
                             "utility": u, "speedup": r["speedup"]})
    xs, ys = np.asarray(xs), np.asarray(ys)
    # linear fit through the data; theorem predicts y = x
    ss_res = float(np.sum((ys - xs) ** 2))
    ss_tot = float(np.sum((ys - ys.mean()) ** 2))
    r2 = 1.0 - ss_res / ss_tot
    save_json("utility_fit", {"rows": rows, "r2_vs_identity": r2,
                              "n_points": len(rows)})
    emit("utility_fit/r2", 0.0, f"r2={r2:.4f};n={len(rows)};target=identity")
    return r2


if __name__ == "__main__":
    main()
