"""Paper Fig. 17 (§7.3): Cascade with an EAGLE-style learned drafter on
Mixtral. EAGLE drafts are more accurate but drafting costs grow ~5% per
unit K; the paper finds K=1 the best static setting and Cascade matching
the best static-K on every task.

Honesty note: this study is simulator-based end to end — `drafter="eagle"`
selects `sim.simulator`'s *statistical model* of an EAGLE drafter
(task-calibrated acceptance curves and a per-K draft-cost multiplier),
not a trained draft head; no EAGLE weights exist in this repo and the
real serving engine never runs here. The numbers reproduce the paper's
*relative* claim (Cascade vs static-K under EAGLE-shaped acceptance),
not EAGLE itself. Training a real learned drafter and folding its
measured acceptance back into these curves is the ROADMAP's
"learned-drafter acceptance curves" item."""

from __future__ import annotations

from repro.configs import get_config
from repro.data.workloads import MIXES
from repro.sim.simulator import run_point

from .common import PAPER_TASKS, emit, save_json


def main(fast: bool = False):
    cfg = get_config("mixtral-8x7b")
    tasks = PAPER_TASKS[:3] if fast else PAPER_TASKS
    n_req, iters = (4, 120) if fast else (8, 300)
    rows = []
    for task in tasks:
        mix = list(MIXES[task])
        rec = {"task": task}
        for pol in ["cascade", 1, 2, 3]:
            k = None if pol == "cascade" else pol
            r = run_point(cfg, mix, k, drafter="eagle", n_requests=n_req,
                          iters=iters, seed=23)
            rec[f"speedup_{pol}"] = r["speedup"]
        rows.append(rec)
        emit(f"eagle/mixtral/{task}", 0.0,
             ";".join(f"{p}={rec[f'speedup_{p}']:.3f}"
                      for p in ["cascade", 1, 2, 3]))
    save_json("eagle_study", rows)
    return rows


if __name__ == "__main__":
    main()
