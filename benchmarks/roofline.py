"""Roofline analysis (deliverable g): derive the three roofline terms from
the dry-run's compiled artifacts, per (arch x shape) on the single-pod mesh.

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

plus MODEL_FLOPS = 6·N·D (6·N_active·D for MoE), the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, the dominant term, and a what-would-move-it note.
For MoE *decode* shapes the XLA program necessarily touches every local
expert's weights (static shapes), so we additionally report the
effective memory term from the active-expert cost model — the paper's own
§2.4 analysis — as `memory_eff`.

Usage: python -m benchmarks.roofline [--dir experiments/dryrun]
       [--variant experiments/dryrun_opt]   (prints before/after deltas)
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.core import cost_model as cm
from repro.models.config import INPUT_SHAPES

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

SPEC_K = 3


def model_flops(cfg, shape) -> float:
    """6·N(_active)·D global."""
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d  # forward only
    d = shape.global_batch * (SPEC_K + 1)
    return 2.0 * n * d


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec["devices"]

    # trip-aware numbers (hlo_analysis.py): XLA's cost_analysis counts scan
    # (while) bodies once; these multiply by known_trip_count.
    ta = rec.get("trip_aware")
    if ta:
        flops = ta["flops_per_device"]
        bytes_ = ta["bytes_per_device"]
        coll_b = ta["collective_bytes_per_device"]
    else:  # legacy artifact
        flops = rec["flops_per_device"]
        bytes_ = rec["bytes_accessed_per_device"]
        coll_b = rec["collectives"]["total_bytes"]

    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll_b / ICI_BW

    mf = model_flops(cfg, shape)
    useful = mf / max(flops * chips, 1.0)

    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "model_flops": mf, "useful_ratio": useful,
        "temp_bytes_per_device": rec["memory"]["temp_bytes"],
        "arg_bytes_per_device": rec["memory"]["argument_bytes"],
    }

    # effective (active-experts) memory term for MoE decode
    if cfg.is_moe and shape.kind == "decode":
        b = cm.iteration_bytes(cfg, shape.global_batch * (SPEC_K + 1),
                               shape.seq_len, affinity=0.3,
                               window=rec.get("window", 0))
        out["memory_eff_s"] = b["total"] / (chips * HBM_BW)

    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    out["dominant"] = dom
    out["bound_s"] = terms[dom]
    out["note"] = {
        "compute": "reduce recompute (remat policy) / pick a lower-FLOP "
                   "dispatch; MoE capacity factor directly scales this term",
        "memory": "shard or shrink the dominant resident tensor (KV ring, "
                  "dispatch buffers); for MoE decode the active-expert "
                  "kernel path realizes memory_eff_s",
        "collective": "re-shard to turn all-gathers into reduce-scatters / "
                      "move the expert all-to-all onto the data axis",
    }[dom]
    return out


def main(fast: bool = False, dir_: str = "experiments/dryrun",
         variant: str = None):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*_16x16.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            print(f"roofline/{rec.get('arch')}/{rec.get('shape')},0,"
                  f"FAILED={rec.get('error', '')[:60]}")
            continue
        a = analyze(rec)
        rows.append(a)
        eff = (f";mem_eff={a['memory_eff_s']*1e6:.0f}us"
               if "memory_eff_s" in a else "")
        print(f"roofline/{a['arch']}/{a['shape']},{a['bound_s']*1e6:.1f},"
              f"dom={a['dominant']};comp={a['compute_s']*1e6:.0f}us;"
              f"mem={a['memory_s']*1e6:.0f}us;"
              f"coll={a['collective_s']*1e6:.0f}us;"
              f"useful={a['useful_ratio']:.2f}{eff}")

    if variant:
        base = {(r["arch"], r["shape"]): r for r in rows}
        for path in sorted(glob.glob(os.path.join(variant, "*_16x16.json"))):
            rec = json.load(open(path))
            if not rec.get("ok"):
                continue
            a = analyze(rec)
            b = base.get((a["arch"], a["shape"]))
            if b:
                print(f"roofline_delta/{a['arch']}/{a['shape']},"
                      f"{a['bound_s']*1e6:.1f},"
                      f"dom_before={b['bound_s']*1e6:.0f}us;"
                      f"dom_after={a['bound_s']*1e6:.0f}us;"
                      f"x{b['bound_s']/max(a['bound_s'],1e-12):.2f}")

    os.makedirs("experiments/bench", exist_ok=True)
    with open("experiments/bench/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()
    main(dir_=args.dir, variant=args.variant)
