"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows and persists JSON to
experiments/bench/.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Default is the fast profile (CPU-friendly); --full uses the paper-scale
request counts. The roofline module reads experiments/dryrun/ (run
repro.launch.dryrun first for deliverables e/g)."""

from __future__ import annotations

import argparse
import os
import sys
import time

MODULES = [
    "serving_micro",   # real-engine primitives (wall clock)
    "static_k",        # Fig. 4/5
    "utility_fit",     # Fig. 8 / Thm 4.2
    "cascade_main",    # Fig. 13 (headline)
    "ablation",        # Fig. 18
    "sensitivity",     # 7.5
    "eagle_study",     # Fig. 17
    "traces",          # Figs. 6/7/15/16
    "lookahead_study", # paper 8.1 quantified (beyond-paper)
    "roofline",        # deliverable g (needs dry-run artifacts)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = []
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            if name == "roofline" and not os.path.isdir("experiments/dryrun"):
                print(f"{name},0,SKIPPED=no-dryrun-artifacts")
                continue
            mod.main(fast=not args.full)
            print(f"{name}/_elapsed,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # pragma: no cover
            failures.append(name)
            print(f"{name},0,ERROR={type(e).__name__}:{e}")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
