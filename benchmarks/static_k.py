"""Paper Fig. 4 / Fig. 5: static-K n-gram speculation across the 5 MoEs and
7 workloads — shows per-(model,task) TPOT speedups/slowdowns and ETR."""

from __future__ import annotations

from repro.configs import get_config
from repro.data.workloads import MIXES
from repro.sim.simulator import run_point

from .common import PAPER_MODELS, PAPER_TASKS, Timer, emit, save_json


def main(fast: bool = False):
    models = PAPER_MODELS[:2] if fast else PAPER_MODELS
    tasks = PAPER_TASKS[:3] if fast else PAPER_TASKS
    n_req, iters = (4, 120) if fast else (8, 256)
    rows = []
    for model in models:
        cfg = get_config(model)
        for task in tasks:
            mix = list(MIXES[task])
            for k in (1, 2, 3):
                with Timer() as t:
                    r = run_point(cfg, mix, k, n_requests=n_req, iters=iters,
                                  seed=7)
                rows.append({"model": model, "task": task, "k": k,
                             "speedup": r["speedup"], "etr": r["etr"],
                             "tpot_s": r["tpot"]})
                emit(f"static_k/{model}/{task}/K{k}",
                     r["tpot"] * 1e6,
                     f"speedup={r['speedup']:.3f};etr={r['etr']:.2f}")
    worst = min(rows, key=lambda r: r["speedup"])
    best = max(rows, key=lambda r: r["speedup"])
    save_json("static_k", {"rows": rows, "worst": worst, "best": best})
    emit("static_k/worst", worst["tpot_s"] * 1e6,
         f"{worst['model']}/{worst['task']}/K{worst['k']}="
         f"{worst['speedup']:.3f}")
    return rows


if __name__ == "__main__":
    main()
