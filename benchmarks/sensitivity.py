"""Paper §7.5: hyperparameter sensitivity of the test-and-set policy —
trial length t in {2,4,8} (T=4t) and set length S in {8,16,32} on the
Mixtral task suite."""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.manager import CascadeConfig
from repro.data.workloads import MIXES
from repro.sim.simulator import run_point

from .common import PAPER_TASKS, emit, save_json


def main(fast: bool = False):
    cfg = get_config("mixtral-8x7b")
    tasks = PAPER_TASKS[:3] if fast else PAPER_TASKS
    n_req, iters = (3, 120) if fast else (6, 300)
    rows = []
    for t, s in [(2, 8), (4, 16), (8, 32)]:
        cc = CascadeConfig(trial_len=t, set_len=s)
        sp = []
        for task in tasks:
            r = run_point(cfg, list(MIXES[task]), None, n_requests=n_req,
                          iters=iters, seed=19, cascade_cfg=cc)
            sp.append(r["speedup"])
        mean = float(np.mean(sp))
        rows.append({"t": t, "S": s, "mean_speedup": mean,
                     "per_task": dict(zip(tasks, sp))})
        emit(f"sensitivity/t{t}_S{s}", 0.0, f"mean_speedup={mean:.3f}")
    save_json("sensitivity", rows)
    return rows


if __name__ == "__main__":
    main()
