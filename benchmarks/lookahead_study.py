"""Paper §8.1 quantified: Lookahead-style multi-branch drafting (G n-grams
of length K in flight simultaneously) and Medusa-style tree drafts multiply
the in-flight token count — and therefore the unique-expert activation —
without multiplying ETR. The paper argues this makes them infeasible for
MoEs; this benchmark measures it with the routing + cost model, and shows
Cascade correctly refuses to speculate under them."""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import cost_model as cm
from repro.core.controller import CascadeController, StaticKController
from repro.sim.tasks import TASK_PROCESSES, AcceptanceProcess, \
    RoutingSimulator, effective_affinity

from .common import emit, save_json


def run_lookahead(cfg, task: str, k: int, g: int, *, iters=250, seed=0,
                  controller=None):
    """G parallel branches of K drafts; at most one branch is accepted."""
    rng = np.random.default_rng(seed)
    acc = AcceptanceProcess(TASK_PROCESSES[task], rng)
    aff = effective_affinity(cfg.name, task)
    routing = RoutingSimulator(cfg.num_experts, cfg.experts_per_token,
                               aff, rng)
    t_total, toks_total = 0.0, 0
    ctl = controller
    for _ in range(iters):
        kk = ctl.next_k() if ctl else k
        a = acc.step()
        # primary branch drafts the greedy continuation (acceptance a);
        # alternative branches are off-greedy candidates whose tokens match
        # far less often (Medusa/Lookahead's tree arms) — branch diversity
        # helps sub-linearly while in-flight tokens grow linearly in G.
        n_acc = 0
        for b in range(max(1, g if kk else 1)):
            a_b = a if b == 0 else a * 0.35
            n = 0
            for _ in range(kk):
                if rng.random() < a_b:
                    n += 1
                else:
                    break
            n_acc = max(n_acc, n)
        tokens = n_acc + 1
        n_inflight = g * kk + 1 if kk else 1
        uniq = routing.unique_for(n_inflight)
        r = cm.iteration_time(cfg, cm.TPU_V5E, n_inflight, 1024,
                              unique_experts=uniq)
        t = r["t_iter"] + cm.draft_time(cm.TPU_V5E, g * kk) + \
            cm.sample_time(g * kk)
        if ctl:
            ctl.observe(tokens, t, k=kk if kk else 0)
        t_total += t
        toks_total += tokens
    return t_total / toks_total


def main(fast: bool = False):
    cfg = get_config("mixtral-8x7b")
    iters = 120 if fast else 300
    rows = []
    base = run_lookahead(cfg, "code", 0, 1, iters=iters,
                         controller=StaticKController(0))
    for g in (1, 4, 8):
        for k in (3, 5):
            tpot = run_lookahead(cfg, "code", k, g, iters=iters)
            rows.append({"g": g, "k": k, "speedup": base / tpot})
            emit(f"lookahead/mixtral/code/G{g}K{k}", tpot * 1e6,
                 f"speedup={base/tpot:.3f}")
    # Cascade on top of a G=8 lookahead drafter: must park at K=0
    ctl = CascadeController()
    tpot_c = run_lookahead(cfg, "code", 3, 8, iters=iters, controller=ctl)
    rows.append({"g": 8, "k": "cascade", "speedup": base / tpot_c,
                 "final_k": ctl.next_k()})
    emit("lookahead/mixtral/code/G8cascade", tpot_c * 1e6,
         f"speedup={base/tpot_c:.3f};final_k={ctl.next_k()}")
    save_json("lookahead_study", rows)
    return rows


if __name__ == "__main__":
    main()
