"""Paper Fig. 13 (the main result): Cascade vs static-K across 5 MoEs x 7
workloads with n-gram speculation. Headline claims: worst-case slowdown <=
~5% (vs up to 54% static) and 7-15% mean gain over static-K."""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.data.workloads import MIXES
from repro.sim.simulator import run_point

from .common import PAPER_MODELS, PAPER_TASKS, emit, save_json


def main(fast: bool = False):
    models = PAPER_MODELS[:2] if fast else PAPER_MODELS
    tasks = PAPER_TASKS[:3] if fast else PAPER_TASKS
    n_req, iters = (4, 120) if fast else (8, 300)
    rows = []
    for model in models:
        cfg = get_config(model)
        for task in tasks:
            mix = list(MIXES[task])
            rec = {"model": model, "task": task}
            for pol in ["cascade", 1, 2, 3]:
                k = None if pol == "cascade" else pol
                r = run_point(cfg, mix, k, n_requests=n_req, iters=iters,
                              seed=13)
                rec[f"speedup_{pol}"] = r["speedup"]
            rows.append(rec)
            emit(f"cascade_main/{model}/{task}", 0.0,
                 ";".join(f"{p}={rec[f'speedup_{p}']:.3f}"
                          for p in ["cascade", 1, 2, 3]))

    cas = np.array([r["speedup_cascade"] for r in rows])
    stat = {k: np.array([r[f"speedup_{k}"] for r in rows]) for k in (1, 2, 3)}
    summary = {
        "cascade_worst": float(cas.min()),
        "static_worst": {k: float(v.min()) for k, v in stat.items()},
        "cascade_mean": float(cas.mean()),
        "static_mean": {k: float(v.mean()) for k, v in stat.items()},
        "gain_vs_best_static_mean": float(
            (cas / np.maximum.reduce(list(stat.values()))).mean()),
    }
    save_json("cascade_main", {"rows": rows, "summary": summary})
    emit("cascade_main/worst", 0.0,
         f"cascade={summary['cascade_worst']:.3f};"
         f"staticK3={summary['static_worst'][3]:.3f}")
    emit("cascade_main/mean", 0.0,
         f"cascade={summary['cascade_mean']:.3f};"
         f"bestStaticRatio={summary['gain_vs_best_static_mean']:.3f}")
    return summary


if __name__ == "__main__":
    main()
