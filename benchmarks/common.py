"""Shared benchmark plumbing: CSV emission + result persistence."""

from __future__ import annotations

import json
import os
import time

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

PAPER_MODELS = ["mixtral-8x7b", "phi-3.5-moe", "olmoe-1b-7b",
                "deepseek-moe-16b", "qwen15-moe-a2.7b"]
PAPER_TASKS = ["code", "math", "extract", "code+math", "math+extract",
               "code+extract", "all-3"]


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def save_json(name: str, obj):
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
